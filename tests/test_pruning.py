"""End-to-end ADMM pattern pruning in miniature (paper §III-A, Table II).

Validates the paper's qualitative claims on a CPU-sized problem: pattern
pruning reaches irregular-level sparsity with a handful of patterns per
layer and negligible accuracy loss after projection + retraining.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pruning import (
    PruneConfig,
    admm_pattern_prune,
    build_dictionaries,
    magnitude_prune,
    sparsity_of,
)
from repro.models.cnn import (
    cnn_apply,
    conv_weight_names,
    init_cnn,
    mini_cnn_config,
)
from repro.optim import adamw


@pytest.fixture(scope="module")
def task():
    cfg = mini_cnn_config(num_classes=4, input_hw=12)
    protos = jax.random.normal(jax.random.PRNGKey(42), (4, 1, 12, 12))

    def gen_batch(key, n=64):
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (n,), 0, 4)
        x = protos[y] + 0.7 * jax.random.normal(k2, (n, 1, 12, 12))
        return x, y

    def loss_fn(p, x, y):
        logits = cnn_apply(cfg, p, x)
        return -jnp.mean(
            jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y]
        )

    # train dense
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, s = opt.update(g, s, p, 3e-3)
        return p, s

    key = jax.random.PRNGKey(1)
    for _ in range(300):
        key, sk = jax.random.split(key)
        params, state = step(params, state, *gen_batch(sk))

    def accuracy(p):
        accs = []
        k = jax.random.PRNGKey(999)
        for _ in range(8):
            k, sk = jax.random.split(k)
            x, y = gen_batch(sk, 256)
            accs.append(float((cnn_apply(cfg, p, x).argmax(-1) == y).mean()))
        return float(np.mean(accs))

    return cfg, params, loss_fn, gen_batch, accuracy, opt


def test_magnitude_prune_hits_target(task):
    cfg, params, *_ = task
    names = conv_weight_names(cfg)
    pruned = magnitude_prune(params, names, 0.7)
    assert sparsity_of(pruned, names) == pytest.approx(0.7, abs=0.02)


def test_dictionaries_bounded(task):
    cfg, params, *_ = task
    names = conv_weight_names(cfg)
    pruned = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(pruned, names, num_patterns=4)
    for n in names:
        assert dicts[n].num_patterns <= 6  # 4 nonzero + zero (+1 slack)


@pytest.mark.slow
def test_pattern_pruning_preserves_accuracy(task):
    """The paper's Table-II claim in miniature: >= 70% sparsity, <= 5
    patterns/layer, accuracy drop < 3 points after retraining."""
    cfg, params, loss_fn, gen_batch, accuracy, opt = task
    names = conv_weight_names(cfg)
    acc_dense = accuracy(params)

    def data_iter():
        k = jax.random.PRNGKey(7)
        while True:
            k, sk = jax.random.split(k)
            yield gen_batch(sk)

    pc = PruneConfig(
        target_sparsity=0.7, num_patterns=4, admm_steps=150,
        retrain_steps=150,
    )
    res = admm_pattern_prune(
        params, names, loss_fn, data_iter(), pc, opt
    )
    acc_pruned = accuracy(res.params)
    sp = sparsity_of(res.params, names)
    assert sp >= 0.55, f"sparsity only {sp:.2f}"
    assert acc_pruned >= acc_dense - 0.03, (
        f"accuracy collapse: {acc_dense:.3f} -> {acc_pruned:.3f}"
    )
    # every kernel's mask is in its layer dictionary
    for n in names:
        bits = set(np.unique(res.pattern_bits[n]))
        assert bits.issubset(set(res.dictionaries[n].patterns))
