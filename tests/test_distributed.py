"""Distribution: sharding rules, shard_map MoE parity, mini dry-run.

Tests that need >1 device run in a subprocess via
``conftest.run_virtual_devices`` (the main pytest process stays at 1
device so every other test sees the normal environment).
"""

import pytest
from conftest import run_virtual_devices as _run_sub
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    DEFAULT_RULES,
    logical_to_pspec,
    pad_to_multiple,
    padded_heads,
)


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_logical_to_pspec_divisibility():
    mesh = _FakeMesh({"data": 4, "model": 2})
    # divisible -> sharded
    assert logical_to_pspec(("ff", None), (8, 3), mesh) == P("model")
    # non-divisible -> replicated
    assert logical_to_pspec(("ff", None), (7, 3), mesh) == P()
    # multi-axis batch
    mesh2 = _FakeMesh({"pod": 2, "data": 4, "model": 2})
    assert logical_to_pspec(("batch", None), (16, 3), mesh2) == P(("pod", "data"))
    assert logical_to_pspec(("batch", None), (4, 3), mesh2) == P()


def test_padded_heads():
    assert padded_heads(40, 16) == 48
    assert padded_heads(32, 16) == 32
    assert padded_heads(8, 16) == 16
    assert pad_to_multiple(49155, 256) == 49408


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    """The distributed train step (DP x TP mesh, ZeRO, SP constraints)
    computes the same loss as the single-device step."""
    res = _run_sub(8, """
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import param_shardings
    from repro.optim import adamw
    from repro.parallel.activations import activation_sharding_ctx
    from repro.runtime.train import TrainConfig, init_train_state, make_train_step
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config('granite_3_2b'), model_shards=2)
    params, specs, statics = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    tcfg = TrainConfig(steps=1)
    step = make_train_step(cfg, statics, opt, lambda s: 1e-3, tcfg)
    state = init_train_state(params, opt, tcfg)
    batch = {'tokens': jax.random.randint(jax.random.PRNGKey(3), (8, 33), 0, cfg.vocab)}

    # single device
    _, m1 = jax.jit(step)(jax.tree.map(lambda x: x, state), batch)

    # 4x2 mesh
    mesh = make_mesh((4, 2), ('data', 'model'))
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    p_shard = param_shardings(specs, shapes, mesh)
    state2 = init_train_state(jax.tree.map(jax.device_put, params, p_shard), opt, tcfg)
    def wrapped(s, b):
        with activation_sharding_ctx(mesh):
            return step(s, b)
    _, m2 = jax.jit(wrapped)(state2, batch)
    print(json.dumps({'loss1': float(m1['loss']), 'loss2': float(m2['loss'])}))
    """)
    assert res["loss1"] == pytest.approx(res["loss2"], rel=1e-4)


@pytest.mark.slow
def test_moe_shard_map_matches_local():
    res = _run_sub(8, """
    import jax.numpy as jnp
    from repro.launch.mesh import make_mesh
    from repro.models.moe import MoEConfig, moe_init, moe_apply, _moe_local
    from repro.parallel.activations import activation_sharding_ctx
    mesh = make_mesh((4, 2), ('data', 'model'))
    cfg = MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff_expert=16,
                    model_shards=2, capacity_factor=8.0)
    params, _, static = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 32))
    y_local = _moe_local(params, cfg, x)
    with activation_sharding_ctx(mesh):
        y_dist = jax.jit(lambda p, xx: moe_apply(p, static, cfg, xx))(params, x)
    err = float(jnp.abs(y_local - y_dist).max())
    print(json.dumps({'err': err}))
    """)
    assert res["err"] < 1e-5


@pytest.mark.slow
def test_mini_dryrun_single_and_multipod():
    """A reduced config lowers + compiles on both mesh layouts (the
    full-size equivalent is launch/dryrun.py)."""
    res = _run_sub(16, """
    import jax.numpy as jnp, dataclasses
    from repro.configs import get_smoke_config
    from repro.launch.hlo_stats import cost_analysis_dict
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_step
    from repro.configs.base import ShapeSpec
    shape = ShapeSpec('mini', 'train', 64, 8)
    out = {}
    for name, (dims, axes) in {
        'single': ((4, 4), ('data', 'model')),
        'multi': ((2, 2, 4), ('pod', 'data', 'model')),
    }.items():
        mesh = make_mesh(dims, axes)
        cfg = dataclasses.replace(get_smoke_config('granite_3_2b'),
                                  model_shards=4)
        built = build_step('granite_3_2b', shape, mesh, cfg=cfg)
        compiled = built.fn.lower(*built.args).compile()
        cost = cost_analysis_dict(compiled)
        out[name] = float(cost.get('flops', 0))
    print(json.dumps(out))
    """)
    assert res["single"] > 0
    assert res["multi"] > 0


@pytest.mark.slow
def test_elastic_remesh_restore(tmp_path):
    """Checkpoint on an 8-device mesh, restore onto a 4-device mesh —
    the elastic-scaling path after losing nodes."""
    res = _run_sub(8, f"""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.launch.mesh import make_mesh
    mesh8 = make_mesh((8,), ('data',))
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh8, P('data')))
    save_checkpoint({str(tmp_path)!r}, 3, {{'x': xs}})
    # re-mesh to 4 devices (simulating node loss)
    mesh4 = make_mesh((4,), ('data',), devices=jax.devices()[:4])
    shard4 = {{'x': NamedSharding(mesh4, P('data'))}}
    out = restore_checkpoint({str(tmp_path)!r}, 3, {{'x': x}}, shardings=shard4)
    ok = bool((out['x'] == x).all()) and len(out['x'].sharding.device_set) == 4
    print(json.dumps({{'ok': ok}}))
    """)
    assert res["ok"]
