"""Mapping design-space optimizer: differential + cost-model harness.

Three contracts under test, matching the guarantees ``check_baseline.py``
gates on the bench side:

  * **zero-drift cost model** — ``core.simulator.mapping_cost`` prices a
    candidate through the simulator's own chain, so its
    area/energy/cycles equal the ``hardware_report`` numbers *exactly*
    (``==`` on floats, no tolerance) for every layer of an optimized
    program, fp32 and int8;
  * **semantics preserved** — ``compile_network(optimize='auto')`` only
    changes layout, never math: fp32 logits are bit-identical to the
    fixed scheme on XLA (any forced reorder strategy included), Pallas
    agrees to fp32 noise, the 8-virtual-device sharded path agrees at
    fp32 and int8, and every visited candidate's column reorder is a
    bijective permutation;
  * **never worse, always reproducible** — selection is Pareto-guarded
    (chosen <= fixed on both area-cells and energy, fixed on ties),
    deterministic within a process and byte-identical across processes
    for the same seed, and the chosen mapping round-trips through the
    v3 manifest (v2 manifests still load, as the fixed scheme).

Hypothesis-randomized variants of the bijectivity and zero-drift
properties live in ``tests/test_mapping_search_props.py``; the
exhaustive-sweep oracle check is ``slow``-marked at the bottom.
"""

import dataclasses
import json
import os

import jax
import numpy as np
import pytest
from conftest import run_virtual_devices as _run_sub

from repro.core.mapping import MappingCandidate
from repro.core.mapsearch import (
    MappingSearchConfig,
    choose_fc_reorder,
    search_layer_mapping,
)
from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.core.simulator import mapping_cost
from repro.core.sparse import (
    REORDERS,
    nonzero_block_masks,
    predicted_tile_nnz,
    reorder_columns,
)
from repro.engine import (
    EngineConfig,
    compile_network,
    conv_mapping_search,
    load_program,
    make_forward,
    save_program,
)
from repro.engine.lowering import _pad_axis, conv_matrix, lower_matrix
from repro.models.cnn import conv_weight_names, init_cnn, mini_cnn_config


def _pruned(seed=0, sparsity=0.7, num_patterns=4, widths=(8, 16, 16),
            num_classes=4):
    cfg = mini_cnn_config(num_classes=num_classes, input_hw=12,
                          widths=widths)
    params = init_cnn(cfg, jax.random.PRNGKey(seed))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, sparsity)
    dicts = build_dictionaries(params, names, num_patterns)
    params, bits = project_params(params, dicts)
    return cfg, params, bits


@pytest.fixture(scope="module")
def mini():
    return _pruned()


@pytest.fixture(scope="module")
def progs(mini):
    """(fixed, auto) fp32 programs of the same pruned net."""
    cfg, params, bits = mini
    return (
        compile_network(cfg, params, bits),
        compile_network(cfg, params, bits, optimize="auto"),
    )


@pytest.fixture(scope="module")
def x8():
    return jax.random.normal(jax.random.PRNGKey(5), (8, 1, 12, 12))


# ------------------------------------------------------------- cost model


def test_cost_model_zero_drift_fp32(progs):
    """mapping_cost re-prices every optimized layer to the exact
    hardware_report numbers — the differential that makes search
    predictions trustworthy."""
    _, auto = progs
    rep = auto.hardware_report()
    for c, row in zip(auto.convs, rep["layers"]):
        assert c.mapping is not None
        mc = mapping_cost(c.pattern_bits, c.mapping, c.out_hw ** 2,
                          c.kernel ** 2)
        assert mc.crossbars == row["crossbars"]
        assert mc.area_cells == row["area_cells"]
        assert mc.energy_pj == row["energy_pj"]  # exact, not approx
        assert mc.cycles == row["cycles"]


def test_cost_model_zero_drift_int8(mini):
    """Same zero-drift contract when the search prices the quantized
    cell-slice count."""
    cfg, params, bits = mini
    prog = compile_network(cfg, params, bits, precision="int8",
                           optimize="auto")
    rep = prog.hardware_report()
    for c, row in zip(prog.convs, rep["layers"]):
        assert c.mapping.cells_per_weight == prog.cells_per_weight
        mc = mapping_cost(c.pattern_bits, c.mapping, c.out_hw ** 2,
                          c.kernel ** 2)
        assert (mc.crossbars, mc.area_cells, mc.energy_pj, mc.cycles) == (
            row["crossbars"], row["area_cells"], row["energy_pj"],
            row["cycles"],
        )


def test_search_cost_equals_report_cost(mini, progs):
    """The standalone search's predicted cost for its chosen candidate is
    the cost the compiled program reports."""
    cfg, params, bits = mini
    _, auto = progs
    rep = auto.hardware_report()
    for i, (c, row) in enumerate(zip(auto.convs, rep["layers"]), start=1):
        res = conv_mapping_search(
            np.asarray(params[f"conv{i}"]["w"]), bits[f"conv{i}"], c.out_hw
        )
        assert res.chosen == c.mapping
        assert res.cost.area_cells == row["area_cells"]
        assert res.cost.energy_pj == row["energy_pj"]


# ------------------------------------------------- search-loop invariants


def test_visited_candidates_all_bijective(mini):
    """Every candidate the search prices induces a bijective column
    permutation on the layer's engine operands — no reorder strategy can
    drop or duplicate an output column."""
    cfg, params, bits = mini
    ecfg = EngineConfig()
    for i in (1, 2, 3):
        w = np.asarray(params[f"conv{i}"]["w"], np.float32)
        wp = _pad_axis(_pad_axis(conv_matrix(w), 0, ecfg.block), 1,
                       ecfg.tile)
        masks = nonzero_block_masks(wp, ecfg.block)
        res = conv_mapping_search(w, bits[f"conv{i}"], out_hw=10)
        assert res.evaluations == len(res.visited) > 1
        for cand in res.visited:
            order = reorder_columns(masks, cand.reorder)
            np.testing.assert_array_equal(
                np.sort(order), np.arange(masks.shape[0])
            )


def test_predicted_bricks_match_built(mini):
    """predicted_tile_nnz (the search's engine-memory objective) equals
    the brick count the lowering actually stores, per strategy."""
    cfg, params, bits = mini
    ecfg = EngineConfig()
    w = np.asarray(params["conv2"]["w"], np.float32)
    wp = _pad_axis(_pad_axis(conv_matrix(w), 0, ecfg.block), 1, ecfg.tile)
    masks = nonzero_block_masks(wp, ecfg.block)
    for strategy in REORDERS:
        order = reorder_columns(masks, strategy)
        predicted = int(predicted_tile_nnz(masks, order, ecfg.tile).sum())
        bp = lower_matrix(wp, ecfg.block, ecfg.tile, reorder=strategy)
        assert predicted == int(bp.nnz.sum())


def test_pareto_guard_never_worse(mini):
    cfg, params, bits = mini
    for i in (1, 2, 3):
        res = conv_mapping_search(
            np.asarray(params[f"conv{i}"]["w"]), bits[f"conv{i}"], out_hw=10
        )
        assert res.cost.area_cells <= res.fixed_cost.area_cells
        assert res.cost.energy_pj <= res.fixed_cost.energy_pj
        assert res.fixed == MappingCandidate()
    # the smoke net must show a strict win somewhere (ISSUE acceptance)
    assert any(
        conv_mapping_search(
            np.asarray(params[f"conv{i}"]["w"]), bits[f"conv{i}"], out_hw=10
        ).improved
        for i in (1, 2, 3)
    )


def test_search_rerun_identical(mini):
    """Same inputs + seed -> byte-identical result object, visited order
    included."""
    cfg, params, bits = mini
    w = np.asarray(params["conv1"]["w"])
    a = conv_mapping_search(w, bits["conv1"], out_hw=10)
    b = conv_mapping_search(w, bits["conv1"], out_hw=10)
    assert a == b
    assert a.visited == b.visited


def test_tie_keeps_fixed_scheme():
    """A layer too small for any geometry to win: the Pareto tie-break
    must return the fixed scheme itself, unimproved."""
    bits = np.full((2, 2), 0b111111111, dtype=np.int64)
    res = search_layer_mapping(
        bits,
        search=MappingSearchConfig(crossbar_dims=((512, 512),),
                                   block_orders=("pattern",),
                                   reorders=("pattern",)),
    )
    assert res.chosen == res.fixed
    assert not res.improved


def test_search_config_validation():
    with pytest.raises(ValueError, match="block orders"):
        MappingSearchConfig(block_orders=("bogus",))
    with pytest.raises(ValueError, match="reorder"):
        MappingSearchConfig(reorders=("bogus",))
    with pytest.raises(ValueError, match="crossbar dims"):
        MappingSearchConfig(crossbar_dims=((0, 512),))
    with pytest.raises(ValueError, match="restarts"):
        MappingSearchConfig(restarts=-1)
    # a fixed scheme that cannot realize the layer is an error, not a
    # silent fallback
    bits = np.full((2, 2), 0b111111111, dtype=np.int64)
    with pytest.raises(ValueError, match="cannot realize"):
        search_layer_mapping(bits, fixed=MappingCandidate(ou_rows=2))


def test_optimize_arg_validation(mini):
    cfg, params, bits = mini
    with pytest.raises(ValueError, match="optimize"):
        compile_network(cfg, params, bits, optimize="bogus")
    with pytest.raises(ValueError, match="optimize"):
        compile_network(cfg, params, bits, optimize=42)


def test_choose_fc_reorder_counts_complete():
    rng = np.random.default_rng(3)
    masks = rng.random((64, 7)) < 0.4
    best, counts = choose_fc_reorder(masks, tile=16)
    assert set(counts) == set(REORDERS)
    assert counts[best] == min(counts.values())
    # ties keep the earliest strategy in the tuple ('pattern' first)
    tied = {s for s in REORDERS if counts[s] == counts[best]}
    assert best == next(s for s in REORDERS if s in tied)


# ------------------------------------------------------------ differential


def test_auto_logits_bit_identical_xla(progs, x8):
    fixed, auto = progs
    ref = np.asarray(make_forward(fixed, backend="xla")(x8))
    out = np.asarray(make_forward(auto, backend="xla")(x8))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("strategy", REORDERS)
def test_forced_reorder_bit_identical_xla(mini, x8, strategy):
    """Any single reorder strategy forced through the search changes
    layout only: fp32 XLA logits stay bit-identical to the fixed
    compile."""
    cfg, params, bits = mini
    fixed = compile_network(cfg, params, bits)
    auto = compile_network(
        cfg, params, bits,
        optimize=MappingSearchConfig(reorders=(strategy,)),
    )
    ref = np.asarray(make_forward(fixed, backend="xla")(x8))
    out = np.asarray(make_forward(auto, backend="xla")(x8))
    np.testing.assert_array_equal(out, ref)


def test_auto_pallas_interpret_matches(progs, x8):
    fixed, auto = progs
    ref = np.asarray(make_forward(fixed, backend="xla")(x8))
    out = np.asarray(
        make_forward(auto, backend="pallas", interpret=True)(x8)
    )
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_auto_int8_tolerance_equal(mini):
    """int8 logits are only tolerance-equal across layouts: per-brick
    quantization scales depend on column grouping, so a reorder can
    shift individual logits by O(quantization error)."""
    cfg, params, bits = mini
    fixed = compile_network(cfg, params, bits, precision="int8")
    auto = compile_network(cfg, params, bits, precision="int8",
                           optimize="auto")
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 1, 12, 12))
    ref = np.asarray(make_forward(fixed, backend="xla")(x))
    out = np.asarray(make_forward(auto, backend="xla")(x))
    np.testing.assert_allclose(out, ref, atol=5e-3)
    assert (out.argmax(-1) == ref.argmax(-1)).mean() >= 0.95


def test_sharded_auto_matches_subprocess():
    """optimize='auto' programs shard identically to fixed ones: on 8
    virtualized devices the searched fp32 program agrees with its own
    single-device run and with the fixed program, and int8 holds to the
    quantization bound."""
    res = _run_sub(8, """
    from repro.core.pruning import (build_dictionaries, magnitude_prune,
                                    project_params)
    from repro.engine import compile_network, make_forward
    from repro.launch.mesh import make_mesh
    from repro.models.cnn import (conv_weight_names, init_cnn,
                                  mini_cnn_config)

    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 1, 12, 12))
    mesh = make_mesh((1, 8), ("data", "model"))

    out = {}
    fixed = compile_network(cfg, params, bits)
    auto = compile_network(cfg, params, bits, optimize="auto")
    ref = np.asarray(make_forward(fixed, backend="xla")(x))
    single = np.asarray(make_forward(auto, backend="xla")(x))
    sharded = np.asarray(make_forward(auto, backend="xla", mesh=mesh)(x))
    out["fp32_auto_vs_fixed"] = float(np.abs(single - ref).max())
    out["fp32_sharded_vs_single"] = float(np.abs(sharded - single).max())

    autoq = compile_network(cfg, params, bits, precision="int8",
                            optimize="auto")
    sq = np.asarray(make_forward(autoq, backend="xla")(x))
    shq = np.asarray(make_forward(autoq, backend="xla", mesh=mesh)(x))
    out["int8_sharded_vs_single"] = float(np.abs(shq - sq).max())
    print(json.dumps(out))
    """)
    assert res["fp32_auto_vs_fixed"] == 0.0  # bit-identical, not close
    assert res["fp32_sharded_vs_single"] < 1e-4
    assert res["int8_sharded_vs_single"] < 5e-3


# -------------------------------------------------------- reproducibility


def test_search_cross_process_determinism():
    """Same seed, two fresh processes: chosen mappings byte-identical."""
    body = """
    from repro.core.pruning import (build_dictionaries, magnitude_prune,
                                    project_params)
    from repro.engine import compile_network
    from repro.models.cnn import (conv_weight_names, init_cnn,
                                  mini_cnn_config)

    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    prog = compile_network(cfg, params, bits, optimize="auto")
    print(json.dumps({
        "mappings": [c.mapping.to_manifest() for c in prog.convs],
        "fc": prog.fc.reorder,
    }))
    """
    a = _run_sub(1, body)
    b = _run_sub(1, body)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_in_process_matches_subprocess(progs):
    """The compiled choice is environment-independent: the subprocess
    result equals this process's compile."""
    _, auto = progs
    res = _run_sub(1, """
    from repro.core.pruning import (build_dictionaries, magnitude_prune,
                                    project_params)
    from repro.engine import compile_network
    from repro.models.cnn import (conv_weight_names, init_cnn,
                                  mini_cnn_config)

    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    prog = compile_network(cfg, params, bits, optimize="auto")
    print(json.dumps([c.mapping.to_manifest() for c in prog.convs]))
    """)
    assert res == [c.mapping.to_manifest() for c in auto.convs]


# --------------------------------------------------------- serialization


def test_v3_roundtrip_preserves_mapping(tmp_path, progs, x8):
    _, auto = progs
    d = str(tmp_path / "prog")
    save_program(d, auto)
    loaded = load_program(d)  # verify=True: V205/V206 run on the load
    for a, b in zip(auto.convs, loaded.convs):
        assert a.mapping == b.mapping
    assert loaded.fc.reorder == auto.fc.reorder
    ref = np.asarray(make_forward(auto, backend="xla")(x8))
    out = np.asarray(make_forward(loaded, backend="xla")(x8))
    np.testing.assert_array_equal(out, ref)
    assert loaded.hardware_report() == auto.hardware_report()


def test_v2_manifest_loads_as_fixed_scheme(tmp_path, progs, x8):
    """A hand-downgraded v2 manifest (no mapping keys) still loads: convs
    get ``mapping=None``, the FC reorder defaults to 'pattern', and the
    program verifies clean."""
    fixed, _ = progs
    d = str(tmp_path / "prog")
    save_program(d, fixed)
    mpath = os.path.join(d, "program.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format_version"] = 2
    for e in manifest["convs"]:
        del e["mapping"]
    del manifest["fc"]["reorder"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    loaded = load_program(d)
    assert all(c.mapping is None for c in loaded.convs)
    assert loaded.fc.reorder == "pattern"
    ref = np.asarray(make_forward(fixed, backend="xla")(x8))
    np.testing.assert_array_equal(
        np.asarray(make_forward(loaded, backend="xla")(x8)), ref
    )


def test_report_mapping_section(progs):
    fixed, auto = progs
    rf, ra = fixed.hardware_report(), auto.hardware_report()
    assert rf["mapping"]["optimized"] is False
    assert ra["mapping"]["optimized"] is True
    assert ra["mapping"]["per_layer"] == {
        c.name: c.mapping.to_manifest() for c in auto.convs
    }
    # totals are the per-layer sums, and the search won on area
    assert ra["area_cells"] == sum(r["area_cells"] for r in ra["layers"])
    assert ra["area_cells"] < rf["area_cells"]
    assert ra["energy_pj"] <= rf["energy_pj"]


# ----------------------------------------------------------------- oracle


@pytest.mark.slow
def test_greedy_matches_exhaustive_oracle(mini):
    """On the smoke layers the greedy descent must find the exhaustive
    sweep's optimum (same objective value — the argmin candidate may
    differ only on tie-broken axes)."""
    cfg, params, bits = mini
    for i in (1, 2, 3):
        w = np.asarray(params[f"conv{i}"]["w"])
        greedy = conv_mapping_search(w, bits[f"conv{i}"], out_hw=10)
        oracle = conv_mapping_search(
            w, bits[f"conv{i}"], out_hw=10,
            search=MappingSearchConfig(exhaustive=True),
        )
        assert dataclasses.astuple(greedy.cost) == \
            dataclasses.astuple(oracle.cost)
        assert greedy.bricks == oracle.bricks
