"""Inference engine: lowering/executor parity, serialization, service."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.mapping import map_layer
from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.core.sparse import block_density
from repro.engine import (
    ClassifyRequest,
    EngineConfig,
    InferenceService,
    compile_network,
    execute,
    extract_patches,
    load_program,
    make_forward,
    save_program,
)
from repro.models.cnn import (
    cnn_apply,
    conv_weight_names,
    init_cnn,
    mini_cnn_config,
    vgg16_config,
)

BACKENDS = [("xla", None), ("pallas", True)]


def _pruned_net(cfg, seed=0, sparsity=0.7, num_patterns=4):
    params = init_cnn(cfg, jax.random.PRNGKey(seed))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, sparsity)
    dicts = build_dictionaries(params, names, num_patterns)
    return project_params(params, dicts)


@pytest.fixture(scope="module")
def mini():
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params, bits = _pruned_net(cfg)
    return cfg, params, bits, compile_network(cfg, params, bits)


def test_extract_patches_matches_conv(rng):
    """im2col patches @ conv_matrix == lax conv (the lowering's premise)."""
    from repro.engine.lowering import conv_matrix

    x = jnp.asarray(rng.normal(size=(2, 3, 6, 6)).astype(np.float32))
    w = rng.normal(size=(5, 3, 3, 3)).astype(np.float32)
    patches = extract_patches(x, 3)  # [B, H, W, C*9]
    y = patches.reshape(-1, 27) @ jnp.asarray(conv_matrix(w))
    y = y.reshape(2, 6, 6, 5).transpose(0, 3, 1, 2)
    ref = jax.lax.conv_general_dilated(
        x, jnp.asarray(w), (1, 1), "SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_lowering_is_lossless(mini):
    """Compressed operands reconstruct the pruned dense weights exactly."""
    from repro.engine.lowering import conv_matrix

    cfg, params, bits, prog = mini
    for i, op in enumerate(prog.convs, start=1):
        wm = conv_matrix(np.asarray(params[f"conv{i}"]["w"]))
        dense = np.asarray(op.bp.dense())[: wm.shape[0], : wm.shape[1]]
        np.testing.assert_array_equal(dense.astype(np.float32), wm)
        assert 0.0 < block_density(op.bp) <= 1.0


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_mini_cnn_parity(mini, backend, interpret):
    cfg, params, bits, prog = mini
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 1, 12, 12))
    ref = cnn_apply(cfg, params, x)
    out = make_forward(prog, backend=backend, interpret=interpret)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_vgg16_parity(backend, interpret):
    cfg = vgg16_config(num_classes=10, input_hw=32)
    params, bits = _pruned_net(cfg, seed=1, sparsity=0.86, num_patterns=8)
    prog = compile_network(cfg, params, bits)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 32, 32))
    ref = cnn_apply(cfg, params, x)
    out = make_forward(prog, backend=backend, interpret=interpret)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


def test_engine_config_small_blocks(mini):
    """Non-default (block, tile) geometry stays exact."""
    cfg, params, bits, _ = mini
    prog = compile_network(cfg, params, bits,
                           ecfg=EngineConfig(block=9, tile=8))
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 1, 12, 12))
    ref = cnn_apply(cfg, params, x)
    out = make_forward(prog, backend="xla")(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


def test_serialize_roundtrip_bit_exact(mini, tmp_path):
    cfg, params, bits, prog = mini
    path = save_program(str(tmp_path / "prog"), prog)
    prog2 = load_program(path)

    assert prog2.config == cfg
    assert (prog2.block, prog2.tile) == (prog.block, prog.tile)
    for a, b in zip(prog.convs, prog2.convs):
        assert (a.name, a.c_in, a.c_out, a.kernel, a.out_hw, a.pool_after) \
            == (b.name, b.c_in, b.c_out, b.kernel, b.out_hw, b.pool_after)
        np.testing.assert_array_equal(np.asarray(a.bp.w_comp),
                                      np.asarray(b.bp.w_comp))
        np.testing.assert_array_equal(np.asarray(a.bp.block_ids),
                                      np.asarray(b.bp.block_ids))
        np.testing.assert_array_equal(a.bp.nnz, b.bp.nnz)
        np.testing.assert_array_equal(a.bp.new_order, b.bp.new_order)
        np.testing.assert_array_equal(a.bp.inv_order, b.bp.inv_order)
        np.testing.assert_array_equal(a.bias, b.bias)
        np.testing.assert_array_equal(a.pattern_bits, b.pattern_bits)
    np.testing.assert_array_equal(np.asarray(prog.fc.bp.w_comp),
                                  np.asarray(prog2.fc.bp.w_comp))
    np.testing.assert_array_equal(prog.fc.bias, prog2.fc.bias)

    x = jax.random.normal(jax.random.PRNGKey(9), (3, 1, 12, 12))
    np.testing.assert_array_equal(
        np.asarray(execute(prog, x, backend="xla")),
        np.asarray(execute(prog2, x, backend="xla")),
    )


def test_serialize_roundtrip_partition_metadata(mini, tmp_path):
    """A partitioned program reloads with its partition intact and still
    produces the identical forward output (golden, bit-exact)."""
    from repro.engine import NetworkPartition, partition_network

    cfg, params, bits, prog = mini
    progp = partition_network(prog, data=2, model=4)
    x = jax.random.normal(jax.random.PRNGKey(21), (3, 1, 12, 12))
    golden = np.asarray(execute(prog, x, backend="xla"))

    path = save_program(str(tmp_path / "prog_part"), progp)
    prog2 = load_program(path)
    assert prog2.partition == NetworkPartition(data=2, model=4)
    np.testing.assert_array_equal(
        np.asarray(execute(prog2, x, backend="xla")), golden
    )
    # the chips view survives the round trip via the partition
    rep = prog2.hardware_report()
    assert rep["chips"]["n_chips"] == 8

    # an unpartitioned program round-trips with no partition
    prog3 = load_program(save_program(str(tmp_path / "prog_plain"), prog))
    assert prog3.partition is None
    assert "chips" not in prog3.hardware_report()


def test_serialize_roundtrip_quantized(mini, tmp_path):
    """Quantized programs round-trip bit-exactly: int8 payloads, fp32
    row-group scales, precision/cell_bits and partition metadata all
    survive, and the reloaded program executes identically."""
    from repro.engine import partition_network

    cfg, params, bits, _ = mini
    progq = partition_network(
        compile_network(cfg, params, bits, precision="int8"), data=2, model=2
    )
    path = save_program(str(tmp_path / "progq"), progq)
    prog2 = load_program(path)

    assert prog2.precision == "int8"
    assert prog2.cell_bits == progq.cell_bits
    assert prog2.partition == progq.partition
    for a, b in zip([*progq.convs, progq.fc], [*prog2.convs, prog2.fc]):
        wa, wb = np.asarray(a.bp.w_comp), np.asarray(b.bp.w_comp)
        assert wa.dtype == wb.dtype == np.int8
        np.testing.assert_array_equal(wa, wb)
        sa, sb = np.asarray(a.bp.w_scales), np.asarray(b.bp.w_scales)
        assert sa.dtype == sb.dtype == np.float32
        np.testing.assert_array_equal(sa, sb)

    x = jax.random.normal(jax.random.PRNGKey(17), (3, 1, 12, 12))
    np.testing.assert_array_equal(
        np.asarray(execute(progq, x, backend="xla")),
        np.asarray(execute(prog2, x, backend="xla")),
    )


def test_save_is_atomic(mini, tmp_path):
    """A second save over an existing program replaces it cleanly."""
    *_, prog = mini
    path = save_program(str(tmp_path / "prog"), prog)
    path2 = save_program(str(tmp_path / "prog"), prog)
    assert path == path2
    load_program(path)  # still loadable, no stale .tmp / .old
    import os
    assert not os.path.exists(path + ".tmp")
    assert not os.path.exists(path + ".old")


def test_load_falls_back_to_old_after_interrupted_swap(mini, tmp_path):
    """A save killed between the two swap renames leaves the previous
    program at <dir>.old; load_program must still find it."""
    import os

    *_, prog = mini
    path = save_program(str(tmp_path / "prog"), prog)
    os.replace(path, path + ".old")  # simulate the crash window
    prog2 = load_program(path)
    np.testing.assert_array_equal(prog.fc.bias, prog2.fc.bias)


def test_service_matches_forward(mini):
    cfg, params, bits, prog = mini
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(11), (8, 1, 12, 12)),
        np.float32,
    )
    svc = InferenceService(prog, batch_slots=8, backend="xla")
    labels = svc.classify(x)
    ref = np.asarray(make_forward(prog, backend="xla")(jnp.asarray(x)))
    np.testing.assert_array_equal(labels, ref.argmax(-1))
    assert svc.batches_run == 1

    # two generations: 16 requests through 8 slots
    reqs = [ClassifyRequest(image=img) for img in np.concatenate([x, x])]
    svc.serve(reqs)
    assert all(r.done and r.logits is not None for r in reqs)
    np.testing.assert_array_equal(
        [r.label for r in reqs[:8]], [r.label for r in reqs[8:]]
    )
    assert svc.batches_run == 3


def test_service_partial_batch_padded_with_dead_slots(mini):
    """A partial batch runs zero-padded at the fixed batch_slots shape:
    per-sample channel_norm keeps dead slots numerically inert, so the
    live rows are bit-identical to the same images inside the padded
    batch and match the natural-size forward to fp32 tolerance."""
    cfg, params, bits, prog = mini
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(13), (3, 1, 12, 12)),
        np.float32,
    )
    svc = InferenceService(prog, batch_slots=8, backend="xla")
    reqs = [ClassifyRequest(image=img) for img in x]
    svc.serve(reqs)
    assert svc.batches_run == 1 and svc.trace_count() == 1
    got = np.stack([r.logits for r in reqs])
    padded = np.zeros((8, 1, 12, 12), np.float32)
    padded[:3] = x
    fixed = np.asarray(make_forward(prog, backend="xla")(jnp.asarray(padded)))
    np.testing.assert_array_equal(got, fixed[:3])
    natural = np.asarray(make_forward(prog, backend="xla")(jnp.asarray(x)))
    np.testing.assert_allclose(got, natural, rtol=1e-5, atol=1e-6)


def test_program_introspection(mini):
    """op_list covers the whole schedule; weight_bytes matches the bricks."""
    cfg, params, bits, prog = mini
    ops = prog.op_list()
    assert len(ops) == prog.num_ops == cfg.num_convs + 2
    assert [name for name, _ in ops[:-2]] \
        == [f"conv{i}" for i in range(1, cfg.num_convs + 1)]
    assert ops[-1][0] == "fc"

    comp, dense = prog.weight_bytes()
    expect_comp = sum(
        int(np.sum(op.bp.nnz)) * op.bp.block * op.bp.tile * 4
        for op in [*prog.convs, prog.fc]
    )
    expect_dense = 4 * (
        sum(c.c_in * 9 * c.c_out for c in prog.convs)
        + prog.fc.d_in * prog.fc.d_out
    )
    assert (comp, dense) == (expect_comp, expect_dense)


def test_hardware_report_consistent_with_mapping(mini):
    """Report crossbar counts == direct map_layer on the same bits."""
    cfg, params, bits, prog = mini
    rep = prog.hardware_report()
    expect = sum(
        map_layer(bits[f"conv{i}"]).num_crossbars
        for i in range(1, cfg.num_convs + 1)
    )
    assert rep["crossbars"] == expect
    assert rep["naive_crossbars"] >= rep["crossbars"]
    assert rep["energy_pj"] > 0 and rep["cycles"] > 0
    assert len(rep["layers"]) == cfg.num_convs
