"""End-to-end system behaviour tests.

The paper's full pipeline: train a CNN -> pattern-prune -> map onto
crossbars -> simulate the accelerator -> verify the three paper metrics
exist and are self-consistent; plus the LM-framework end-to-end paths
(train a small LM, serve it, checkpoint/restart).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, runnable, skip_reason


def test_shape_registry_covers_40_cells():
    cells = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells if not runnable(*c)]
    # exactly the seven pure-full-attention archs skip long_500k
    assert len(skips) == 7
    assert all(s == "long_500k" for _, s in skips)
    for a, s in skips:
        assert "sub-quadratic" in skip_reason(a, s)


def test_paper_pipeline_end_to_end(rng):
    """Synthetic pruned layer -> mapping -> OU schedule -> energy/cycles:
    every stage consistent with the next."""
    from repro.core.indexing import build_index_stream, index_overhead_bits
    from repro.core.mapping import map_layer, map_layer_naive
    from repro.core.ou import naive_ou_schedule, pattern_ou_schedule
    from repro.core.patterns import pattern_sizes
    from repro.core.synthetic import LayerSpec, synthesize_layer

    spec = LayerSpec("conv", c_in=16, c_out=64, out_hw=8)
    layer = synthesize_layer(
        spec, n_patterns=5, zero_ratio=0.35, target_sparsity=0.8,
        rng=np.random.default_rng(0),
    )
    m = map_layer(layer.pattern_bits)
    naive = map_layer_naive(spec.c_out, spec.c_in)
    assert m.num_crossbars <= naive.num_crossbars

    sched = pattern_ou_schedule(m)
    # OU cells cover exactly the stored weight cells
    stored_cells = int(pattern_sizes(layer.pattern_bits).sum()) * 4
    assert int((sched.bitlines * sched.wordlines).sum()) == stored_cells

    stream = build_index_stream(m)
    bits = index_overhead_bits(stream)
    assert bits["total_bits"] > 0
    # index overhead beats storing full coordinates
    naive_coords = m.stored_kernels * (9 + 9 + 6)  # xbar,row,col
    assert bits["kernel_index_bits"] < naive_coords


def test_lm_train_then_serve(tmp_path):
    """Train a small LM on the bigram corpus, then serve it: greedy
    continuations must be valid tokens from a trained model."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataConfig, SyntheticCorpus, packed_batches
    from repro.models.transformer import init_params
    from repro.optim import adamw
    from repro.runtime.serve import ServeConfig, ServeLoop
    from repro.serve import Request
    from repro.runtime.train import (
        TrainConfig,
        Trainer,
        init_train_state,
        make_train_step,
    )

    cfg = get_smoke_config("granite_3_2b")
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    tcfg = TrainConfig(steps=40, ckpt_every=40, ckpt_dir=str(tmp_path))
    step = make_train_step(cfg, statics, opt, lambda s: 3e-3, tcfg)
    state = init_train_state(params, opt, tcfg)
    corpus = SyntheticCorpus(cfg.vocab, seed=3)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=3)
    trainer = Trainer(jax.jit(step), state, packed_batches(dcfg, corpus), tcfg)
    hist = trainer.run()
    assert hist[-1]["loss"] < hist[0]["loss"]

    scfg = ServeConfig(batch_slots=4, max_seq=48, eos_id=-1)
    loop = ServeLoop(cfg, statics, trainer.state["params"], scfg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=8)
        for _ in range(4)
    ]
    loop.generate(reqs)
    for r in reqs:
        assert len(r.output) == 8
        assert all(0 <= t < cfg.vocab for t in r.output)


def test_serve_loop_handles_more_requests_than_slots():
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params
    from repro.runtime.serve import ServeConfig, ServeLoop
    from repro.serve import Request

    cfg = get_smoke_config("mamba2_780m")
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_slots=2, max_seq=24, eos_id=-1)
    loop = ServeLoop(cfg, statics, params, scfg)
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=4)
        for _ in range(5)  # 5 requests, 2 slots -> 3 generations
    ]
    loop.generate(reqs)
    assert all(len(r.output) == 4 for r in reqs)
