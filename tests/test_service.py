"""InferenceService on the continuous-batching scheduler.

The two regression properties this file pins down:

  * **batch-composition independence** — per-sample ``channel_norm``
    makes a request's logits bit-identical whether it is served alone,
    co-batched with arbitrary other requests, or next to zero-padded
    dead slots (the pre-fix norm reduced over the batch axis, so logits
    depended on who shared the batch);
  * **one traced shape + exact statistics** — the service always
    executes the fixed ``batch_slots`` batch, so a bursty trace traces
    the forward exactly once, and the validity mask keeps the measured
    skip statistics equal to a one-shot stats forward over exactly the
    live images (dead slots excluded from counts and windows).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.engine import (
    ClassifyRequest,
    InferenceService,
    SchedulerFull,
    compile_network,
    execute,
    make_forward,
)
from repro.models.cnn import (
    cnn_apply,
    conv_weight_names,
    init_cnn,
    mini_cnn_config,
)

BACKENDS = [("xla", None), ("pallas", True)]


@pytest.fixture(scope="module")
def mini():
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    return cfg, params, bits, compile_network(cfg, params, bits)


def _images(n, seed=5):
    return np.array(
        jax.random.normal(jax.random.PRNGKey(seed), (n, 1, 12, 12)),
        np.float32,
    )


# ----------------------------------------------- composition independence


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_logits_invariant_to_batch_composition(mini, backend, interpret):
    """The same image at the same batch shape yields bit-identical logits
    regardless of what fills the other rows: other requests, different
    other requests, or zero-padded dead slots."""
    cfg, params, bits, prog = mini
    fwd = make_forward(prog, backend=backend, interpret=interpret)
    x = _images(8)
    crowd = np.asarray(fwd(jnp.asarray(x)))

    padded = np.zeros_like(x)
    padded[0] = x[0]
    dead = np.asarray(fwd(jnp.asarray(padded)))

    other = _images(8, seed=9)
    other[0] = x[0]
    recrowd = np.asarray(fwd(jnp.asarray(other)))

    np.testing.assert_array_equal(crowd[0], dead[0])
    np.testing.assert_array_equal(crowd[0], recrowd[0])


def test_dense_reference_composition_independent(mini):
    """cnn_apply (the shared-norm reference) has the same invariance."""
    cfg, params, bits, prog = mini
    fwd = jax.jit(lambda xx: cnn_apply(cfg, params, xx))
    x = _images(8)
    crowd = np.asarray(fwd(jnp.asarray(x)))
    padded = np.zeros_like(x)
    padded[0] = x[0]
    np.testing.assert_array_equal(crowd[0], np.asarray(fwd(padded))[0])


def test_classify_alone_equals_classify_in_crowd(mini):
    """End to end through the service: one request served by itself gets
    bit-identical logits to the same request served inside a full batch
    (both run at the fixed batch_slots shape)."""
    cfg, params, bits, prog = mini
    x = _images(8)
    svc = InferenceService(prog, batch_slots=8, backend="xla")
    alone = [ClassifyRequest(image=x[0])]
    svc.serve(alone)
    crowd = [ClassifyRequest(image=img) for img in x]
    svc.serve(crowd)
    np.testing.assert_array_equal(alone[0].logits, crowd[0].logits)
    assert alone[0].label == crowd[0].label


def test_cross_shape_difference_is_fp32_noise(mini):
    """Different *shapes* (not compositions) may re-fuse reductions; the
    drift must stay at fp32 noise.  The service never changes shape, so
    this bound never reaches a served request."""
    cfg, params, bits, prog = mini
    x = _images(8)
    full = np.asarray(make_forward(prog, backend="xla")(jnp.asarray(x)))
    small = np.asarray(make_forward(prog, backend="xla")(jnp.asarray(x[:3])))
    np.testing.assert_allclose(small, full[:3], rtol=1e-5, atol=1e-6)


def test_sharded_composition_independence(mini):
    """The mesh path (1x1 mesh runs everywhere) keeps the invariance to
    fp32 tolerance; with one device it is bit-exact."""
    from repro.launch.mesh import make_mesh

    cfg, params, bits, prog = mini
    mesh = make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    fwd = make_forward(prog, backend="xla", mesh=mesh)
    x = _images(8)
    crowd = np.asarray(fwd(jnp.asarray(x)))
    padded = np.zeros_like(x)
    padded[0] = x[0]
    dead = np.asarray(fwd(jnp.asarray(padded)))
    np.testing.assert_allclose(dead[0], crowd[0], rtol=1e-6, atol=1e-6)
    if len(jax.devices()) == 1:
        np.testing.assert_array_equal(dead[0], crowd[0])


# ------------------------------------------------- scheduler-driven service


def test_bursty_trace_single_trace_exact_stats(mini):
    """A bursty 100-request trace through batch_slots=8: the forward is
    traced exactly once, every request completes, and the accumulated
    skip statistics equal a one-shot stats forward over the same images
    (dead slots contribute neither counts nor windows)."""
    cfg, params, bits, prog = mini
    svc = InferenceService(prog, batch_slots=8, backend="xla",
                           collect_stats=True)
    images = _images(100, seed=3)
    reqs = [ClassifyRequest(image=img) for img in images]
    # bursty arrivals: uneven burst sizes interleaved with service steps,
    # so batches run at many different occupancies
    bursts = [1, 7, 19, 2, 30, 5, 11, 3, 22]
    assert sum(bursts) == 100
    it = iter(reqs)
    for burst in bursts:
        for _ in range(burst):
            svc.submit(next(it))
        svc.step()
    svc.run()

    assert all(r.done for r in reqs)
    assert svc.trace_count() == 1
    assert svc.batches_run >= int(np.ceil(100 / 8))
    assert svc.metrics["completed"] == 100
    assert 0.0 < svc.metrics["occupancy_mean"] <= 1.0

    ref_logits, ref_stats = make_forward(
        prog, backend="xla", collect_stats=True
    )(jnp.asarray(images))
    for name, st in ref_stats.layers.items():
        got = svc.activation_stats.layers[name]
        assert got.windows == st.windows
        np.testing.assert_array_equal(got.counts, st.counts)
    # and every request's logits are bit-identical to the one-shot rows?
    # no — the one-shot pass runs at shape 100; the service guarantee is
    # label/logit stability at its own fixed shape, checked to tolerance:
    np.testing.assert_allclose(
        np.stack([r.logits for r in reqs]), np.asarray(ref_logits),
        rtol=1e-5, atol=1e-6,
    )


def test_stats_windows_exclude_dead_slots(mini):
    """3 requests through 8 slots: windows count 3 images, not 8, and the
    all-zero dead rows add no (vacuously skippable) counts."""
    cfg, params, bits, prog = mini
    svc = InferenceService(prog, batch_slots=8, backend="xla",
                           collect_stats=True)
    images = _images(3, seed=11)
    svc.serve([ClassifyRequest(image=img) for img in images])
    assert svc.batches_run == 1
    _, ref = make_forward(prog, backend="xla", collect_stats=True)(
        jnp.asarray(images)
    )
    for name, st in ref.layers.items():
        got = svc.activation_stats.layers[name]
        assert got.windows == st.windows  # 3 * H * W, not 8 * H * W
        np.testing.assert_array_equal(got.counts, st.counts)


def test_serve_validates_all_shapes_up_front(mini):
    """One malformed request rejects the whole serve() before any batch
    runs: nothing is half-served."""
    cfg, params, bits, prog = mini
    svc = InferenceService(prog, batch_slots=4, backend="xla")
    good = _images(5)
    reqs = [ClassifyRequest(image=img) for img in good]
    reqs.insert(3, ClassifyRequest(image=np.zeros((1, 5, 5), np.float32)))
    with pytest.raises(ValueError, match="request image"):
        svc.serve(reqs)
    assert svc.batches_run == 0
    assert not any(r.done for r in reqs)
    assert not svc.scheduler.has_work()
    # submit() validates too
    with pytest.raises(ValueError, match="request image"):
        svc.submit(ClassifyRequest(image=np.zeros((2, 2), np.float32)))


def test_submit_backpressure_and_drain(mini):
    cfg, params, bits, prog = mini
    svc = InferenceService(prog, batch_slots=2, backend="xla", max_queue=3)
    imgs = _images(8, seed=13)
    for img in imgs[:3]:
        svc.submit(ClassifyRequest(image=img))
    with pytest.raises(SchedulerFull):
        svc.submit(ClassifyRequest(image=imgs[3]))
    assert svc.metrics["rejected"] == 1
    done = svc.run()
    assert len(done) == 3 and all(r.done for r in done)
    # serve() interleaves submission with serving, so a one-shot batch
    # larger than queue + slots still drains through a bounded queue —
    # and its internal backpressure waits are not counted as rejections
    reqs = [ClassifyRequest(image=img) for img in imgs]
    svc.serve(reqs)
    assert all(r.done for r in reqs)
    assert svc.metrics["rejected"] == 1  # only the explicit submit() above


def test_trace_count_retraces_on_new_shape(mini):
    cfg, params, bits, prog = mini
    fwd = make_forward(prog, backend="xla")
    assert fwd.trace_count() == 0
    fwd(jnp.asarray(_images(4)))
    fwd(jnp.asarray(_images(4, seed=7)))
    assert fwd.trace_count() == 1  # same shape: no retrace
    fwd(jnp.asarray(_images(2)))
    assert fwd.trace_count() == 2  # new shape: one retrace


# ------------------------------------------------------------ observability


def test_traced_service_keeps_single_trace_and_emits_lifecycles(mini):
    """Tracing is host-side only: a traced service still hits exactly one
    jitted trace, while every request lands as an async lifecycle and
    each executed batch as a serve-category step span."""
    from repro.obs import Tracer

    cfg, params, bits, prog = mini
    tr = Tracer()
    svc = InferenceService(prog, batch_slots=4, backend="xla",
                           collect_stats=True, tracer=tr)
    images = _images(10, seed=21)
    reqs = [ClassifyRequest(image=img) for img in images]
    svc.serve(reqs)
    assert svc.trace_count() == 1
    assert all(r.done for r in reqs)
    ev = tr.events()
    begins = [e for e in ev if e["ph"] == "b" and e["cat"] == "request"]
    ends = [e for e in ev if e["ph"] == "e" and e["cat"] == "request"]
    assert len(begins) == 10 and len(ends) == 10
    steps = [e for e in ev
             if e["ph"] == "X" and e["name"] == "service.step"]
    assert len(steps) == svc.batches_run
    assert all(e["cat"] == "serve" for e in steps)
    # an untraced service's logits are bit-identical: same forward path
    svc2 = InferenceService(prog, batch_slots=4, backend="xla")
    reqs2 = [ClassifyRequest(image=img) for img in images]
    svc2.serve(reqs2)
    np.testing.assert_array_equal(
        np.stack([r.logits for r in reqs]),
        np.stack([r.logits for r in reqs2]),
    )
    # Prometheus text exposition comes straight off the scheduler metrics
    text = svc.metrics_text()
    assert "engine_service_completed_total 10" in text
    assert "engine_service_latency_seconds_count 10" in text


def test_instrumented_forward_matches_and_reports_drift(mini):
    """The per-layer instrumented forward computes the same logits as the
    jitted path, exposes per-layer mean wall-times, and those feed the
    hardware report's predicted-vs-measured drift section."""
    from repro.obs import Tracer

    cfg, params, bits, prog = mini
    x = jnp.asarray(_images(4, seed=2))
    plain = make_forward(prog, backend="xla")
    tr = Tracer()
    traced = make_forward(prog, backend="xla", tracer=tr)
    np.testing.assert_allclose(
        np.asarray(traced(x)), np.asarray(plain(x)), rtol=1e-5, atol=1e-6
    )
    # the instrumented path never touched the jitted function
    assert traced.trace_count() == 0
    times = traced.observed_times()
    layer_names = [c.name for c in prog.convs] + ["fc"]
    assert set(times) == set(layer_names)
    assert all(v > 0 for v in times.values())
    spans = [s.name for s in tr.spans("execute")]
    assert "forward" in spans
    assert {f"layer:{c.name}" for c in prog.convs} <= set(spans)

    rep = prog.hardware_report(observed=times)
    drift = rep["drift"]
    rows = {r["name"]: r for r in drift["layers"]}
    assert set(rows) == {c.name for c in prog.convs}  # fc: no cycle model
    assert drift["unpredicted"] == ["fc"]
    for r in rows.values():
        assert r["share_drift"] == pytest.approx(
            r["measured_share"] - r["predicted_share"]
        )
    # shares each sum to 1 over the compared layers
    assert sum(r["predicted_share"] for r in rows.values()) == (
        pytest.approx(1.0)
    )
    assert sum(r["measured_share"] for r in rows.values()) == (
        pytest.approx(1.0)
    )
    assert drift["rate_spread"] >= 1.0
    # no observations -> no drift section
    assert "drift" not in prog.hardware_report()


def test_compile_tracer_records_phases_without_changing_output(mini):
    from repro.obs import Tracer

    cfg, params, bits, prog = mini
    tr = Tracer()
    prog_tr = compile_network(cfg, params, bits, tracer=tr)
    names = [s.name for s in tr.spans("compile")]
    assert "compile_network" in names
    assert {"prune", "reorder", "pack"} <= set(names)
    assert {f"lower:{c.name}" for c in prog.convs} <= set(names)
    x = jnp.asarray(_images(2, seed=1))
    np.testing.assert_array_equal(
        np.asarray(make_forward(prog_tr, backend="xla")(x)),
        np.asarray(make_forward(prog, backend="xla")(x)),
    )


# --------------------------------------------------------- execute() cache


def test_execute_cache_capped_and_value_keyed(mini):
    """The per-program forward cache is bounded and keys meshes by value
    (axis names + device ids), not object identity."""
    from repro.engine.executor import _FORWARD_CACHE_MAX
    from repro.launch.mesh import make_mesh

    cfg, params, bits, prog = mini
    prog = compile_network(cfg, params, bits)  # fresh cache
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 12, 12))
    for bm in (8, 16, 24, 32, 40, 48, 56, 64, 72, 80):
        execute(prog, x, backend="xla", bm=bm)
    cache = prog.__dict__["_forward_cache"]
    assert len(cache) == _FORWARD_CACHE_MAX

    # two equal meshes share one entry (jax may intern Mesh objects, so
    # also check the key builder ignores object identity outright)
    from repro.engine.executor import _dispatch_key

    prog2 = compile_network(cfg, params, bits)
    m1 = make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    m2 = make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    y1 = execute(prog2, x, backend="xla", mesh=m1)
    y2 = execute(prog2, x, backend="xla", mesh=m2)
    assert len(prog2.__dict__["_forward_cache"]) == 1
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    class _MeshView:  # same axes/devices, distinct wrapper objects
        def __init__(self, mesh):
            self.axis_names = tuple(mesh.axis_names)
            self.devices = np.array(mesh.devices)

    k1 = _dispatch_key("xla", None, None, _MeshView(m1), None)
    k2 = _dispatch_key("xla", None, None, _MeshView(m1), None)
    assert k1 == k2 and hash(k1) == hash(k2)

    # LRU: re-touching an old entry keeps it alive past new insertions
    prog3 = compile_network(cfg, params, bits)
    for bm in (8, 16):
        execute(prog3, x, backend="xla", bm=bm)
    execute(prog3, x, backend="xla", bm=8)  # touch
    for bm in (24, 32, 40, 48, 56, 64, 72):
        execute(prog3, x, backend="xla", bm=bm)
    keys = list(prog3.__dict__["_forward_cache"])
    assert any(k[2] == 8 for k in keys)  # touched entry survived
    assert not any(k[2] == 16 for k in keys)  # untouched one evicted
