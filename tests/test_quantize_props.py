"""Hypothesis properties of the int quantization scheme (core/quantize).

Deterministic counterparts of these checks run in ``tests/test_quantize.py``
so environments without hypothesis still cover the bounds; this module
fuzzes the same invariants across seeds, magnitudes and cell widths.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.quantize import (
    QMAX,
    cell_slices,
    compose_cell_slices,
    dequantize_groups,
    group_scales,
    n_cell_slices,
    quantize_bp,
    quantize_groups,
)
from repro.core.sparse import build_block_pattern, nonzero_block_masks


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale_pow=st.integers(-6, 6))
def test_quantize_dequantize_error_bounded_by_group_scale(seed, scale_pow):
    """|w - s*q| <= s/2 elementwise, per group (round-to-nearest bound)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(3, 4, 8, 8)).astype(np.float32) * 10.0**scale_pow
    w[0, 0] = 0.0  # an all-zero group must survive (scale 0, exact)
    scales = group_scales(w, group_ndim=2)
    q = quantize_groups(w, scales, group_ndim=2)
    back = dequantize_groups(q, scales, group_ndim=2)
    bound = scales[:, :, None, None] / 2 * (1 + 1e-5) + 1e-30
    assert (np.abs(back - w) <= bound).all()
    assert np.abs(q).max() <= QMAX


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cell_bits=st.integers(2, 8))
def test_cell_slices_roundtrip(seed, cell_bits):
    """Sign-magnitude cell decomposition is lossless and fits the cells."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-QMAX, QMAX + 1, size=(5, 7), dtype=np.int8)
    s = cell_slices(q, cell_bits)
    assert s.shape == q.shape + (n_cell_slices(cell_bits),)
    assert s.max() < 2**cell_bits
    np.testing.assert_array_equal(compose_cell_slices(s, cell_bits), q)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    cell_bits=st.sampled_from([3, 5, 7]),
)
def test_cell_slices_roundtrip_nondividing_agrees_with_verifier(
    seed, cell_bits
):
    """Non-dividing cell widths (narrow top slice, offset sign bit):
    the round trip is lossless and ``verify_bp`` raises no V113/V114 on
    the quantized operand at the same width."""
    from repro.analysis.verify import verify_bp
    from repro.core.sparse import build_block_pattern, nonzero_block_masks

    rng = np.random.default_rng(seed)
    q = rng.integers(-QMAX, QMAX + 1, size=(4, 9), dtype=np.int8)
    s = cell_slices(q, cell_bits)
    assert s.max() < 2**cell_bits
    np.testing.assert_array_equal(compose_cell_slices(s, cell_bits), q)

    w = rng.normal(size=(48, 32)).astype(np.float32)
    w[rng.random(w.shape) < 0.6] = 0.0
    bp = build_block_pattern(
        w, block=16, tile=8, masks=nonzero_block_masks(w, 16)
    )
    report = verify_bp(quantize_bp(bp), layer="conv", cell_bits=cell_bits)
    assert not {"V113", "V114"} & report.rules(), report.format()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantized_bp_dense_within_bound(seed):
    """dense() of a quantized weight errs at most scale/2 per element."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(64, 48)).astype(np.float32)
    w[rng.random(w.shape) < 0.5] = 0.0
    bp = build_block_pattern(w, block=16, tile=8, masks=nonzero_block_masks(w, 16))
    qbp = quantize_bp(bp)
    assert qbp.precision == "int8"
    err = np.abs(np.asarray(qbp.dense()) - np.asarray(bp.dense()))
    max_scale = float(np.asarray(qbp.w_scales).max())
    assert err.max() <= max_scale / 2 * (1 + 1e-5)
