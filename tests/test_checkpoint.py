"""Checkpointer: atomicity, retention, async, elastic restore."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    Checkpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(10), "c": jnp.float32(3.5)},
        "list": [jnp.ones((2,)), jnp.zeros((3,))],
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    out = restore_checkpoint(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_partial_write_ignored(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crashed writer: a .tmp dir and a final dir missing manifest
    os.makedirs(tmp_path / "step_0000000002.tmp")
    os.makedirs(tmp_path / "step_0000000003")
    assert latest_step(str(tmp_path)) == 1


def test_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path)
        if n.startswith("step_")
    )
    assert steps == [3, 4]


def test_async_checkpointer(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3, async_save=True)
    tree = _tree()
    for s in (1, 2, 3):
        ck.save(s, tree)
    ck.wait()
    assert ck.latest_step() == 3
    out = ck.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    ck.close()


def test_elastic_restore_new_sharding(tmp_path):
    """Restore under a different device layout (elastic re-mesh): the
    checkpoint stores full arrays; restore device_puts per-leaf with target
    shardings — here simply a different (single) device placement."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree)
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree
    )
    out = restore_checkpoint(str(tmp_path), 7, tree, shardings=shardings)
    assert all(
        leaf.sharding == jax.sharding.SingleDeviceSharding(jax.devices()[0])
        for leaf in jax.tree.leaves(out)
    )


def test_restore_shape_mismatch_raises(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    bad = dict(tree)
    bad["a"] = jnp.zeros((5, 8))
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(str(tmp_path), 1, bad)
