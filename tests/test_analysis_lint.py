"""Trace-safety lint: each rule fires on a synthetic fixture, stays
silent on the compliant variant, honours ``# lint: allow(...)``, and the
real ``src/repro`` tree is clean (the CI contract)."""

import os
import textwrap

import pytest

from repro.analysis.lint import lint_paths

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _lint(tmp_path, source, name="mod.py"):
    path = tmp_path / name
    path.write_text(textwrap.dedent(source))
    return lint_paths([str(path)])


# ---------------------------------------------------------------------------
# L001: jit-reachable impurity
# ---------------------------------------------------------------------------


def test_l001_wall_clock_in_jitted_function(tmp_path):
    report = _lint(tmp_path, """
        import time
        import jax

        def forward(x):
            t = time.time()  # frozen at trace time
            return x * t

        fn = jax.jit(forward)
    """)
    assert report.rules() == {"L001"}
    assert "time.time" in report.errors[0].message


def test_l001_through_call_graph(tmp_path):
    report = _lint(tmp_path, """
        import time
        import numpy as np
        import jax

        def helper(x):
            return x + np.random.default_rng(0).random()

        def forward(x):
            return helper(x)

        fn = jax.jit(forward)
    """)
    assert report.rules() == {"L001"}
    assert "np.random" in report.errors[0].message


def test_l001_factory_closure_is_reachable(tmp_path):
    report = _lint(tmp_path, """
        import time
        import jax

        def make_step(cfg):
            def step(x):
                return x * time.perf_counter()
            return step

        fn = jax.jit(make_step(None))
    """)
    assert report.rules() == {"L001"}


def test_l001_decorated_seed(tmp_path):
    report = _lint(tmp_path, """
        import functools
        import time
        import jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def forward(x, n):
            return x + time.monotonic()
    """)
    assert report.rules() == {"L001"}


def test_l001_unreachable_function_is_fine(tmp_path):
    report = _lint(tmp_path, """
        import time

        def host_only():
            return time.time()
    """)
    assert report.clean, report.format()


# ---------------------------------------------------------------------------
# L002: tracer defaults
# ---------------------------------------------------------------------------


def test_l002_tracer_without_default(tmp_path):
    report = _lint(tmp_path, """
        def compile_thing(x, tracer):
            return x
    """)
    assert report.rules() == {"L002"}


def test_l002_compliant_defaults(tmp_path):
    report = _lint(tmp_path, """
        from obs import NULL_TRACER

        def a(x, tracer=None):
            return x

        def b(x, tracer=NULL_TRACER):
            return x

        def _private(x, tracer):
            return x
    """)
    assert report.clean, report.format()


# ---------------------------------------------------------------------------
# L003: mutable defaults
# ---------------------------------------------------------------------------


def test_l003_mutable_literal_and_ctor(tmp_path):
    report = _lint(tmp_path, """
        def f(x, acc=[]):
            return acc

        def g(x, table=dict()):
            return table
    """)
    assert report.rules() == {"L003"}
    assert len(report.errors) == 2


def test_l003_nonfrozen_dataclass_default(tmp_path):
    report = _lint(tmp_path, """
        import dataclasses

        @dataclasses.dataclass
        class Mutable:
            n: int = 0

        @dataclasses.dataclass(frozen=True)
        class Frozen:
            n: int = 0

        def bad(cfg=Mutable()):
            return cfg

        def good(cfg=Frozen()):
            return cfg
    """)
    assert report.rules() == {"L003"}
    assert len(report.errors) == 1
    assert "Mutable" in report.errors[0].message


def test_l003_immutable_defaults_are_fine(tmp_path):
    report = _lint(tmp_path, """
        def f(x, pair=(1, 2), name="a", bits=frozenset({1})):
            return x
    """)
    assert report.clean, report.format()


# ---------------------------------------------------------------------------
# L004: unsynchronized timing
# ---------------------------------------------------------------------------


def test_l004_times_dispatch_not_execution(tmp_path):
    report = _lint(tmp_path, """
        import time
        import jax.numpy as jnp
        import jax

        def bench(x):
            t0 = time.perf_counter()
            y = jax.device_put(x)
            return time.perf_counter() - t0
    """)
    assert report.rules() == {"L004"}


def test_l004_block_until_ready_passes(tmp_path):
    report = _lint(tmp_path, """
        import time
        import jax

        def bench(x):
            t0 = time.perf_counter()
            y = jax.device_put(x)
            jax.block_until_ready(y)
            return time.perf_counter() - t0
    """)
    assert report.clean, report.format()


def test_l004_jax_work_outside_timed_region_passes(tmp_path):
    report = _lint(tmp_path, """
        import time
        import jax

        def bench(x):
            key = jax.random.PRNGKey(0)  # before the timed region
            t0 = time.time()
            host_work(key)
            return time.time() - t0
    """)
    assert report.clean, report.format()


def test_l005_deprecated_serving_imports(tmp_path):
    report = _lint(tmp_path, """
        from repro.engine.service import ClassifyRequest
        from repro.runtime.serve import Request
    """)
    assert report.rules() == {"L005"}
    assert len(report.diagnostics) == 2


def test_l005_unified_and_unrelated_imports_are_fine(tmp_path):
    report = _lint(tmp_path, """
        from repro.serve import Request
        from repro.engine.service import InferenceService
        from repro.runtime.serve import ServeConfig, ServeLoop
    """)
    assert report.clean, report.format()


def test_l005_allow_comment_for_backcompat_reexport(tmp_path):
    report = _lint(tmp_path, """
        from repro.engine.service import ClassifyRequest  # lint: allow(L005)
    """)
    assert report.clean, report.format()


# ---------------------------------------------------------------------------
# L006: lock discipline
# ---------------------------------------------------------------------------


def test_l006_unlocked_mutation_fires(tmp_path):
    report = _lint(tmp_path, """
        import threading

        class Registry:
            def __init__(self):
                self._lock = threading.Lock()
                self._items = {}
                self._count = 0

            def add(self, key, value):
                self._items[key] = value  # subscript store, unlocked
                self._count += 1          # augmented assign, unlocked
    """)
    assert report.rules() == {"L006"}
    assert len(report.diagnostics) == 2
    assert "Registry.add()" in report.diagnostics[0].message


def test_l006_locked_mutation_is_fine(tmp_path):
    report = _lint(tmp_path, """
        import threading

        class Registry:
            def __init__(self):
                self._lock = threading.RLock()
                self._items = {}

            def add(self, key, value):
                with self._lock:
                    self._items[key] = value

            def get(self, key):
                return self._items.get(key)  # reads are not flagged
    """)
    assert report.clean, report.format()


def test_l006_from_import_and_unlocked_delete(tmp_path):
    report = _lint(tmp_path, """
        from threading import Lock

        class Cache:
            def __init__(self):
                self._mu = Lock()
                self._data = {}

            def evict(self, key):
                del self._data[key]
    """)
    assert report.rules() == {"L006"}


def test_l006_lockless_class_and_init_are_exempt(tmp_path):
    report = _lint(tmp_path, """
        import threading

        class Plain:
            def __init__(self):
                self._items = {}

            def add(self, k, v):
                self._items[k] = v  # no lock attribute: not in scope

        class Locked:
            def __init__(self):
                self._lock = threading.Lock()
                self._items = {}
                self._items["seed"] = 0  # __init__ is pre-publication
    """)
    assert report.clean, report.format()


def test_l006_nested_def_is_skipped(tmp_path):
    # a closure's execution context is unknown (it may run after the
    # lock is released, or under it) — neither flagged nor excused
    report = _lint(tmp_path, """
        import threading

        class Worker:
            def __init__(self):
                self._lock = threading.Lock()
                self._state = 0

            def schedule(self):
                def callback():
                    self._state = 1
                return callback
    """)
    assert report.clean, report.format()


def test_l006_allow_comment_suppresses(tmp_path):
    report = _lint(tmp_path, """
        import threading

        class Snapshot:
            def __init__(self):
                self._lock = threading.Lock()
                self._frozen = None

            def publish(self, value):
                self._frozen = value  # lint: allow(L006)
    """)
    assert report.clean, report.format()


# ---------------------------------------------------------------------------
# suppression + CLI + the real tree
# ---------------------------------------------------------------------------


def test_allow_comment_suppresses(tmp_path):
    report = _lint(tmp_path, """
        import time
        import jax

        def bench(x):
            t0 = time.time()  # lint: allow(L004)
            y = jax.device_put(x)
            return time.time() - t0
    """)
    assert report.clean, report.format()


def test_allow_comment_is_rule_specific(tmp_path):
    report = _lint(tmp_path, """
        import time
        import jax

        def bench(x):
            t0 = time.time()  # lint: allow(L001)
            y = jax.device_put(x)
            return time.time() - t0
    """)
    assert report.rules() == {"L004"}


def test_cli_lint(tmp_path, capsys):
    from repro.analysis.__main__ import main

    bad = tmp_path / "bad.py"
    bad.write_text("def f(x, acc=[]):\n    return acc\n")
    assert main(["lint", str(bad)]) == 1
    assert "L003" in capsys.readouterr().out
    good = tmp_path / "good.py"
    good.write_text("def f(x):\n    return x\n")
    assert main(["lint", str(good)]) == 0


def test_repo_source_tree_is_clean():
    report = lint_paths([REPO_SRC])
    assert report.clean, report.format()


def test_repo_lint_actually_reaches_the_jitted_forward():
    # guard against the lint silently losing its seeds: the executor's
    # jitted forward and the pallas kernels must be in the reachable set
    from repro.analysis import lint as L

    mods = L._parse([REPO_SRC])
    by = {m.name: m for m in mods}
    for m in mods:
        for k in (m.name.removeprefix("repro."), m.name.split(".")[-1]):
            by.setdefault(k, m)
    seeds = L._collect_seeds(mods, by)
    reachable = L._reachable(mods, by, seeds)
    assert "repro.engine.executor::make_forward.forward" in seeds
    assert any(s.startswith("repro.kernels.pattern_spmm::") for s in seeds)
    assert len(reachable) >= len(seeds)
