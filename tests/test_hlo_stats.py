"""Loop-aware HLO statistics parser — validated against known programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_stats import parse_hlo_stats


def _stats(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return parse_hlo_stats(txt)


def test_plain_matmul_flops():
    x = jnp.zeros((64, 128))
    w = jnp.zeros((128, 256))
    s = _stats(lambda a, b: a @ b, x, w)
    assert s.flops == pytest.approx(2 * 64 * 128 * 256, rel=0.01)


def test_scan_multiplies_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.zeros((128, 256))
    ws = jnp.zeros((10, 256, 256))
    s = _stats(f, x, ws)
    assert s.flops == pytest.approx(2 * 128 * 256 * 256 * 10, rel=0.01)
    assert s.while_trips == [10]


def test_nested_scans():
    def g(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jnp.zeros((128, 256))
    ws = jnp.zeros((4, 256, 256))
    s = _stats(g, x, ws)
    assert s.flops == pytest.approx(2 * 128 * 256 * 256 * 20, rel=0.01)
    assert sorted(s.while_trips) == [4, 5]


def test_grad_counts_forward_and_backward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    x = jnp.zeros((64, 128))
    w = jnp.zeros((128, 256))
    s = _stats(jax.grad(loss), w, x)
    # grad wrt w only: fwd dot (needed for 2(xw)) + one bwd dot = 2x
    one = 2 * 64 * 128 * 256
    assert s.flops >= 2 * one * 0.99
    assert s.flops <= 3 * one


def test_entry_params_counted_in_bytes():
    x = jnp.zeros((1024, 1024))  # 4MB fp32
    s = _stats(lambda a: a * 2.0, x)
    assert s.bytes >= 4 * 1024 * 1024
