"""GPipe pipeline parallelism — numerical parity with sequential fold."""

import json
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_pipeline_matches_sequential():
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, json
    from repro.launch.mesh import make_mesh
    from repro.parallel.pipeline import pipeline_apply

    mesh = make_mesh((4,), ('stage',))
    L, D = 8, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3
    def layer(w, x):
        return jnp.tanh(x @ w)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 2, D))
    y_pipe = pipeline_apply(layer, ws, x, mesh, 'stage')
    def ref_one(xm):
        for i in range(L):
            xm = layer(ws[i], xm)
        return xm
    y_ref = jax.vmap(ref_one)(x)
    print(json.dumps({'err': float(jnp.abs(y_pipe - y_ref).max())}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-6
