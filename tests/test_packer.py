"""Packing invariants for core/mapping._Packer (no hypothesis needed).

Exercises the strip-widening path of ``_Packer.place`` (a later block wider
than the current strip) by using small crossbars and orders that mix block
widths, and checks that for every ``block_order`` mode:

  * placements never overlap within a crossbar and stay in bounds,
  * cells_used + cells_wasted <= cells_total.
"""

import numpy as np
import pytest

from repro.core.mapping import CrossbarConfig, map_layer


def _random_bits(rng, co, ci, n_pat=5, zero_frac=0.3, k=9):
    pats = [0]
    while len(pats) < n_pat + 1:
        b = int(rng.integers(1, 2**k))
        if b not in pats:
            pats.append(b)
    probs = np.full(n_pat + 1, (1 - zero_frac) / n_pat)
    probs[0] = zero_frac
    choice = rng.choice(len(pats), size=(co, ci), p=probs)
    return np.array(pats)[choice]


CONFIGS = [
    CrossbarConfig(),  # paper geometry
    CrossbarConfig(rows=64, cols=64, cells_per_weight=4),  # forces splits
    CrossbarConfig(rows=32, cols=128, cells_per_weight=2),
]


@pytest.mark.parametrize("order", ["pattern", "channel", "width"])
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("config", CONFIGS, ids=["paper", "tiny", "wide"])
def test_packer_invariants(order, seed, config):
    rng = np.random.default_rng(seed)
    co, ci = int(rng.integers(8, 48)), int(rng.integers(2, 12))
    bits = _random_bits(rng, co, ci)
    m = map_layer(bits, config, block_order=order)

    assert m.cells_used + m.cells_wasted <= m.cells_total
    assert m.utilization <= 1.0

    by_xbar: dict[int, list] = {}
    for p in m.placements:
        assert 0 <= p.crossbar < m.num_crossbars
        assert 0 <= p.row0 and p.row0 + p.height <= config.rows
        assert 0 <= p.col0 and p.col0 + p.width_cells <= config.cols
        assert p.width_cells == p.block.n_kernels * config.cells_per_weight
        by_xbar.setdefault(p.crossbar, []).append(p)

    for placements in by_xbar.values():
        for i, a in enumerate(placements):
            for b in placements[i + 1 :]:
                row_overlap = (a.row0 < b.row0 + b.height
                               and b.row0 < a.row0 + a.height)
                col_overlap = (a.col0 < b.col0 + b.width_cells
                               and b.col0 < a.col0 + a.width_cells)
                assert not (row_overlap and col_overlap), (
                    f"overlap on crossbar {a.crossbar}: {a} vs {b}"
                )


@pytest.mark.parametrize("order", ["pattern", "channel", "width"])
def test_packer_stores_every_nonzero_kernel(order):
    rng = np.random.default_rng(7)
    bits = _random_bits(rng, 24, 6)
    m = map_layer(bits, CrossbarConfig(rows=64, cols=64), block_order=order)
    placed = sum(p.block.n_kernels for p in m.placements)
    assert placed == m.stored_kernels == int((bits != 0).sum())
