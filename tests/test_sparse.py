"""Block-pattern sparse layer (TPU adaptation, DESIGN §3) — properties."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sparse import (
    block_density,
    build_block_pattern,
    pattern_spmm_xla,
)


def test_lossless_when_weight_conforms(rng):
    """If the dense weight already satisfies a <=P-mask block pattern, the
    build is an exact (lossless) re-layout — mirrors the paper's claim that
    mapping pattern-pruned weights loses nothing."""
    k, n, block, tile = 512, 512, 64, 64
    nb = k // block
    dict_masks = rng.random((3, nb)) < 0.4
    cols = rng.integers(0, 3, n)
    w = rng.normal(size=(k, n)).astype(np.float32)
    w *= np.repeat(dict_masks[cols].T, block, axis=0)
    bp = build_block_pattern(w, num_patterns=3, density=0.5, block=block,
                             tile=tile)
    np.testing.assert_allclose(np.asarray(bp.dense()), w, atol=0)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    num_patterns=st.integers(1, 6),
    density=st.floats(0.1, 0.9),
)
def test_projection_properties(seed, num_patterns, density):
    rng = np.random.default_rng(seed)
    k, n = 256, 256
    w = rng.normal(size=(k, n)).astype(np.float32)
    bp = build_block_pattern(w, num_patterns=num_patterns, density=density)
    # dictionary size respected
    assert bp.dict_masks.shape[0] <= num_patterns
    # permutation is a permutation
    assert sorted(bp.new_order.tolist()) == list(range(n))
    np.testing.assert_array_equal(bp.new_order[bp.inv_order], np.arange(n))
    # projection only zeroes (dense recon is a masked version of w)
    wd = np.asarray(bp.dense())
    mask = wd != 0
    np.testing.assert_allclose(wd[mask], w[mask], rtol=1e-6)
    assert 0.0 < block_density(bp) <= 1.0


def test_spmm_xla_grad_flows(rng):
    """The compressed weight is trainable: gradients flow through the
    gather/scan path (needed for projection-retraining)."""
    import jax

    k, n = 256, 256
    w = rng.normal(size=(k, n)).astype(np.float32)
    bp = build_block_pattern(w, num_patterns=3, density=0.4)
    x = jnp.asarray(rng.normal(size=(4, k)).astype(np.float32))

    def loss(w_comp):
        y = pattern_spmm_xla(x, w_comp, bp.block_ids, bp.block)
        return jnp.sum(y**2)

    g = jax.grad(loss)(bp.w_comp)
    assert g.shape == bp.w_comp.shape
    assert bool(jnp.any(g != 0))
    assert not bool(jnp.any(jnp.isnan(g)))


def test_flop_savings_accounting(rng):
    """block_density == compressed FLOPs / dense FLOPs (the roofline win)."""
    k, n = 512, 768
    w = rng.normal(size=(k, n)).astype(np.float32)
    bp = build_block_pattern(w, num_patterns=4, density=0.25)
    nb = k // bp.block
    dense_flops = 2 * k * n
    comp_flops = 2 * int(bp.nnz.sum()) * bp.block * bp.tile
    assert comp_flops / dense_flops == pytest.approx(block_density(bp))
    assert block_density(bp) < 0.7  # actually compresses
