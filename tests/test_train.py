"""Training runtime: convergence, fault tolerance, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticCorpus, packed_batches
from repro.models.transformer import init_params
from repro.optim import adamw
from repro.optim.compression import (
    compress_gradients,
    decompress_gradients,
    init_compression_state,
)
from repro.runtime.fault import FailureInjector, SimulatedFailure, \
    StragglerDetector
from repro.runtime.train import (
    TrainConfig,
    Trainer,
    init_train_state,
    make_train_step,
)


def _setup(tmp_path, steps=30, arch="granite_3_2b", **tkw):
    cfg = get_smoke_config(arch)
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    tcfg = TrainConfig(
        steps=steps, ckpt_every=10, ckpt_dir=str(tmp_path / "ckpt"), **tkw
    )
    step = make_train_step(cfg, statics, opt, lambda s: 2e-3, tcfg)
    state = init_train_state(params, opt, tcfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    return cfg, jax.jit(step), state, dcfg, tcfg


def test_loss_decreases(tmp_path):
    cfg, step, state, dcfg, tcfg = _setup(tmp_path, steps=30)
    batches = packed_batches(dcfg)
    trainer = Trainer(step, state, batches, tcfg)
    hist = trainer.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, f"no learning: {first:.3f} -> {last:.3f}"


def test_checkpoint_restart_bit_exact(tmp_path):
    """Crash at step 17, restore from step 10, rerun -> identical losses
    to an uninterrupted run (determinism incl. the data pipeline)."""
    # uninterrupted reference
    cfg, step, state, dcfg, tcfg = _setup(tmp_path / "a", steps=25)
    ref_hist = Trainer(step, state, packed_batches(dcfg), tcfg).run()

    # interrupted run — same seeds
    cfg, step, state, dcfg, tcfg = _setup(tmp_path / "b", steps=25)
    injector = FailureInjector({17: "node-failure"})
    tr = Trainer(step, state, packed_batches(dcfg), tcfg, injector=injector)
    with pytest.raises(SimulatedFailure):
        tr.run()
    # restart: fresh trainer, resume from latest checkpoint (step 10),
    # fresh data stream fast-forwarded to the restored step, as a real
    # deterministic loader does
    cfg, step, state2, dcfg, tcfg = _setup(tmp_path / "b", steps=25)
    batches = packed_batches(dcfg)
    tr2 = Trainer(step, state2, batches, tcfg, injector=FailureInjector())
    resumed = tr2.maybe_restore()
    assert resumed == 10
    for _ in range(resumed):
        next(batches)  # deterministic fast-forward
    hist2 = tr2.run()

    ref_tail = {h["step"]: h["loss"] for h in ref_hist if h["step"] >= 10}
    for h in hist2:
        assert h["loss"] == pytest.approx(ref_tail[h["step"]], rel=1e-6), (
            f"divergence at step {h['step']}"
        )


def test_straggler_detection():
    det = StragglerDetector(window=20, threshold=2.0)
    for i in range(10):
        det.record(i, 0.1)
    assert det.record(10, 0.5) is True
    assert det.record(11, 0.11) is False
    assert det.flagged and det.flagged[0][0] == 10


def test_grad_compression_error_feedback(rng):
    """int8 + error feedback: the *accumulated* applied gradient tracks the
    true gradient (residual stays bounded), unlike naive quantization."""
    g_true = jnp.asarray(rng.normal(size=(256,)) * 1e-3)
    state = init_compression_state({"g": g_true})
    applied = jnp.zeros_like(g_true)
    for _ in range(50):
        comp, state = compress_gradients({"g": g_true}, state)
        applied = applied + decompress_gradients(comp)["g"]
    # mean applied per step ~ g_true
    np.testing.assert_allclose(
        np.asarray(applied) / 50, np.asarray(g_true), atol=2e-6
    )


def test_grad_compression_training_parity(tmp_path):
    """Compressed training converges on the same task."""
    cfg, step, state, dcfg, tcfg = _setup(
        tmp_path, steps=30, grad_compression=True
    )
    hist = Trainer(step, state, packed_batches(dcfg), tcfg).run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1


def test_microbatching_matches_full_batch(tmp_path):
    """Gradient accumulation over 4 microbatches == one big batch (same
    data, same init) up to numerics."""
    cfg = get_smoke_config("granite_3_2b")
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(5), (8, 33), 0,
                                     cfg.vocab)
    }
    outs = {}
    for nmb in (1, 4):
        tcfg = TrainConfig(steps=1, microbatches=nmb)
        step = make_train_step(cfg, statics, opt, lambda s: 1e-2, tcfg)
        state = init_train_state(params, opt, tcfg)
        new_state, m = jax.jit(step)(state, batch)
        outs[nmb] = (m["loss"], new_state["params"])
    np.testing.assert_allclose(
        float(outs[1][0]), float(outs[4][0]), rtol=1e-5
    )
    deltas = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), outs[1][1], outs[4][1]
    )
    # Adam's first-step update is lr*g/(|g|+eps) — unit magnitude whatever
    # the gradient scale — so for weights whose gradient sits near the fp32
    # accumulation noise floor, the two summation orders (one 8-row backward
    # vs four 2-row backwards averaged) legitimately move the parameter by
    # a noise-directed fraction of lr, not of gradient precision.  The
    # losses above agree to 1e-5 rel; bound the post-optimizer drift at 5%
    # of one step (observed max ~1.9% of lr on CPU).
    lr = 1e-2
    assert max(jax.tree.leaves(deltas)) < 0.05 * lr
