"""CompileOptions: the one-object compile surface.

Pins the API-redesign contract: ``compile_network(options=...)`` is the
preferred form, the historical loose kwargs keep working as deprecated
aliases (warning, but compiling a *bit-identical* program), and the two
forms cannot be mixed.
"""

import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.engine import CompileOptions, EngineConfig, compile_network
from repro.models.cnn import conv_weight_names, init_cnn, mini_cnn_config
from repro.obs.trace import Tracer


@pytest.fixture(scope="module")
def mini():
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    return cfg, params, bits


def _bp_arrays(bp):
    arrs = [bp.w_comp, bp.block_ids, bp.nnz, bp.new_order, bp.inv_order]
    if bp.w_scales is not None:
        arrs.append(bp.w_scales)
    return [np.asarray(a) for a in arrs]


def assert_programs_identical(a, b):
    """Every stored operand of two compiled programs is bit-equal."""
    assert (a.block, a.tile, a.precision, a.cell_bits) == (
        b.block, b.tile, b.precision, b.cell_bits
    )
    assert len(a.convs) == len(b.convs)
    for ca, cb in zip(a.convs, b.convs):
        assert ca.name == cb.name
        np.testing.assert_array_equal(ca.bias, cb.bias)
        np.testing.assert_array_equal(ca.pattern_bits, cb.pattern_bits)
        for xa, xb in zip(_bp_arrays(ca.bp), _bp_arrays(cb.bp)):
            np.testing.assert_array_equal(xa, xb)
    np.testing.assert_array_equal(a.fc.bias, b.fc.bias)
    for xa, xb in zip(_bp_arrays(a.fc.bp), _bp_arrays(b.fc.bp)):
        np.testing.assert_array_equal(xa, xb)


def test_options_form_does_not_warn(mini):
    cfg, params, bits = mini
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        compile_network(cfg, params, bits, options=CompileOptions())
        compile_network(cfg, params, bits)  # bare call is not deprecated


@pytest.mark.parametrize(
    "kwargs, options",
    [
        (
            dict(ecfg=EngineConfig(block=16, tile=16)),
            CompileOptions(block=16, tile=16),
        ),
        (
            dict(precision="int8"),
            CompileOptions(precision="int8"),
        ),
        (
            dict(ecfg=EngineConfig(block=16, tile=16, cell_bits=2),
                 precision="int8", verify="strict"),
            CompileOptions(block=16, tile=16, cell_bits=2,
                           precision="int8", verify="strict"),
        ),
    ],
)
def test_kwargs_alias_round_trip_bit_identical(mini, kwargs, options):
    """Deprecated kwargs warn but compile the same bits as options=."""
    cfg, params, bits = mini
    with pytest.warns(DeprecationWarning, match="CompileOptions"):
        legacy = compile_network(cfg, params, bits, **kwargs)
    new = compile_network(cfg, params, bits, options=options)
    assert_programs_identical(legacy, new)


def test_positional_ecfg_slot_still_works(mini):
    """CI's analysis job passes EngineConfig in the 4th positional slot;
    that call shape must keep compiling (with a deprecation warning)."""
    cfg, params, bits = mini
    e = EngineConfig(block=16, tile=16)
    with pytest.warns(DeprecationWarning):
        prog = compile_network(cfg, params, bits, e, verify="strict")
    assert_programs_identical(
        prog,
        compile_network(
            cfg, params, bits,
            options=CompileOptions.from_engine_config(e, verify="strict"),
        ),
    )


def test_options_cannot_mix_with_legacy_kwargs(mini):
    cfg, params, bits = mini
    with pytest.raises(TypeError, match="deprecated kwarg"):
        compile_network(
            cfg, params, bits, precision="int8", options=CompileOptions()
        )


def test_options_validation():
    with pytest.raises(ValueError, match="precision"):
        CompileOptions(precision="fp16")
    with pytest.raises(ValueError, match="cell_bits"):
        CompileOptions(cell_bits=0)
    with pytest.raises(ValueError, match="verify"):
        CompileOptions(verify="bogus")
    with pytest.raises(ValueError, match="optimize"):
        CompileOptions(optimize=42)


def test_engine_config_projection_round_trips():
    e = EngineConfig(block=16, tile=32, precision="int8", cell_bits=2)
    opts = CompileOptions.from_engine_config(e, verify="warn")
    assert opts.engine_config() == e
    assert opts.verify == "warn"
    assert dataclasses.replace(opts, verify=None).engine_config() == e


def test_options_carry_tracer(mini):
    """The tracer rides inside options: compile spans land on it."""
    cfg, params, bits = mini
    tr = Tracer()
    compile_network(
        cfg, params, bits,
        options=CompileOptions(block=16, tile=16, tracer=tr),
    )
    names = {e["name"] for e in tr.events()}
    assert "compile_network" in names
    assert "lower:fc" in names
