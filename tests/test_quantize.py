"""Int-quantized 4-bit-cell execution path (core/quantize + engine wiring).

Covers the quantization math (deterministic error-bound and cell-slice
round-trip checks; the hypothesis fuzzing of the same invariants lives in
``tests/test_quantize_props.py``), the int8 kernel variants on both
backends, the end-to-end accuracy of a quantized compiled CNN against its
fp32 twin, and the cell-slice-derived hardware pricing.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.core.quantize import (
    QMAX,
    cell_slices,
    compose_cell_slices,
    dequantize_groups,
    group_scales,
    n_cell_slices,
    quantize_bp,
    quantize_groups,
    quantize_rows,
)
from repro.core.sparse import build_block_pattern, nonzero_block_masks
from repro.engine import EngineConfig, compile_network, make_forward
from repro.kernels.ops import pattern_spmm
from repro.models.cnn import (
    conv_weight_names,
    init_cnn,
    mini_cnn_config,
    vgg16_config,
)

BACKENDS = [("xla", None), ("pallas", True)]


def _pruned_net(cfg, seed=0, sparsity=0.7, num_patterns=4):
    params = init_cnn(cfg, jax.random.PRNGKey(seed))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, sparsity)
    dicts = build_dictionaries(params, names, num_patterns)
    return project_params(params, dicts)


@pytest.fixture(scope="module")
def mini_pair():
    """(cfg, fp32 program, int8 program) for the same pruned mini CNN."""
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params, bits = _pruned_net(cfg)
    prog = compile_network(cfg, params, bits)
    progq = compile_network(cfg, params, bits, precision="int8")
    return cfg, prog, progq


# ------------------------------------------------- deterministic bounds


@pytest.mark.parametrize("scale_pow", [-4, 0, 4])
def test_quantize_dequantize_error_bounded_by_group_scale(rng, scale_pow):
    """|w - s*q| <= s/2 elementwise, per group (round-to-nearest bound)."""
    w = rng.normal(size=(3, 4, 8, 8)).astype(np.float32) * 10.0**scale_pow
    w[0, 0] = 0.0  # an all-zero group must survive (scale 0, exact)
    scales = group_scales(w, group_ndim=2)
    q = quantize_groups(w, scales, group_ndim=2)
    back = dequantize_groups(q, scales, group_ndim=2)
    bound = scales[:, :, None, None] / 2 * (1 + 1e-5) + 1e-30
    assert (np.abs(back - w) <= bound).all()
    assert np.abs(q).max() <= QMAX


@pytest.mark.parametrize("cell_bits", [2, 3, 4, 5, 7, 8])
def test_cell_slices_roundtrip(rng, cell_bits):
    """Sign-magnitude cell decomposition is lossless and fits the cells."""
    q = rng.integers(-QMAX, QMAX + 1, size=(5, 7), dtype=np.int8)
    s = cell_slices(q, cell_bits)
    assert s.shape == q.shape + (n_cell_slices(cell_bits),)
    assert s.max() < 2**cell_bits
    np.testing.assert_array_equal(compose_cell_slices(s, cell_bits), q)


@pytest.mark.parametrize("cell_bits", [3, 5, 7])
def test_cell_slices_roundtrip_exhaustive_nondividing(cell_bits):
    """Non-dividing cell widths: bit-exact over the entire int8 domain.

    When ``cell_bits`` does not divide ``WEIGHT_BITS`` the top slice is
    narrower than the rest and carries the sign bit at an offset — the
    exact configuration the random round-trip can miss at the domain
    edges, so every representable value is checked.
    """
    q = np.arange(-QMAX, QMAX + 1, dtype=np.int8)
    s = cell_slices(q, cell_bits)
    assert s.max() < 2**cell_bits
    np.testing.assert_array_equal(compose_cell_slices(s, cell_bits), q)


@pytest.mark.parametrize("cell_bits", [3, 5, 7])
def test_verifier_cell_slice_agreement_nondividing(rng, cell_bits):
    """verify_bp's V114 round-trip check agrees with the quantizer at
    non-dividing cell widths: a healthy operand is silent, and an
    unrepresentable stored value (-128) trips both V113 and V114."""
    from repro.analysis.verify import verify_bp

    w = rng.normal(size=(64, 48)).astype(np.float32)
    w[rng.random(w.shape) < 0.5] = 0.0
    bp = build_block_pattern(w, block=16, tile=8, masks=nonzero_block_masks(w, 16))
    qbp = quantize_bp(bp)
    report = verify_bp(qbp, layer="conv", cell_bits=cell_bits)
    assert report.ok, report.format()
    assert not {"V113", "V114"} & report.rules()

    w_comp = np.asarray(qbp.w_comp).copy()
    w_comp[0, 0, 0, 0] = -128  # |q| > QMAX never survives the slice trip
    broken = dataclasses.replace(qbp, w_comp=w_comp)
    report = verify_bp(broken, layer="conv", cell_bits=cell_bits)
    assert {"V113", "V114"} <= report.rules(), report.format()


def test_quantized_bp_dense_within_bound(rng):
    """dense() of a quantized weight errs at most scale/2 per element."""
    w = rng.normal(size=(64, 48)).astype(np.float32)
    w[rng.random(w.shape) < 0.5] = 0.0
    bp = build_block_pattern(w, block=16, tile=8, masks=nonzero_block_masks(w, 16))
    qbp = quantize_bp(bp)
    assert qbp.precision == "int8"
    assert np.asarray(qbp.w_comp).dtype == np.int8
    err = np.abs(np.asarray(qbp.dense()) - np.asarray(bp.dense()))
    max_scale = float(np.asarray(qbp.w_scales).max())
    assert err.max() <= max_scale / 2 * (1 + 1e-5)


def test_quantize_rows_bounds(rng):
    x = rng.normal(size=(6, 32)).astype(np.float32)
    x[2] = 0.0
    q, s = quantize_rows(x)
    back = np.asarray(q, np.float32) * np.asarray(s)[:, None]
    bound = np.asarray(s)[:, None] / 2 + 1e-30
    assert (np.abs(back - x) <= bound).all()
    assert np.asarray(q)[2].tolist() == [0] * 32


# ---------------------------------------------------------------- kernels


def test_quant_spmm_backends_agree_bitwise(rng):
    """XLA scan and Pallas (interpret) int8 variants produce identical
    fp32 outputs for the same quantized operands."""
    import jax.numpy as jnp

    w = rng.normal(size=(64, 48)).astype(np.float32)
    w[rng.random(w.shape) < 0.6] = 0.0
    bp = build_block_pattern(w, block=16, tile=8, masks=nonzero_block_masks(w, 16))
    qbp = quantize_bp(bp)
    x = jnp.asarray(rng.normal(size=(5, 64)).astype(np.float32))
    y_xla = np.asarray(pattern_spmm(x, qbp, backend="xla"))
    y_pal = np.asarray(pattern_spmm(x, qbp, backend="pallas", interpret=True))
    np.testing.assert_array_equal(y_xla, y_pal)
    # and both stay within the composed quantization bound of the exact y
    y_ref = np.asarray(x) @ w
    denom = np.abs(y_ref).max()
    assert np.abs(y_xla - y_ref).max() / denom < 0.05


# ------------------------------------------------------------- end-to-end


@pytest.mark.parametrize("backend,interpret", BACKENDS)
def test_quantized_forward_agrees_with_fp32(mini_pair, backend, interpret):
    """Quantized forward: >= 99% top-1 agreement with the fp32 engine on a
    synthetic eval batch, logits within a small relative bound."""
    cfg, prog, progq = mini_pair
    x = jax.random.normal(jax.random.PRNGKey(5), (256, 1, 12, 12))
    ref = np.asarray(make_forward(prog, backend="xla")(x))
    out = np.asarray(make_forward(progq, backend=backend, interpret=interpret)(x))
    agree = (out.argmax(-1) == ref.argmax(-1)).mean()
    assert agree >= 0.99
    rel = np.abs(out - ref).max() / max(np.abs(ref).max(), 1e-9)
    assert rel < 0.05


def test_quantized_program_metadata(mini_pair):
    cfg, prog, progq = mini_pair
    assert prog.precision == "fp32" and prog.cells_per_weight is None
    assert progq.precision == "int8" and progq.cells_per_weight == 2
    for op in [*progq.convs, progq.fc]:
        assert np.asarray(op.bp.w_comp).dtype == np.int8
        assert op.bp.w_scales is not None
        assert op.bp.precision == "int8"
    # int8 storage is ~4x smaller than the fp32 payload (plus scales)
    comp_fp, dense = prog.weight_bytes()
    comp_q, dense_q = progq.weight_bytes()
    assert dense_q == dense
    assert comp_q < comp_fp / 2


def test_hardware_report_prices_stored_cell_slices(mini_pair):
    """int8 programs price area from the actual 2-slice storage; fp32
    programs keep the crossbar model's assumed width."""
    cfg, prog, progq = mini_pair
    rep, repq = prog.hardware_report(), progq.hardware_report()
    assert rep["precision"] == {
        "weights": "fp32",
        "weight_bits": 32,
        "cell_bits": 4,
        "cells_per_weight": 4,
        "derived_from_storage": False,
    }
    assert repq["precision"] == {
        "weights": "int8",
        "weight_bits": 8,
        "cell_bits": 4,
        "cells_per_weight": 2,
        "derived_from_storage": True,
    }
    assert repq["crossbars"] <= rep["crossbars"]
    assert repq["energy_pj"] < rep["energy_pj"]


def test_vgg16_quantized_area_win():
    """On VGG16-sized layers the halved cell count buys real crossbars."""
    cfg = vgg16_config(num_classes=10, input_hw=32)
    params, bits = _pruned_net(cfg, seed=1, sparsity=0.86, num_patterns=8)
    prog = compile_network(cfg, params, bits)
    progq = compile_network(cfg, params, bits, precision="int8")
    rep, repq = prog.hardware_report(), progq.hardware_report()
    assert repq["crossbars"] < rep["crossbars"]
    assert repq["naive_crossbars"] < rep["naive_crossbars"]


def test_engine_config_validates_precision():
    with pytest.raises(ValueError):
        EngineConfig(precision="int4")
    with pytest.raises(ValueError):
        EngineConfig(cell_bits=0)
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params, bits = _pruned_net(cfg)
    with pytest.raises(ValueError):
        compile_network(cfg, params, bits, precision="fp16")


def test_quantized_nondefault_geometry(mini_pair):
    """Non-MXU (block, tile) geometry quantizes and executes too."""
    cfg, prog, _ = mini_pair
    params, bits = _pruned_net(cfg)
    progq = compile_network(
        cfg, params, bits, ecfg=EngineConfig(block=9, tile=8, precision="int8")
    )
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 1, 12, 12))
    ref = np.asarray(make_forward(prog, backend="xla")(x))
    out = np.asarray(make_forward(progq, backend="xla")(x))
    assert (out.argmax(-1) == ref.argmax(-1)).mean() >= 0.95
