"""Pattern extraction / projection (paper §III-A) — unit + property tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import patterns as P


def test_bits_roundtrip(rng):
    masks = rng.random((10, 4, 9)) < 0.5
    bits = P.masks_to_bits(masks)
    for i in range(10):
        for j in range(4):
            np.testing.assert_array_equal(
                P.bits_to_mask(bits[i, j], 9), masks[i, j]
            )


def test_pattern_sizes_popcount(rng):
    bits = rng.integers(0, 2**9, size=100)
    sizes = P.pattern_sizes(bits)
    expect = [bin(int(b)).count("1") for b in bits]
    np.testing.assert_array_equal(sizes, expect)


def test_pdf_sums_to_one(rng):
    bits = rng.integers(0, 2**9, size=1000)
    pdf = P.pattern_pdf(bits)
    assert abs(sum(pdf.values()) - 1.0) < 1e-9


def test_select_candidates_includes_zero():
    pdf = {5: 0.5, 3: 0.3, 9: 0.2}
    d = P.select_candidates(pdf, 2, k=9)
    assert P.ALL_ZERO in d.patterns
    assert 5 in d.patterns and 3 in d.patterns
    assert 9 not in d.patterns


def test_projection_idempotent(rng):
    """Projecting already-pattern-conformant kernels changes nothing."""
    d = P.PatternDict(k=9, patterns=(0b111, 0b11000, 0))
    masks = d.masks()
    choice = rng.integers(0, len(d.patterns), size=(8, 4))
    w = rng.normal(size=(8, 4, 9)) * masks[choice]
    proj, bits = P.project_to_patterns(w, d)
    np.testing.assert_allclose(proj, w)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_pat=st.integers(1, 8),
    co=st.integers(1, 12),
    ci=st.integers(1, 6),
)
def test_projection_properties(seed, n_pat, co, ci):
    """Properties: every projected kernel's mask is in the dictionary;
    projection only removes weights (never adds); magnitude metric keeps
    at least as much energy as any single dictionary pattern would."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(co, ci, 9))
    w[rng.random(w.shape) < 0.5] = 0.0
    bits = P.masks_to_bits(P.kernel_masks(w))
    d = P.select_candidates(P.pattern_pdf(bits), n_pat, 9)
    proj, chosen = P.project_to_patterns(w, d, metric="magnitude")

    assert set(np.unique(chosen)).issubset(set(d.patterns))
    # projection zeroes, never creates
    assert np.all((proj != 0) <= (w != 0))
    # energy optimality of the magnitude metric
    masks = d.masks()
    flat_w = w.reshape(-1, 9)
    kept = (proj.reshape(-1, 9) ** 2).sum(-1)
    best = ((flat_w**2) @ masks.T).max(axis=1)
    np.testing.assert_allclose(kept, best, rtol=1e-9, atol=1e-12)


def test_hamming_metric(rng):
    d = P.PatternDict(k=9, patterns=(0b1, 0b111111111))
    w = np.zeros((1, 1, 9))
    w[0, 0, :2] = 1.0  # mask 0b11: hamming 1 to 0b1? (|11|+|1|-2*1)=1 ;
    # to full: 9+2-2*2=7 -> chooses 0b1
    _, bits = P.project_to_patterns(w, d, metric="hamming")
    assert bits[0, 0] == 0b1
