"""Kernel-reordering weight mapping (paper §III-B) — invariants + oracle."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import patterns as P
from repro.core.indexing import (
    build_index_stream,
    decode_placements,
    index_overhead_bits,
)
from repro.core.mapping import (
    CrossbarConfig,
    map_layer,
    map_layer_naive,
)
from repro.core.ou import naive_ou_schedule, pattern_ou_schedule


def _random_bits(rng, co, ci, n_pat=4, zero_frac=0.3, k=9):
    pats = [0]
    while len(pats) < n_pat + 1:
        b = int(rng.integers(1, 2**k))
        if b not in pats:
            pats.append(b)
    probs = np.full(n_pat + 1, (1 - zero_frac) / n_pat)
    probs[0] = zero_frac
    choice = rng.choice(len(pats), size=(co, ci), p=probs)
    return np.array(pats)[choice]


@pytest.mark.parametrize("order", ["pattern", "channel", "width"])
def test_no_overlap_and_bounds(rng, order):
    """Placements never overlap and never exceed crossbar bounds."""
    bits = _random_bits(rng, co=40, ci=6)
    cfg = CrossbarConfig(rows=64, cols=64, cells_per_weight=2)
    m = map_layer(bits, cfg, block_order=order)
    occupied = {}
    for p in m.placements:
        for r in range(p.row0, p.row0 + p.height):
            for c in range(p.col0, p.col0 + p.width_cells):
                key = (p.crossbar, r, c)
                assert key not in occupied, f"overlap at {key}"
                occupied[key] = p
        assert p.row0 + p.height <= cfg.rows
        assert p.col0 + p.width_cells <= cfg.cols
        assert p.crossbar < m.num_crossbars


def test_all_nonzero_kernels_placed(rng):
    bits = _random_bits(rng, co=30, ci=5)
    m = map_layer(bits)
    placed = {}
    for p in m.placements:
        for kid in p.block.kernel_ids:
            placed.setdefault(p.block.channel, set()).add(kid)
    for c in range(5):
        expect = set(np.nonzero(bits[:, c])[0])
        assert placed.get(c, set()) == expect


def test_zero_kernels_never_stored(rng):
    bits = _random_bits(rng, co=30, ci=5, zero_frac=0.6)
    m = map_layer(bits)
    nz = int((bits != 0).sum())
    assert m.stored_kernels == nz


def test_cells_accounting(rng):
    bits = _random_bits(rng, co=30, ci=5)
    m = map_layer(bits)
    expect = int(P.pattern_sizes(bits).sum()) * m.config.cells_per_weight
    assert m.cells_used == expect


def test_area_never_worse_with_full_sparsity():
    """An all-zero layer maps to zero crossbars."""
    bits = np.zeros((16, 4), np.int64)
    m = map_layer(bits)
    assert m.num_crossbars == 0
    assert m.stored_kernels == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), zero=st.floats(0.0, 0.9))
def test_compression_beats_naive_on_sparse(seed, zero):
    """With <= 4 nonzero patterns, pattern mapping never uses more
    crossbars than naive (the paper's headline claim, as an invariant)."""
    rng = np.random.default_rng(seed)
    bits = _random_bits(rng, co=64, ci=8, n_pat=4, zero_frac=zero)
    ours = map_layer(bits).num_crossbars
    naive = map_layer_naive(64, 8).num_crossbars
    assert ours <= naive


def test_index_stream_roundtrip(rng):
    """§IV-C: placement is reconstructible from the index stream alone."""
    bits = _random_bits(rng, co=50, ci=7)
    m = map_layer(bits)
    stream = build_index_stream(m)
    decoded = decode_placements(stream, m.config)
    assert len(decoded) == len(m.placements)
    for a, b in zip(decoded, m.placements):
        assert (a.crossbar, a.row0, a.col0, a.width_cells) == (
            b.crossbar, b.row0, b.col0, b.width_cells,
        )
        assert a.block.kernel_ids == b.block.kernel_ids


def test_index_overhead_bits(rng):
    bits = _random_bits(rng, co=512, ci=4, zero_frac=0.4)
    m = map_layer(bits)
    stream = build_index_stream(m)
    info = index_overhead_bits(stream)
    # paper §V-D: <= 9 bits per kernel for 512 output channels
    assert info["bits_per_kernel_index"] == 9
    assert info["kernel_index_bits"] == 9 * m.stored_kernels


def test_ou_schedules(rng):
    bits = _random_bits(rng, co=40, ci=6)
    m = map_layer(bits)
    sched = pattern_ou_schedule(m)
    cfg = m.config
    # every OU fits inside a pattern block: wordlines == block height <= 9
    assert (sched.wordlines <= cfg.ou_rows).all()
    assert (sched.bitlines <= cfg.ou_cols).all()
    # total ADC-side cells covered equals stored cells
    assert int(sched.bitlines.sum() * cfg.ou_rows
               >= m.cells_used)  # bands cover all cells

    naive = map_layer_naive(40, 6)
    ns = naive_ou_schedule(naive)
    # naive covers the whole dense matrix
    total_cells = naive.rows_total * naive.cols_total
    covered = int((ns.wordlines * ns.bitlines).sum())
    assert covered == total_cells
