"""Per-architecture smoke tests (reduced configs, deliverable f) + numerics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models.transformer import (
    apply_model,
    count_params,
    init_cache,
    init_params,
)


def _inputs(cfg, b=2, s=16):
    toks = jnp.arange(b * s).reshape(b, s) % cfg.vocab
    kw = {}
    if cfg.encoder_layers:
        kw["frames"] = jnp.ones((b, cfg.enc_seq, cfg.d_model)) * 0.01
    if cfg.prefix_len:
        kw["prefix_embeds"] = jnp.ones((b, cfg.prefix_len, cfg.d_model)) * 0.01
    return toks, kw


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params, specs, statics = init_params(cfg, jax.random.PRNGKey(0))
    toks, kw = _inputs(cfg)
    logits, _, _ = apply_model(params, statics, toks, **kw)
    expect_s = toks.shape[1] + (cfg.prefix_len or 0)
    assert logits.shape == (2, expect_s, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert count_params(params) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    """One SGD step decreases nothing catastrophically: loss finite, grads
    finite, params update."""
    from repro.optim import adamw
    from repro.runtime.train import TrainConfig, cross_entropy, \
        init_train_state, make_train_step

    cfg = get_smoke_config(arch)
    params, specs, statics = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(weight_decay=0.0)
    tcfg = TrainConfig(steps=1)

    def kwargs_fn(batch):
        kw = {}
        if cfg.encoder_layers:
            kw["frames"] = batch["frames"]
        if cfg.prefix_len:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        return kw

    step = make_train_step(cfg, statics, opt, lambda s: 1e-3, tcfg, kwargs_fn)
    state = init_train_state(params, opt, tcfg)
    b, s = 2, 16
    batch = {"tokens": jnp.arange(b * (s + 1)).reshape(b, s + 1) % cfg.vocab}
    if cfg.encoder_layers:
        batch["frames"] = jnp.ones((b, cfg.enc_seq, cfg.d_model)) * 0.01
    if cfg.prefix_len:
        batch["prefix_embeds"] = jnp.ones((b, cfg.prefix_len, cfg.d_model)) * 0.01
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.map(
        lambda a, b_: float(jnp.abs(a - b_).max()), state["params"],
        new_state["params"],
    )
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params, specs, statics = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(statics, 2, max_seq=32, dtype=jnp.float32)
    toks, kw = _inputs(cfg, b=2, s=1)
    if cfg.encoder_layers:
        cache["memory"] = kw["frames"]
    # decode never re-feeds the VLM patch prefix: it lives in the cache
    logits, cache2, _ = apply_model(
        params, statics, toks, positions=jnp.array([3]), cache=cache,
        cache_pos=jnp.int32(3), cache_len=jnp.int32(4),
    )
    assert logits.shape[1] == 1
    assert not bool(jnp.isnan(logits).any())


def test_prefill_decode_consistency():
    """Cache-based decode reproduces the full forward pass exactly."""
    cfg = get_smoke_config("granite_3_2b")
    params, _, statics = init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    full, _, _ = apply_model(params, statics, toks)
    cache = init_cache(statics, 2, max_seq=16, dtype=jnp.float32)
    outs = []
    for t in range(12):
        lg, cache, _ = apply_model(
            params, statics, toks[:, t : t + 1], positions=jnp.array([t]),
            cache=cache, cache_pos=jnp.int32(t), cache_len=jnp.int32(t + 1),
        )
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=2e-4, atol=2e-4,
    )


def test_swa_masks_distant_tokens():
    """Sliding-window attention must ignore tokens beyond the window."""
    cfg = dataclasses.replace(get_smoke_config("h2o_danube_1_8b"))
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    w = cfg.window
    s = w + 8
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)  # perturb outside window
    l1, _, _ = apply_model(params, statics, t1)
    l2, _, _ = apply_model(params, statics, t2)
    # last token is > window away from position 0 in every layer — BUT
    # information can propagate w positions per layer; with 2 layers the
    # receptive field is 2w, so use a perturbation 2w+ away:
    s2 = 2 * w + 4
    t1 = jax.random.randint(jax.random.PRNGKey(2), (1, s2), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)
    l1, _, _ = apply_model(params, statics, t1)
    l2, _, _ = apply_model(params, statics, t2)
    np.testing.assert_allclose(
        np.asarray(l1[0, -1]), np.asarray(l2[0, -1]), rtol=1e-5, atol=1e-5
    )


def test_mla_absorbed_matches_expanded():
    """DeepSeek MLA: the absorbed decode path equals the expanded path."""
    from repro.models.mla import MLAConfig, mla_apply, mla_init

    cfg = MLAConfig(d_model=64, n_heads=4, kv_lora=32, q_lora=48, d_nope=16,
                    d_rope=8, d_v=16, model_shards=1)
    params, _ = mla_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64)) * 0.5
    pos = jnp.arange(10)
    y_abs, _ = mla_apply(params, cfg, x, pos, absorbed=True)
    y_exp, _ = mla_apply(params, cfg, x, pos, absorbed=False)
    np.testing.assert_allclose(
        np.asarray(y_abs), np.asarray(y_exp), rtol=2e-4, atol=2e-4
    )


def test_moe_routes_to_topk():
    """MoE output depends only on top-k experts: ablating an unrouted
    expert's weights changes nothing."""
    from repro.models.moe import MoEConfig, moe_apply, moe_init

    cfg = MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff_expert=16,
                    model_shards=1, capacity_factor=8.0)
    params, _, static = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    y1 = moe_apply(params, static, cfg, x)
    # find an expert no token routed to
    import jax.nn as jnn
    logits = x.reshape(-1, 32) @ params["router"]["w"]
    top = set(np.asarray(jax.lax.top_k(logits, 2)[1]).ravel().tolist())
    unused = next(e for e in range(8) if e not in top)
    p2 = jax.tree.map(lambda a: a, params)
    p2["experts"] = dict(p2["experts"])
    for k in ("gate", "up", "down"):
        p2["experts"][k] = p2["experts"][k].at[unused].set(0.0)
    y2 = moe_apply(p2, static, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_ssd_chunked_equals_recurrence():
    from repro.models.ssm import SSMConfig, init_ssm_cache, ssm_apply, ssm_init

    cfg = SSMConfig(d_model=32, d_state=8, head_dim=8, chunk=4, model_shards=1)
    params, _ = ssm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32)) * 0.5
    y_chunk, _ = ssm_apply(params, cfg, x, None)
    cache = init_ssm_cache(cfg, 2)
    ys = []
    for t in range(12):
        yt, cache = ssm_apply(params, cfg, x[:, t : t + 1], cache)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(
        np.asarray(y_chunk), np.asarray(y_seq), rtol=1e-4, atol=1e-5
    )
