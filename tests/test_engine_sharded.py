"""Sharded execution of ``CompiledNetwork`` across a device mesh.

Two layers of coverage, mirroring ``tests/test_distributed.py``:

  * in-process tests run whenever the pytest process sees enough devices —
    the CI multi-device job forces
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every mesh
    size runs there (and the 1-device mesh case always runs, so the
    shard_map code path is exercised even in the plain suite);
  * one subprocess test virtualizes 8 host devices regardless of the
    parent environment and sweeps the whole 1/2/4/8 matrix — including
    tile counts not divisible by the mesh, the data x model mesh, stats
    equality, sharded service traffic, and the Pallas-interpret backend —
    so the multi-device paths are verified by the default tier-1 run too.

Partitioner unit tests (deterministic; hypothesis properties live in
``tests/test_partition.py``) ride along at the bottom.
"""

import jax
import numpy as np
import pytest
from conftest import run_virtual_devices as _run_sub

from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.engine import (
    EngineConfig,
    NetworkPartition,
    compile_network,
    make_forward,
    pad_bp_tiles,
    partition_from_mesh,
    partition_network,
    tile_assignment,
)
from repro.launch.mesh import make_mesh
from repro.models.cnn import conv_weight_names, init_cnn, mini_cnn_config

# widths (8, 16, 24) with tile=8 give per-layer spmm tile counts (1, 2, 3)
# — deliberately not divisible by 2/4/8-way meshes, so every sharded run
# exercises the zero-padded grey-area tiles.
UNEVEN_ECFG = EngineConfig(block=9, tile=8)


def _pruned_program(ecfg=UNEVEN_ECFG, widths=(8, 16, 24), num_classes=5):
    cfg = mini_cnn_config(num_classes=num_classes, input_hw=12, widths=widths)
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    return cfg, compile_network(cfg, params, bits, ecfg=ecfg)


@pytest.fixture(scope="module")
def uneven():
    return _pruned_program()


def _mesh(data: int, model: int):
    n = data * model
    return make_mesh((data, model), ("data", "model"),
                     devices=jax.devices()[:n])


# ---------------------------------------------------------------- in-process


@pytest.mark.parametrize("n", [1, 2, 4, 8])
def test_sharded_forward_matches_single_device(uneven, n):
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices")
    cfg, prog = uneven
    assert [c.bp.n_tiles for c in prog.convs] == [1, 2, 3]
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 1, 12, 12))
    ref = np.asarray(make_forward(prog, backend="xla")(x))
    out = np.asarray(make_forward(prog, backend="xla", mesh=_mesh(1, n))(x))
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_sharded_data_model_mesh_and_stats(uneven):
    """2x4 mesh, odd batch (fc rows fall back to replication), stats
    counters psum-reduced back to exactly the single-device counts."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices")
    cfg, prog = uneven
    mesh = _mesh(2, 4)
    x = jax.random.normal(jax.random.PRNGKey(7), (7, 1, 12, 12))
    ref, s_ref = make_forward(prog, backend="xla", collect_stats=True)(x)
    out, s_sh = make_forward(
        prog, backend="xla", collect_stats=True, mesh=mesh
    )(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    for name in s_ref.layers:
        np.testing.assert_array_equal(
            s_ref.layers[name].counts, s_sh.layers[name].counts
        )
        assert s_ref.layers[name].windows == s_sh.layers[name].windows


def test_single_device_mesh_runs_everywhere(uneven):
    """The mesh code path itself (shard_map spmm + scatter/psum wiring)
    needs no extra devices: a 1x1 mesh must agree bit-for-bit."""
    cfg, prog = uneven
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 1, 12, 12))
    ref = np.asarray(make_forward(prog, backend="xla")(x))
    out = np.asarray(make_forward(prog, backend="xla", mesh=_mesh(1, 1))(x))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("n", [1, 2, 4])
def test_sharded_quantized_forward(n):
    """Int8 programs shard the same way: w_scales slabs ride with their
    tiles through shard_map.  Unlike fp32, sharded vs single-device is
    bounded by *quantization* error, not fp32 noise: a one-ulp
    reassociation difference in one layer's psum can flip an int8
    rounding in the next layer's dynamic activation quantization,
    amplifying to O(row_scale/2) — observed ~1e-4 here, asserted at the
    composed quantization bound."""
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices")
    cfg, prog = _pruned_program()
    progq = _pruned_program_quantized()
    # batch 64 so the agreement bars below tolerate a couple of argmax
    # flips on near-tied logits (this net is random-init and 0.7-pruned)
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 1, 12, 12))
    ref = np.asarray(make_forward(progq[1], backend="xla")(x))
    out = np.asarray(
        make_forward(progq[1], backend="xla", mesh=_mesh(1, n))(x)
    )
    np.testing.assert_allclose(out, ref, atol=5e-3)
    assert (out.argmax(-1) == ref.argmax(-1)).mean() >= 0.98
    # and the quantized sharded run agrees with fp32 to quantization error
    ref_fp = np.asarray(make_forward(prog, backend="xla")(x))
    assert (out.argmax(-1) == ref_fp.argmax(-1)).mean() >= 0.95


def _pruned_program_quantized():
    cfg = mini_cnn_config(num_classes=5, input_hw=12, widths=(8, 16, 24))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    ecfg = EngineConfig(block=9, tile=8, precision="int8")
    return cfg, compile_network(cfg, params, bits, ecfg=ecfg)


# ---------------------------------------------------------------- subprocess


def test_sharded_matrix_subprocess():
    """The full multi-device matrix on 8 virtualized host devices: sharded
    vs single-device forward for 1/2/4/8-way tile parallelism (both spmm
    geometries, uneven tile counts included), the 2x4 data x model mesh,
    exact stats-counter equality, sharded InferenceService traffic, and
    the Pallas-interpret backend."""
    res = _run_sub(8, """
    from repro.core.pruning import (build_dictionaries, magnitude_prune,
                                    project_params)
    from repro.engine import (EngineConfig, InferenceService,
                              compile_network, make_forward)
    from repro.launch.mesh import make_mesh
    from repro.models.cnn import (conv_weight_names, init_cnn,
                                  mini_cnn_config)

    cfg = mini_cnn_config(num_classes=5, input_hw=12, widths=(8, 16, 24))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 1, 12, 12))

    out = {"diffs": {}, "n_tiles": {}}
    for gname, ecfg in [("mxu", EngineConfig()),
                        ("fine", EngineConfig(block=9, tile=8))]:
        prog = compile_network(cfg, params, bits, ecfg=ecfg)
        out["n_tiles"][gname] = [c.bp.n_tiles for c in prog.convs]
        ref = np.asarray(make_forward(prog, backend="xla")(x))
        for n in (1, 2, 4, 8):
            mesh = make_mesh((1, n), ("data", "model"),
                             devices=jax.devices()[:n])
            got = np.asarray(
                make_forward(prog, backend="xla", mesh=mesh)(x))
            out["diffs"][f"{gname}_model{n}"] = \\
                float(np.abs(got - ref).max())
        mesh = make_mesh((2, 4), ("data", "model"))
        got = np.asarray(make_forward(prog, backend="xla", mesh=mesh)(x))
        out["diffs"][f"{gname}_data2_model4"] = float(np.abs(got - ref).max())

        _, s_ref = make_forward(prog, backend="xla", collect_stats=True)(x)
        _, s_sh = make_forward(prog, backend="xla", collect_stats=True,
                               mesh=mesh)(x)
        out[f"stats_equal_{gname}"] = all(
            np.array_equal(s_ref.layers[k].counts, s_sh.layers[k].counts)
            and s_ref.layers[k].windows == s_sh.layers[k].windows
            for k in s_ref.layers)

    # sharded service: 10 requests through 8 slots (partial generation)
    prog = compile_network(cfg, params, bits,
                           ecfg=EngineConfig(block=9, tile=8))
    imgs = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (10, 1, 12, 12)),
        np.float32)
    mesh = make_mesh((2, 4), ("data", "model"))
    svc = InferenceService(prog, batch_slots=8, backend="xla",
                           collect_stats=True, mesh=mesh)
    ref_svc = InferenceService(prog, batch_slots=8, backend="xla",
                               collect_stats=True)
    out["service_labels_equal"] = bool(
        np.array_equal(svc.classify(imgs), ref_svc.classify(imgs)))
    out["service_stats_equal"] = all(
        np.array_equal(svc.activation_stats.layers[k].counts,
                       ref_svc.activation_stats.layers[k].counts)
        for k in svc.activation_stats.layers)

    # Pallas interpret backend under the same mesh
    mesh2 = make_mesh((1, 2), ("data", "model"), devices=jax.devices()[:2])
    ref = np.asarray(make_forward(prog, backend="xla")(x))
    got = np.asarray(make_forward(prog, backend="pallas", interpret=True,
                                  mesh=mesh2)(x))
    out["diffs"]["pallas_model2"] = float(np.abs(got - ref).max())
    print(json.dumps(out))
    """)
    assert res["n_tiles"]["fine"] == [1, 2, 3]  # uneven vs 2/4/8-way meshes
    for key, diff in res["diffs"].items():
        assert diff < 1e-4, (key, diff)
    for key, val in res.items():
        if key.startswith(("stats_equal", "service_")):
            assert val, key


# ------------------------------------------------- partitioner (no devices)


@pytest.mark.parametrize("shards", [1, 2, 3, 4, 8])
def test_pad_bp_tiles_invariants(uneven, shards):
    cfg, prog = uneven
    for op in [*prog.convs, prog.fc]:
        bp = op.bp
        padded = pad_bp_tiles(bp, shards)
        assert padded.n_tiles % shards == 0
        assert padded.n_tiles - bp.n_tiles < shards  # minimal padding
        # original tiles bit-identical, padding tiles inert
        np.testing.assert_array_equal(
            np.asarray(padded.w_comp[: bp.n_tiles]), np.asarray(bp.w_comp)
        )
        assert not np.asarray(padded.w_comp[bp.n_tiles:]).any()
        assert not padded.nnz[bp.n_tiles:].any()
        # geometry / permutations untouched -> dense reconstruction equal
        assert (padded.n_out, padded.k_in) == (bp.n_out, bp.k_in)
        np.testing.assert_array_equal(
            np.asarray(padded.dense()), np.asarray(bp.dense())
        )


def test_tile_assignment_partitions_padded_range():
    for n_tiles, shards in [(1, 1), (1, 4), (3, 2), (5, 4), (8, 8), (7, 3)]:
        asg = tile_assignment(n_tiles, shards)
        assert asg.shape[0] == shards
        flat = np.sort(asg.ravel())
        np.testing.assert_array_equal(
            flat, np.arange(len(flat))
        )  # every padded tile exactly once
        assert len(flat) % shards == 0 and len(flat) >= n_tiles


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_partition_from_mesh_defaults_and_validation():
    mesh = _FakeMesh({"data": 2, "model": 4})
    part = partition_from_mesh(mesh)
    assert (part.data, part.model) == (2, 4)
    # explicit partition must match the mesh axis sizes
    ok = NetworkPartition(data=2, model=4)
    assert partition_from_mesh(mesh, ok) is ok
    with pytest.raises(ValueError, match="model=8"):
        partition_from_mesh(mesh, NetworkPartition(data=2, model=8))
    # axis absent from the mesh counts as size 1
    assert partition_from_mesh(_FakeMesh({"x": 3})).n_chips == 1
    with pytest.raises(ValueError):
        NetworkPartition(data=0, model=2)


def test_make_forward_partition_requires_mesh(uneven):
    cfg, prog = uneven
    with pytest.raises(ValueError, match="requires mesh"):
        make_forward(prog, partition=NetworkPartition(model=2))


def test_partition_mesh_size_mismatch_rejected(uneven):
    """A program partitioned for 4 chips must not silently run on a
    smaller mesh."""
    cfg, prog = uneven
    progp = partition_network(prog, model=4)
    with pytest.raises(ValueError, match="mesh has"):
        make_forward(progp, backend="xla", mesh=_mesh(1, 1))


def test_hardware_report_chips_view(uneven):
    cfg, prog = uneven
    progp = partition_network(prog, data=2, model=4)
    rep = progp.hardware_report()
    ch = rep["chips"]
    assert (ch["model_shards"], ch["data_replicas"], ch["n_chips"]) \
        == (4, 2, 8)
    assert len(ch["per_chip"]) == 4
    # proportional split: chips sum back to the program totals
    assert sum(r["crossbars"] for r in ch["per_chip"]) \
        == pytest.approx(rep["crossbars"])
    assert sum(r["energy_pj"] for r in ch["per_chip"]) \
        == pytest.approx(rep["energy_pj"])
    assert ch["total_crossbars_all_chips"] \
        == pytest.approx(rep["crossbars"] * 2)
    # the bottleneck chip is never slower than the serial program
    assert 0 < ch["cycles_parallel"] <= rep["cycles"]
    assert ch["parallel_speedup"] >= 1.0
    # explicit n_chips= view without a recorded partition
    rep4 = prog.hardware_report(n_chips=4)
    assert rep4["chips"]["model_shards"] == 4
    assert rep4["chips"]["data_replicas"] == 1
    assert "chips" not in prog.hardware_report()
