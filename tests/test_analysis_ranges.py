"""Mutation tests for the range & bit-width certification pass.

The contract pinned here mirrors ``test_analysis_verify.py``'s: a
pristine compiled program certifies clean end to end (compile -> save ->
load -> ranges) on both precisions, and each corruption family flags
exactly the V5xx rule that guards it — an inflated scale proves
accumulator overflow (V501) without tripping the saturation rule, a
saturating/denormal scale is V502, a zeroed scale over a live brick is
V503, non-finite payloads are V504, shrunken magnitudes expose
unreachable cell slices (V505), and a stale stored certificate is V506.
The certificate itself is bit-deterministic across processes and its
``certified_potential`` pricing matches ``hardware_report``'s own layer
rows exactly.
"""

import dataclasses
import inspect
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.analysis import ProgramFormatError
from repro.analysis.ranges import (
    DEFAULT_INPUT_RANGE,
    NORM_EPS,
    RangeCertificate,
    analyze_network,
    analyze_saved,
)
from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.engine import CompileOptions, compile_network, serialize
from repro.models.cnn import conv_weight_names, init_cnn, mini_cnn_config
from repro.obs import Tracer


@pytest.fixture(scope="module")
def pruned():
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    return cfg, params, bits


def _compile(pruned, precision):
    cfg, params, bits = pruned
    return compile_network(
        cfg, params, bits,
        options=CompileOptions(
            block=16, tile=16, precision=precision, verify="strict"
        ),
    )


@pytest.fixture(scope="module")
def prog_fp32(pruned):
    return _compile(pruned, "fp32")


@pytest.fixture(scope="module")
def prog_int8(pruned):
    return _compile(pruned, "int8")


def _with_bp(prog, bp):
    conv0 = dataclasses.replace(prog.convs[0], bp=bp)
    return dataclasses.replace(prog, convs=[conv0] + prog.convs[1:])


def _np(bp, field):
    return np.array(getattr(bp, field))  # mutable host copy


def _active_slot(bp):
    """(tile, slot) of an active brick with nonzero weights."""
    w = _np(bp, "w_comp")
    nnz = _np(bp, "nnz")
    for t in range(w.shape[0]):
        for k in range(int(nnz[t])):
            if np.any(w[t, k]):
                return t, k
    raise AssertionError("no active nonzero brick in fixture")


def _with_scale(prog, value):
    bp = prog.convs[0].bp
    t, k = _active_slot(bp)
    s = _np(bp, "w_scales")
    s[t, k] = value
    return _with_bp(prog, dataclasses.replace(bp, w_scales=s))


# ------------------------------------------------- pristine programs


def test_pristine_fp32_certifies_clean(prog_fp32):
    report, cert = analyze_network(prog_fp32)
    assert report.clean, report.format()
    assert cert.precision == "fp32"
    assert cert.fp32_safe
    assert (cert.input_lo, cert.input_hi) == DEFAULT_INPUT_RANGE
    assert [e.name for e in cert.layers] == (
        [c.name for c in prog_fp32.convs] + ["fc"]
    )
    for entry in cert.layers:
        assert np.isfinite(entry.act_lo) and np.isfinite(entry.act_hi)
        assert entry.act_lo <= entry.act_hi
        assert entry.certified_cells is None  # fp32: no cell table


def test_pristine_int8_certifies_clean(prog_int8):
    report, cert = analyze_network(prog_int8)
    assert report.clean, report.format()
    stored = prog_int8.cells_per_weight
    for conv in prog_int8.convs:
        entry = cert.layer(conv.name)
        assert entry.stored_cells == stored
        # per-brick quantization saturates each brick at QMAX on its own
        # scale, so a pristine program certifies exactly what it stores
        assert entry.certified_cells == stored
        assert 0 < entry.acc_int32_max < 2**31
        assert 0.0 < entry.acc_fp32_max < float(np.finfo(np.float32).max)
    assert set(cert.certified_cells()) == (
        {c.name for c in prog_int8.convs} | {"fc"}
    )


@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_end_to_end_compile_save_load_ranges(pruned, precision, tmp_path):
    prog = _compile(pruned, precision)
    assert prog.certificate is not None  # attached under verify="strict"
    d = str(tmp_path / f"prog_{precision}")
    serialize.save_program(d, prog)
    loaded = serialize.load_program(d)
    assert loaded.certificate is not None
    assert loaded.certificate.to_manifest() == prog.certificate.to_manifest()
    report, cert = analyze_saved(d)
    assert report.ok, report.format()
    assert not {r for r in report.rules() if r.startswith("V5")} - {"V504"}
    assert cert.to_manifest() == prog.certificate.to_manifest()


def test_compile_emits_ranges_span(pruned):
    cfg, params, bits = pruned
    tr = Tracer()
    prog = compile_network(
        cfg, params, bits,
        options=CompileOptions(
            block=16, tile=16, precision="int8", verify="warn", tracer=tr
        ),
    )
    spans = [s for s in tr.spans("compile") if s.name == "ranges"]
    assert len(spans) == 1
    assert spans[0].args["fp32_safe"] is True
    assert spans[0].args["certified_cells"] == (
        prog.certificate.certified_cells()
    )


def test_norm_eps_matches_channel_norm_default():
    from repro.models.cnn import channel_norm

    default = inspect.signature(channel_norm).parameters["eps"].default
    assert default == NORM_EPS


# ------------------------------------------------- V5xx mutations


def test_v501_inflated_scale_proves_fp32_overflow(prog_int8):
    # 1e35 folds to ~1e40 in the accumulator (> fp32 max) while staying
    # below the V502 saturation threshold (1e35 * 127 < fp32 max): the
    # overflow rule must fire on its own evidence, not via scale health
    report, _ = analyze_network(_with_scale(prog_int8, 1e35))
    assert "V501" in report.rules(), report.format()
    assert "V502" not in report.rules(), report.format()
    assert not report.ok


def test_v502_saturating_scale(prog_int8):
    report, _ = analyze_network(_with_scale(prog_int8, 1e38))
    assert "V502" in report.rules(), report.format()
    assert not report.ok


def test_v502_denormal_scale(prog_int8):
    report, _ = analyze_network(_with_scale(prog_int8, 1e-40))
    assert "V502" in report.rules(), report.format()
    assert any("denormal" in d.message for d in report.errors)


def test_v503_dead_scale_group_is_a_warning(prog_int8):
    report, _ = analyze_network(_with_scale(prog_int8, 0.0))
    assert "V503" in report.rules(), report.format()
    assert report.ok  # warning: semantic twin of verify's V112 error
    assert any(d.rule == "V503" for d in report.warnings)


def test_v504_nonfinite_bias_is_an_error(prog_fp32):
    bias = np.array(prog_fp32.convs[0].bias)
    bias[0] = np.inf
    conv0 = dataclasses.replace(prog_fp32.convs[0], bias=bias)
    broken = dataclasses.replace(
        prog_fp32, convs=[conv0] + prog_fp32.convs[1:]
    )
    report, cert = analyze_network(broken)
    assert "V504" in report.rules(), report.format()
    assert not report.ok
    assert not cert.fp32_safe


def test_v504_fp32_exceedance_is_a_warning(prog_fp32):
    # an adversarially wide declared input range pushes finite bounds
    # past the fp32 range: certifiable, but not fp32-safe
    report, cert = analyze_network(prog_fp32, input_range=(-1e38, 1e38))
    assert report.ok, report.format()
    assert any(d.rule == "V504" for d in report.warnings)
    assert not cert.fp32_safe


def test_v505_shrunken_magnitudes_expose_unreachable_cells(prog_int8):
    bp = prog_int8.convs[0].bp
    w = _np(bp, "w_comp")
    broken = _with_bp(
        prog_int8,
        dataclasses.replace(bp, w_comp=np.clip(w, -7, 7)),
    )
    report, cert = analyze_network(broken)
    assert "V505" in report.rules(), report.format()
    assert report.ok  # headroom is a finding, not a defect
    entry = cert.layer(prog_int8.convs[0].name)
    assert entry.certified_cells == 1
    assert entry.stored_cells == 2


def test_v506_stale_stored_certificate(prog_int8, tmp_path):
    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_int8)
    path = os.path.join(d, "program.json")
    with open(path) as f:
        manifest = json.load(f)
    manifest["certificate"]["layers"][0]["act_hi"] *= 2.0
    with open(path, "w") as f:
        json.dump(manifest, f)
    report, _ = analyze_saved(d)
    assert "V506" in report.rules(), report.format()
    assert not report.ok


# ------------------------------------------------- determinism


def test_certificate_deterministic_across_processes(prog_int8, tmp_path):
    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_int8)
    here = json.dumps(
        analyze_saved(d)[1].to_manifest(), sort_keys=True
    )
    code = (
        "import json\n"
        "from repro.analysis.ranges import analyze_saved\n"
        f"_, cert = analyze_saved({d!r})\n"
        "print(json.dumps(cert.to_manifest(), sort_keys=True))\n"
    )
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, check=True,
    )
    assert out.stdout.strip() == here


def test_certificate_manifest_round_trip(prog_int8):
    cert = prog_int8.certificate
    back = RangeCertificate.from_manifest(
        json.loads(json.dumps(cert.to_manifest()))
    )
    assert back == cert


# ------------------------------------------------- certified pricing


def test_certified_potential_zero_drift_against_layer_rows(prog_int8):
    rep = prog_int8.hardware_report()
    cp = rep["certified_potential"]
    assert cp["available"] is True
    by_name = {row["name"]: row for row in rep["layers"]}
    assert len(cp["layers"]) == len(prog_int8.convs)
    for row in cp["layers"]:
        hw = by_name[row["name"]]
        # same pricing chain (core/simulator.mapping_cost): exact equality
        assert row["area_cells"] == hw["area_cells"]
        assert row["energy_pj"] == hw["energy_pj"]
        assert row["cycles"] == hw["cycles"]
        assert row["certified_cells"] <= row["stored_cells"]
        assert row["certified_area_cells"] <= row["area_cells"]
    assert cp["area_win"] >= 1.0
    assert cp["energy_win"] >= 1.0
    assert cp["fp32_safe"] is True


def test_certified_potential_prices_v505_headroom(prog_int8):
    from repro.core.mapping import CrossbarConfig

    # halve every stored magnitude's bit budget: the recertified program
    # must price a strictly smaller certified area than its stored one.
    # Priced on a crossbar narrow enough that the per-weight cell count
    # decides the column-band count (on the paper's 512-wide array the
    # mini CNN fits one band at either width, so the win would round to
    # zero — a granularity fact, not a pricing one).
    convs = []
    for c in prog_int8.convs:
        w = _np(c.bp, "w_comp")
        convs.append(dataclasses.replace(
            c, bp=dataclasses.replace(c.bp, w_comp=np.clip(w, -7, 7))
        ))
    shrunk = dataclasses.replace(prog_int8, convs=convs)
    _, cert = analyze_network(shrunk)
    shrunk = dataclasses.replace(shrunk, certificate=cert)
    narrow = CrossbarConfig(rows=9, cols=8, ou_rows=9, ou_cols=8)
    cp = shrunk.hardware_report(config=narrow)["certified_potential"]
    for row in cp["layers"]:
        assert (row["certified_cells"], row["stored_cells"]) == (1, 2)
        assert row["certified_area_cells"] < row["area_cells"]
    assert cp["certified_area_cells"] < cp["area_cells"]
    assert cp["area_win"] > 1.0


def test_certified_potential_unavailable_on_fp32(prog_fp32):
    cp = prog_fp32.hardware_report()["certified_potential"]
    assert cp["available"] is False
    assert "fp32" in cp["reason"]


# ------------------------------------------------- manifest v4 / compat


def test_manifest_v4_carries_certificate(prog_int8, tmp_path):
    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_int8)
    with open(os.path.join(d, "program.json")) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == 4
    assert manifest["certificate"]["precision"] == "int8"


def test_v3_manifest_loads_without_certificate(prog_int8, tmp_path):
    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_int8)
    path = os.path.join(d, "program.json")
    with open(path) as f:
        manifest = json.load(f)
    manifest["format_version"] = 3
    del manifest["certificate"]
    with open(path, "w") as f:
        json.dump(manifest, f)
    loaded = serialize.load_program(d)
    assert loaded.certificate is None
    # a certificate-less save still certifies — it just can't cross-check
    report, cert = analyze_saved(d)
    assert report.ok, report.format()
    assert cert is not None
    assert "V506" not in report.rules()


def test_malformed_certificate_entry_is_m003(prog_int8, tmp_path):
    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_int8)
    path = os.path.join(d, "program.json")
    with open(path) as f:
        manifest = json.load(f)
    manifest["certificate"] = {"input_lo": "not a number"}
    with open(path, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ProgramFormatError) as e:
        serialize.load_program(d)
    assert e.value.rule == "M003"


# ------------------------------------------------- CLI


def test_cli_ranges_and_all(prog_int8, tmp_path, capsys):
    from repro.analysis.__main__ import main

    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_int8)
    clean_py = tmp_path / "clean.py"
    clean_py.write_text("def f(x):\n    return x\n")

    assert main(["ranges", d]) == 0
    capsys.readouterr()
    assert main(["ranges", d, "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["certificate"]["precision"] == "int8"
    assert payload["report"]["ok"] is True

    assert main(["all", d, "--paths", str(clean_py)]) == 0
    merged = json.loads(capsys.readouterr().out)
    assert merged["ok"] is True and merged["exit_code"] == 0
    assert {"verify", "lint", "ranges"} <= set(merged)


def test_cli_exit_codes_isolate_failure_classes(prog_int8, tmp_path, capsys):
    from repro.analysis.__main__ import main

    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_int8)
    path = os.path.join(d, "program.json")
    with open(path) as f:
        manifest = json.load(f)
    manifest["certificate"]["layers"][0]["act_hi"] *= 2.0  # V506 (ranges)
    with open(path, "w") as f:
        json.dump(manifest, f)
    dirty_py = tmp_path / "dirty.py"
    dirty_py.write_text("def f(x, acc=[]):\n    return acc\n")  # L003

    assert main(["ranges", d]) == 1
    capsys.readouterr()
    # verify passes (structure intact), lint fails (+2), ranges fails (+4)
    assert main(["all", d, "--paths", str(dirty_py)]) == 6
    merged = json.loads(capsys.readouterr().out)
    assert merged["exit_code"] == 6
    assert merged["verify"]["ok"] is True


def test_cli_input_range_override(prog_fp32, tmp_path, capsys):
    from repro.analysis.__main__ import main

    d = str(tmp_path / "prog")
    serialize.save_program(d, prog_fp32)
    # `=` form: argparse would otherwise read "-1e38" as an option
    rc = main(["ranges", d, "--json", "--input-lo=-1e38", "--input-hi", "1e38"])
    assert rc == 0  # V504 exceedance is a warning, not an error
    payload = json.loads(capsys.readouterr().out)
    assert payload["certificate"]["fp32_safe"] is False
    assert payload["certificate"]["input_hi"] == 1e38
