"""Property-based tests for the tile partitioner (engine/partition.py).

Random block-sparse weights are compressed with the engine's exact
lowering path and then tile-padded for every shard count: the assignment
must cover each padded tile exactly once, padding tiles must be inert
(all-zero bricks, zero nnz), and the padded operand must reconstruct the
identical dense matrix — the invariants the sharded executor's
scatter + psum combine relies on.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sparse import build_block_pattern, nonzero_block_masks
from repro.engine.partition import (
    pad_bp_tiles,
    padded_tiles,
    tile_assignment,
)

BLOCK, TILE = 8, 8


def _random_bp(seed: int, nb: int, nt: int, density: float):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(nb * BLOCK, nt * TILE)).astype(np.float32)
    # block-structured zeros: kill whole (block, column) strips
    kill = rng.random(size=(nb, nt * TILE)) > density
    w *= ~np.repeat(kill, BLOCK, axis=0)
    masks = nonzero_block_masks(w, BLOCK)
    return w, build_block_pattern(w, block=BLOCK, tile=TILE, masks=masks)


bp_params = st.tuples(
    st.integers(0, 2**31 - 1),  # seed
    st.integers(1, 3),  # K blocks
    st.integers(1, 6),  # tiles
    st.floats(0.1, 0.9),  # density
    st.integers(1, 9),  # shards
)


@given(bp_params)
@settings(max_examples=40, deadline=None)
def test_assignment_covers_every_padded_tile_once(p):
    _, nb, nt, _, shards = p
    asg = tile_assignment(nt, shards)
    assert asg.shape == (shards, padded_tiles(nt, shards) // shards)
    np.testing.assert_array_equal(
        np.sort(asg.ravel()), np.arange(asg.size)
    )
    # minimal padding: strictly fewer than `shards` inert tiles added
    assert nt <= asg.size < nt + shards


@given(bp_params)
@settings(max_examples=25, deadline=None)
def test_padding_tiles_are_inert(p):
    seed, nb, nt, density, shards = p
    _, bp = _random_bp(seed, nb, nt, density)
    padded = pad_bp_tiles(bp, shards)
    assert padded.n_tiles == padded_tiles(bp.n_tiles, shards)
    # original tiles bit-identical
    np.testing.assert_array_equal(
        np.asarray(padded.w_comp[: bp.n_tiles]), np.asarray(bp.w_comp)
    )
    np.testing.assert_array_equal(
        np.asarray(padded.block_ids[: bp.n_tiles]),
        np.asarray(bp.block_ids),
    )
    np.testing.assert_array_equal(padded.nnz[: bp.n_tiles], bp.nnz)
    # padding tiles carry nothing
    assert not np.asarray(padded.w_comp[bp.n_tiles:]).any()
    assert not padded.nnz[bp.n_tiles:].any()


@given(bp_params)
@settings(max_examples=25, deadline=None)
def test_reassembled_weights_equal_unsharded(p):
    """Gathering each shard's tile slab back together reproduces the
    padded operand, and the padded operand reconstructs the original
    dense weight exactly."""
    seed, nb, nt, density, shards = p
    w, bp = _random_bp(seed, nb, nt, density)
    padded = pad_bp_tiles(bp, shards)
    asg = tile_assignment(bp.n_tiles, shards)
    # per-shard slabs (what each device holds) reassemble to the operand
    slabs = np.asarray(padded.w_comp)[asg.ravel()]
    np.testing.assert_array_equal(slabs, np.asarray(padded.w_comp))
    # and the compressed representation is still the same matrix
    np.testing.assert_array_equal(
        np.asarray(padded.dense()), np.asarray(bp.dense())
    )
    np.testing.assert_array_equal(
        np.asarray(bp.dense()).astype(np.float32), w
    )
