"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse import (
    BlockPatternWeight,
    build_block_pattern,
    pattern_spmm_xla,
)
from repro.kernels import ref
from repro.kernels.ops import flash_attention, ou_mvm, pattern_spmm


def _tolerance(dtype):
    # bf16 inputs with fp32 accumulators: reduction-order noise across the
    # pallas/ref paths is a few ULPs of bf16 (~8e-3 relative) per element
    return dict(rtol=8e-2, atol=4e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,k,n,block,tile",
    [
        (32, 256, 256, 128, 128),
        (130, 256, 384, 128, 128),  # m not tile-aligned
        (16, 512, 256, 64, 64),
        (8, 128, 128, 128, 128),  # single block
    ],
)
def test_pattern_spmm_sweep(rng, m, k, n, block, tile, dtype):
    w = rng.normal(size=(k, n)).astype(np.float32)
    bp = build_block_pattern(w, num_patterns=4, density=0.4, block=block,
                             tile=tile)
    x = (rng.normal(size=(m, k)) * 0.3).astype(np.float32)
    xj = jnp.asarray(x, dtype)

    y_pallas = pattern_spmm(xj, bp, backend="pallas", interpret=True)
    y_ref = ref.pattern_spmm_ref(
        jnp.asarray(x), bp.w_comp, bp.block_ids, block
    )
    y_ref = jnp.take(y_ref, jnp.asarray(bp.inv_order), axis=1)
    np.testing.assert_allclose(
        np.asarray(y_pallas, np.float32), np.asarray(y_ref, np.float32),
        **_tolerance(dtype),
    )
    # XLA path agrees too
    y_xla = pattern_spmm(jnp.asarray(x), bp, backend="xla")
    np.testing.assert_allclose(
        np.asarray(y_xla), np.asarray(y_ref), rtol=1e-5, atol=1e-5
    )


def test_pattern_spmm_bm_autotune(rng):
    """bm=None picks a sublane-aligned row tile from M; result unchanged."""
    from repro.kernels.ops import _pick_bm

    assert _pick_bm(1, jnp.float32) == 8
    assert _pick_bm(8, jnp.float32) == 8
    assert _pick_bm(20, jnp.float32) == 32
    assert _pick_bm(200, jnp.float32) == 128
    assert _pick_bm(1, jnp.bfloat16) == 16  # bf16 min sublane tile is 16
    assert _pick_bm(100, jnp.bfloat16) == 128

    k, n = 256, 256
    w = rng.normal(size=(k, n)).astype(np.float32)
    bp = build_block_pattern(w, num_patterns=4, density=0.4)
    for m in (1, 3, 17, 130):
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        y_auto = pattern_spmm(x, bp, backend="pallas", interpret=True)
        y_ref = pattern_spmm(x, bp, backend="xla")
        np.testing.assert_allclose(
            np.asarray(y_auto), np.asarray(y_ref), rtol=2e-5, atol=2e-5
        )


def test_pattern_spmm_matches_dense_oracle(rng):
    """Compressed compute == dense matmul with the projected weight —
    the paper's central correctness claim at the kernel level."""
    k, n = 512, 512
    w = rng.normal(size=(k, n)).astype(np.float32)
    bp = build_block_pattern(w, num_patterns=4, density=0.3)
    wd = np.asarray(bp.dense())
    x = rng.normal(size=(17, k)).astype(np.float32)
    y = pattern_spmm(jnp.asarray(x), bp, backend="xla")
    np.testing.assert_allclose(np.asarray(y), x @ wd, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 33])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d",
    [
        (1, 2, 1, 64, 64, 32),
        (2, 4, 2, 100, 100, 64),  # unaligned seq
        (1, 3, 3, 128, 256, 32),  # cross-length
    ],
)
def test_flash_attention_sweep(rng, b, hq, hkv, sq, sk, d, causal, window,
                               dtype):
    if sq != sk and causal:
        pytest.skip("causal with sq != sk is not used by the models")
    q = (rng.normal(size=(b, hq, sq, d)) * 0.5).astype(np.float32)
    k = (rng.normal(size=(b, hkv, sk, d)) * 0.5).astype(np.float32)
    v = (rng.normal(size=(b, hkv, sk, d)) * 0.5).astype(np.float32)
    args = [jnp.asarray(a, dtype) for a in (q, k, v)]
    o_pal = flash_attention(*args, causal=causal, window=window,
                            backend="pallas", interpret=True, bq=64, bk=64)
    o_ref = flash_attention(*map(jnp.asarray, (q, k, v)), causal=causal,
                            window=window, backend="xla")
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32),
        **_tolerance(dtype),
    )


@pytest.mark.parametrize("r,c,ou_r,ou_c", [(100, 52, 9, 8), (64, 64, 16, 8),
                                           (27, 8, 9, 8)])
def test_ou_mvm_sweep(rng, r, c, ou_r, ou_c):
    w = rng.normal(size=(r, c)).astype(np.float32)
    x = rng.normal(size=(r,)).astype(np.float32)
    # carve all-zero bands to exercise the skip path
    x[: ou_r] = 0.0
    y = ou_mvm(jnp.asarray(x), jnp.asarray(w), ou_rows=ou_r, ou_cols=ou_c)
    y_ref = ref.ou_mvm_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5,
                               atol=1e-5)


def test_ou_skip_lossless(rng):
    """The all-zero-input skip (paper §IV-A) must be numerically lossless."""
    w = rng.normal(size=(45, 16)).astype(np.float32)
    x = rng.normal(size=(45,)).astype(np.float32)
    x[9:27] = 0.0
    y_skip = ou_mvm(jnp.asarray(x), jnp.asarray(w))
    dense = x @ w
    np.testing.assert_allclose(np.asarray(y_skip), dense, rtol=1e-5, atol=1e-5)
