"""Hypothesis properties of the mapping design-space search.

Randomized over layer geometry and sparsity, the two contracts the
deterministic suite (``tests/test_mapping_search.py``) checks on the
smoke net must hold universally:

  * every candidate the search visits induces a *bijective* column
    permutation of the engine operands, for any reorder strategy;
  * the search's cost model is the simulator's pricing chain — its
    area/energy/cycles for the chosen candidate equal the
    ``simulate_layer_multi`` numbers for the same geometry with **zero
    tolerance** (``==`` on floats), and the Pareto guard holds.

Skipped wholesale when hypothesis is not installed (it is a dev-only
dependency; CI installs it, the bare runtime image may not).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.mapping import MappingCandidate
from repro.core.mapsearch import MappingSearchConfig, search_layer_mapping
from repro.core.simulator import mapping_cost, simulate_layer_multi
from repro.core.synthetic import LayerSpec
from repro.core.sparse import predicted_tile_nnz, reorder_columns

# small geometries keep each example fast; 9-bit patterns = 3x3 kernels
layer_params = st.builds(
    dict,
    c_out=st.integers(2, 12),
    c_in=st.integers(1, 10),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)


def _random_bits(c_out, c_in, density, seed):
    rng = np.random.default_rng(seed)
    masks = rng.random((c_out, c_in, 9)) < density
    weights = 1 << np.arange(9, dtype=np.int64)
    return (masks * weights).sum(-1)


# tiny search space per example: two dims x all orderings exercises every
# reorder/block-order code path without pricing hundreds of candidates
_SEARCH = MappingSearchConfig(
    crossbar_dims=((64, 64), (32, 32)), restarts=1, max_passes=2
)


def _fixed_candidate():
    return MappingCandidate(rows=64, cols=64)


@given(layer_params)
@settings(max_examples=30, deadline=None)
def test_visited_reorders_bijective(p):
    bits = _random_bits(**p)
    # engine-side masks for a matching matmul view: [N, n_blocks]
    rng = np.random.default_rng(p["seed"] + 1)
    n = 16
    masks = rng.random((n, max(p["c_in"], 1))) < 0.5
    res = search_layer_mapping(
        bits, fixed=_fixed_candidate(), search=_SEARCH, masks=masks, tile=8
    )
    assert res.evaluations == len(res.visited) >= 1
    for cand in res.visited:
        order = reorder_columns(masks, cand.reorder)
        np.testing.assert_array_equal(np.sort(order), np.arange(n))
        # the brick predictor is well-defined for the permuted masks:
        # per-tile counts bounded by the block count, total bounded below
        # by the union mask (a block present anywhere is stored at least
        # once)
        nnz = predicted_tile_nnz(masks, order, 8)
        assert nnz.max(initial=0) <= masks.shape[1]
        assert nnz.sum() >= masks.any(axis=0).sum()


@given(layer_params)
@settings(max_examples=30, deadline=None)
def test_cost_model_equals_simulator_pricing(p):
    """Zero-drift: for the chosen candidate, mapping_cost == the
    simulator's full-layer pricing at the same geometry."""
    bits = _random_bits(**p)
    out_hw = 4
    res = search_layer_mapping(
        bits, windows=out_hw ** 2, fixed=_fixed_candidate(), search=_SEARCH
    )
    # Pareto guard holds on arbitrary layers
    assert res.cost.area_cells <= res.fixed_cost.area_cells
    assert res.cost.energy_pj <= res.fixed_cost.energy_pj

    spec = LayerSpec("prop", p["c_in"], p["c_out"], out_hw)
    for cand in (res.chosen, res.fixed):
        mc = mapping_cost(bits, cand, out_hw ** 2)
        r = simulate_layer_multi(
            _LayerStub(spec, bits), {"noskip": None},
            config=cand.crossbar_config(), block_order=cand.block_order,
        )["noskip"]
        assert mc.crossbars == r.ours_crossbars
        assert mc.area_cells == r.ours_area_cells
        assert mc.energy_pj == r.ours_energy_pj  # exact float equality
        assert mc.cycles == r.ours_cycles


class _LayerStub:
    """The duck-typed layer simulate_layer_multi expects (only ``spec``
    and ``pattern_bits`` are read on the pattern-pruned pricing path)."""

    def __init__(self, spec, bits):
        self.spec = spec
        self.pattern_bits = bits


@given(layer_params)
@settings(max_examples=20, deadline=None)
def test_search_deterministic_property(p):
    bits = _random_bits(**p)
    a = search_layer_mapping(bits, fixed=_fixed_candidate(), search=_SEARCH)
    b = search_layer_mapping(bits, fixed=_fixed_candidate(), search=_SEARCH)
    assert a == b
