import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# Tests that need >1 device run in a subprocess with
# XLA_FLAGS=--xla_force_host_platform_device_count (the main pytest
# process stays at 1 device unless CI forces more, so every other test
# sees the normal environment).  Shared by tests/test_distributed.py and
# tests/test_engine_sharded.py.
_SUBPROCESS_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import jax, json
import numpy as np
"""


def run_virtual_devices(n_devices: int, body: str) -> dict:
    """Run ``body`` under ``n_devices`` virtualized host devices; the body
    must end by printing one JSON line, which is returned parsed."""
    code = _SUBPROCESS_PRELUDE.format(n=n_devices) + textwrap.dedent(body)
    env = dict(os.environ)
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    line = out.stdout.strip().splitlines()[-1]
    return json.loads(line)
