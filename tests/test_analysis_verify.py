"""Mutation tests for the static program verifier.

The contract pinned here: a pristine compiled/serialized program passes
with zero errors, and corrupting exactly one field flags exactly the
rule that guards it.  Each catalog entry is (name, mutator, expected
error-rule set); a seeded sweep also corrupts *random* sites of the
payload to show detection does not depend on a lucky index.  (Hypothesis
is not available in this environment, so the catalog + seeded sweep
stand in for its strategies.)
"""

import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.analysis import ProgramFormatError, VerificationError
from repro.analysis.verify import (
    verify_bp,
    verify_network,
    verify_partition,
    verify_saved,
)
from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.core.patterns import ALL_ZERO, pattern_sizes
from repro.engine import compile_network, partition_network
from repro.engine.lowering import EngineConfig
from repro.engine.partition import NetworkPartition, pad_bp_tiles
from repro.engine import serialize
from repro.models.cnn import conv_weight_names, init_cnn, mini_cnn_config


@pytest.fixture(scope="module")
def pruned():
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    return cfg, params, bits


@pytest.fixture(scope="module")
def prog_fp32(pruned):
    cfg, params, bits = pruned
    return compile_network(cfg, params, bits,
                           ecfg=EngineConfig(block=16, tile=16))


@pytest.fixture(scope="module")
def prog_int8(pruned):
    cfg, params, bits = pruned
    return compile_network(cfg, params, bits,
                           ecfg=EngineConfig(block=16, tile=16),
                           precision="int8")


def _with_bp(prog, bp):
    conv0 = dataclasses.replace(prog.convs[0], bp=bp)
    return dataclasses.replace(prog, convs=[conv0] + prog.convs[1:])


def _np(bp, field):
    return np.array(getattr(bp, field))  # mutable host copy


def _active_slot(bp):
    """(tile, slot) of an active brick with nonzero weights."""
    w = _np(bp, "w_comp")
    nnz = _np(bp, "nnz")
    for t in range(w.shape[0]):
        for k in range(int(nnz[t])):
            if np.any(w[t, k] != 0):
                return t, k
    raise AssertionError("fixture has no active nonzero brick")


def test_pristine_programs_verify_clean(prog_fp32, prog_int8):
    for prog in (prog_fp32, prog_int8):
        report = verify_network(prog)
        assert report.ok, report.format()
        assert prog.verify(strict=True).ok


# ---------------------------------------------------------------------------
# operand-level mutation catalog
# ---------------------------------------------------------------------------


def _mut_perm_duplicate(bp, rng):
    order = _np(bp, "new_order")
    i, j = rng.choice(len(order), size=2, replace=False)
    order[i] = order[j]  # no longer a bijection
    return dataclasses.replace(bp, new_order=order)


def _mut_perm_swap(bp, rng):
    order = _np(bp, "new_order")
    i, j = rng.choice(len(order), size=2, replace=False)
    order[[i, j]] = order[[j, i]]  # still a bijection, inverse now stale
    return dataclasses.replace(bp, new_order=order)


def _mut_geometry(bp, rng):
    return dataclasses.replace(bp, k_in=bp.k_in + 1)


def _mut_brick_shape(bp, rng):
    return dataclasses.replace(bp, w_comp=_np(bp, "w_comp")[:, :, :, :-1])


def _mut_blockid_oob(bp, rng):
    ids = _np(bp, "block_ids")
    t = rng.integers(ids.shape[0])
    ids[t, 0] = bp.k_in // bp.block  # one past the last row group
    return dataclasses.replace(bp, block_ids=ids)


def _mut_nnz_over(bp, rng):
    nnz = _np(bp, "nnz")
    nnz[rng.integers(len(nnz))] = bp.w_comp.shape[1] + 1
    return dataclasses.replace(bp, nnz=nnz)


def _mut_padded_brick(bp, rng):
    bp = pad_bp_tiles(bp, bp.n_tiles + 1)  # appends >=1 inert tile
    w = _np(bp, "w_comp")
    w[-1, 0, 0, 0] = 3.0 if bp.w_scales is None else 3
    return dataclasses.replace(bp, w_comp=w)


def _mut_dict_masks(bp, rng):
    return dataclasses.replace(bp, dict_masks=_np(bp, "dict_masks")[:, :-1])


OPERAND_MUTATIONS = [
    ("perm-not-bijective", _mut_perm_duplicate, {"V101"}),
    ("perm-inverse-stale", _mut_perm_swap, {"V102"}),
    ("geometry-indivisible", _mut_geometry, {"V103"}),
    ("brick-shape", _mut_brick_shape, {"V104"}),
    ("blockid-out-of-bounds", _mut_blockid_oob, {"V105"}),
    ("nnz-over-capacity", _mut_nnz_over, {"V106"}),
    ("padded-brick-nonzero", _mut_padded_brick, {"V107"}),
    ("dict-mask-shape", _mut_dict_masks, {"V109"}),
]


@pytest.mark.parametrize(
    "name,mutate,expected",
    OPERAND_MUTATIONS,
    ids=[m[0] for m in OPERAND_MUTATIONS],
)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_operand_mutation_flags_rule(prog_fp32, name, mutate, expected, seed):
    rng = np.random.default_rng(seed)
    bp = mutate(prog_fp32.convs[0].bp, rng)
    report = verify_bp(bp, layer="conv1")
    assert report.rules("error") == expected, report.format()


@pytest.mark.parametrize(
    "name,mutate,expected",
    OPERAND_MUTATIONS,
    ids=[m[0] for m in OPERAND_MUTATIONS],
)
def test_operand_mutation_caught_at_network_level(
    prog_fp32, name, mutate, expected
):
    rng = np.random.default_rng(0)
    prog = _with_bp(prog_fp32, mutate(prog_fp32.convs[0].bp, rng))
    report = verify_network(prog)
    assert expected <= report.rules("error"), report.format()
    assert all(d.layer == "conv1" for d in report.errors
               if d.rule in expected)
    with pytest.raises(VerificationError) as ei:
        prog.verify(strict=True)
    assert ei.value.report.rules("error") >= expected


def test_blockid_order_is_a_warning_not_error(prog_fp32):
    for conv in prog_fp32.convs:
        bp = conv.bp
        nnz = _np(bp, "nnz")
        tiles = np.flatnonzero(nnz >= 2)
        if tiles.size:
            break
    assert tiles.size, "fixture needs a tile with >= 2 active bricks"
    ids = _np(bp, "block_ids")
    t = int(tiles[0])
    ids[t, [0, 1]] = ids[t, [1, 0]]  # valid set, non-canonical order
    report = verify_bp(dataclasses.replace(bp, block_ids=ids), layer="x")
    assert report.ok
    assert "V108" in report.rules("warning")


# ---------------------------------------------------------------------------
# quantized-path mutations
# ---------------------------------------------------------------------------


def _mut_scale_shape(bp, rng):
    return dataclasses.replace(bp, w_scales=_np(bp, "w_scales")[:, :-1])


def _mut_scale_nan(bp, rng):
    s = _np(bp, "w_scales")
    t, k = _active_slot(bp)
    s[t, k] = np.nan
    return dataclasses.replace(bp, w_scales=s)


def _mut_scale_zero(bp, rng):
    s = _np(bp, "w_scales")
    t, k = _active_slot(bp)
    s[t, k] = 0.0  # silently drops a nonzero brick
    return dataclasses.replace(bp, w_scales=s)


def _mut_dtype(bp, rng):
    return dataclasses.replace(
        bp, w_comp=_np(bp, "w_comp").astype(np.float32)
    )


def _mut_minus_128(bp, rng):
    w = _np(bp, "w_comp")
    t, k = _active_slot(bp)
    w[t, k, 0, 0] = -128  # out of symmetric range AND breaks cell slicing
    return dataclasses.replace(bp, w_comp=w)


QUANT_MUTATIONS = [
    ("scale-shape", _mut_scale_shape, {"V110"}),
    ("scale-nan", _mut_scale_nan, {"V111"}),
    ("scale-zero-drops-brick", _mut_scale_zero, {"V112"}),
    ("quant-dtype", _mut_dtype, {"V113"}),
    ("minus-128-range-and-roundtrip", _mut_minus_128, {"V113", "V114"}),
]


@pytest.mark.parametrize(
    "name,mutate,expected",
    QUANT_MUTATIONS,
    ids=[m[0] for m in QUANT_MUTATIONS],
)
def test_quantized_mutation_flags_rule(prog_int8, name, mutate, expected):
    rng = np.random.default_rng(0)
    bp = mutate(prog_int8.convs[0].bp, rng)
    report = verify_bp(bp, layer="conv1")
    assert report.rules("error") == expected, report.format()


def test_fp32_nonfinite_weight(prog_fp32):
    bp = prog_fp32.convs[0].bp
    w = _np(bp, "w_comp")
    t, k = _active_slot(bp)
    w[t, k, 0, 0] = np.nan
    report = verify_bp(dataclasses.replace(bp, w_comp=w), layer="x")
    assert report.rules("error") == {"V115"}, report.format()


# ---------------------------------------------------------------------------
# layer/network/partition mutations
# ---------------------------------------------------------------------------


def test_pattern_bits_out_of_window(prog_fp32):
    conv0 = prog_fp32.convs[0]
    bits = np.array(conv0.pattern_bits)
    bits[0, 0] = 1 << (conv0.kernel * conv0.kernel)  # one past the window
    prog = dataclasses.replace(
        prog_fp32,
        convs=[dataclasses.replace(conv0, pattern_bits=bits)]
        + prog_fp32.convs[1:],
    )
    assert verify_network(prog).rules("error") == {"V202"}


def test_pattern_bits_shape(prog_fp32):
    conv0 = prog_fp32.convs[0]
    prog = dataclasses.replace(
        prog_fp32,
        convs=[dataclasses.replace(
            conv0, pattern_bits=np.array(conv0.pattern_bits)[:, :0]
        )] + prog_fp32.convs[1:],
    )
    assert verify_network(prog).rules("error") == {"V201"}


def test_bias_shape(prog_fp32):
    conv0 = prog_fp32.convs[0]
    prog = dataclasses.replace(
        prog_fp32,
        convs=[dataclasses.replace(conv0, bias=conv0.bias[:-1])]
        + prog_fp32.convs[1:],
    )
    assert verify_network(prog).rules("error") == {"V204"}


def test_layer_chain_break(prog_fp32):
    fc = dataclasses.replace(
        prog_fp32.fc,
        d_out=prog_fp32.fc.d_out + 1,
        bias=np.zeros(prog_fp32.fc.d_out + 1, np.float32),
    )
    prog = dataclasses.replace(prog_fp32, fc=fc)
    assert verify_network(prog).rules("error") == {"V301"}


def test_precision_contract(prog_fp32):
    prog = dataclasses.replace(prog_fp32, precision="int8")
    assert verify_network(prog).rules("error") == {"V302"}


def test_program_tile_disagreement(prog_fp32):
    prog = dataclasses.replace(prog_fp32, tile=8)
    assert verify_network(prog).rules("error") == {"V303"}


def test_partition_same_axis(prog_fp32):
    part = NetworkPartition(data=2, model=2, data_axis="x", model_axis="x")
    report = verify_partition(prog_fp32, part)
    assert report.rules("error") == {"V403"}
    with pytest.raises(VerificationError):
        partition_network(prog_fp32, data=2, model=2,
                          data_axis="x", model_axis="x")


def test_partition_nonpositive(prog_fp32):
    part = NetworkPartition(data=1, model=1)
    object.__setattr__(part, "model", 0)  # bypass __post_init__
    assert verify_partition(prog_fp32, part).rules("error") == {"V401"}


def test_partition_valid_passes(prog_fp32):
    prog = partition_network(prog_fp32, data=2, model=4)
    assert verify_network(prog).ok


def test_compile_network_verify_modes(pruned):
    cfg, params, bits = pruned
    ecfg = EngineConfig(block=16, tile=16)
    prog = compile_network(cfg, params, bits, ecfg=ecfg, verify="strict")
    assert verify_network(prog).ok
    compile_network(cfg, params, bits, ecfg=ecfg, verify="warn")
    with pytest.raises(ValueError, match="verify must be"):
        compile_network(cfg, params, bits, ecfg=ecfg, verify="bogus")


# ---------------------------------------------------------------------------
# searched-mapping mutations (V205/V206)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def prog_auto(pruned):
    cfg, params, bits = pruned
    return compile_network(cfg, params, bits,
                           ecfg=EngineConfig(block=16, tile=16),
                           optimize="auto")


def _with_mapping(prog, **kw):
    """First conv's mapping candidate with fields overridden."""
    conv0 = prog.convs[0]
    assert conv0.mapping is not None
    mapped = dataclasses.replace(conv0.mapping, **kw)
    conv0 = dataclasses.replace(conv0, mapping=mapped)
    return dataclasses.replace(prog, convs=[conv0] + prog.convs[1:])


def test_pristine_searched_program_verifies_clean(prog_auto):
    assert all(c.mapping is not None for c in prog_auto.convs)
    report = verify_network(prog_auto)
    assert report.ok, report.format()


MAPPING_MUTATIONS = [
    ("bad-block-order-tag", dict(block_order="bogus"), {"V205"}),
    ("bad-reorder-tag", dict(reorder="zigzag"), {"V205"}),
    ("non-positive-rows", dict(rows=0), {"V205"}),
    ("non-positive-ou-cols", dict(ou_cols=-8), {"V205"}),
    ("ou-taller-than-crossbar", dict(ou_rows=4096, rows=512), {"V206"}),
    ("ou-wider-than-crossbar", dict(ou_cols=4096, cols=512), {"V206"}),
    ("cells-exceed-row", dict(cells_per_weight=10**6), {"V206"}),
]


@pytest.mark.parametrize(
    "name,fields,expected",
    MAPPING_MUTATIONS,
    ids=[m[0] for m in MAPPING_MUTATIONS],
)
def test_mapping_mutation_flags_rule(prog_auto, name, fields, expected):
    prog = _with_mapping(prog_auto, **fields)
    report = verify_network(prog)
    assert report.rules("error") == expected, report.format()
    assert all(d.layer == "conv1" for d in report.errors)


def test_mapping_ou_cannot_hold_tallest_pattern(prog_auto):
    """ou_rows below the layer's tallest pattern block is unrealizable:
    pattern_ou_schedule never splits a block across OU row groups."""
    bits = np.asarray(prog_auto.convs[0].pattern_bits)
    max_h = int(pattern_sizes(bits)[bits != ALL_ZERO].max())
    assert max_h >= 2, "fixture needs a pattern taller than one row"
    prog = _with_mapping(prog_auto, ou_rows=max_h - 1)
    report = verify_network(prog)
    assert report.rules("error") == {"V206"}, report.format()


def test_mapping_int8_cell_slice_mismatch(pruned):
    cfg, params, bits = pruned
    prog = compile_network(cfg, params, bits,
                           ecfg=EngineConfig(block=16, tile=16),
                           precision="int8", optimize="auto")
    assert verify_network(prog).ok
    bad = _with_mapping(prog, cells_per_weight=1)
    report = verify_network(bad)
    assert report.rules("error") == {"V206"}, report.format()
    assert any("cell-slice" in d.message for d in report.errors)


def test_fc_reorder_bad_tag(prog_auto):
    fc = dataclasses.replace(prog_auto.fc, reorder="bogus")
    prog = dataclasses.replace(prog_auto, fc=fc)
    report = verify_network(prog)
    assert report.rules("error") == {"V205"}, report.format()
    assert all(d.layer == "fc" for d in report.errors)


def test_searched_program_full_pipeline_clean(prog_auto, tmp_path):
    """compile(optimize) -> partition -> save -> load -> verify, clean at
    every stage."""
    prog = partition_network(prog_auto, data=2, model=2)
    path = os.path.join(tmp_path, "prog_auto")
    serialize.save_program(path, prog)
    assert verify_saved(path).ok
    loaded = serialize.load_program(path)  # verify=True default
    assert verify_network(loaded).ok
    assert [c.mapping for c in loaded.convs] == \
        [c.mapping for c in prog_auto.convs]


# ---------------------------------------------------------------------------
# serialized programs: manifest statics + load-time verification
# ---------------------------------------------------------------------------


@pytest.fixture()
def saved(prog_int8, tmp_path):
    path = os.path.join(tmp_path, "prog")
    serialize.save_program(path, prog_int8)
    return path


def _manifest(path):
    with open(os.path.join(path, "program.json")) as f:
        return json.load(f)


def _rewrite(path, manifest):
    with open(os.path.join(path, "program.json"), "w") as f:
        json.dump(manifest, f)


def test_saved_pristine_roundtrip(saved):
    assert verify_saved(saved).ok
    prog = serialize.load_program(saved)  # verify=True default
    assert verify_network(prog).ok


@pytest.mark.parametrize(
    "corrupt,rule",
    [
        (lambda p: _rewrite(p, {**_manifest(p), "format_version": 99}),
         "M002"),
        (lambda p: _rewrite(
            p, {k: v for k, v in _manifest(p).items() if k != "fc"}
        ), "M003"),
        (lambda p: os.remove(os.path.join(p, "conv1.bias.npy")), "M004"),
        (lambda p: open(
            os.path.join(p, "program.json"), "w"
        ).write("{truncated"), "M001"),
        (lambda p: open(
            os.path.join(p, "fc.w_comp.npy"), "wb"
        ).write(b"not-an-npy"), "M005"),
    ],
    ids=["bad-version", "missing-key", "missing-payload", "truncated-json",
         "corrupt-payload"],
)
def test_corrupt_saved_program(saved, corrupt, rule):
    corrupt(saved)
    with pytest.raises(ProgramFormatError) as ei:
        serialize.load_program(saved)
    assert ei.value.rule == rule
    report = verify_saved(saved)
    assert report.rules("error") == {rule}, report.format()


def test_load_verifies_semantic_corruption(saved):
    # swap two permutation entries inside the stored payload: the file is
    # structurally valid (every M-rule passes) but semantically wrong
    fname = os.path.join(saved, "conv1.new_order.npy")
    order = np.load(fname)
    order[[0, 1]] = order[[1, 0]]
    np.save(fname, order)
    with pytest.raises(VerificationError) as ei:
        serialize.load_program(saved)
    assert "V102" in ei.value.report.rules("error")
    # opt-out still loads the raw payload
    prog = serialize.load_program(saved, verify=False)
    assert prog.convs
    assert verify_saved(saved).rules("error") == {"V102"}


# ---------------------------------------------------------------------------
# serialized mapping metadata (format v3)
# ---------------------------------------------------------------------------


@pytest.fixture()
def saved_auto(prog_auto, tmp_path):
    path = os.path.join(tmp_path, "prog_auto")
    serialize.save_program(path, prog_auto)
    return path


def _mutate_manifest(path, fn):
    m = _manifest(path)
    fn(m)
    _rewrite(path, m)


@pytest.mark.parametrize(
    "corrupt",
    [
        lambda m: m["convs"][0].__setitem__("mapping", "hybrid"),
        lambda m: m["convs"][0]["mapping"].pop("rows"),
        lambda m: m["convs"][0]["mapping"].__setitem__("block_order", 5),
        lambda m: m["convs"][0]["mapping"].__setitem__("rows", True),
        lambda m: m["fc"].__setitem__("reorder", 7),
    ],
    ids=["mapping-not-a-dict", "mapping-key-missing",
         "block-order-not-a-string", "rows-bool-not-int",
         "fc-reorder-not-a-string"],
)
def test_corrupt_mapping_manifest_is_structural(saved_auto, corrupt):
    _mutate_manifest(saved_auto, corrupt)
    with pytest.raises(ProgramFormatError) as ei:
        serialize.load_program(saved_auto)
    assert ei.value.rule == "M003"
    report = verify_saved(saved_auto)
    assert report.rules("error") == {"M003"}, report.format()


@pytest.mark.parametrize(
    "corrupt,rule",
    [
        (lambda m: m["convs"][0]["mapping"].__setitem__(
            "block_order", "bogus"), "V205"),
        (lambda m: m["convs"][0]["mapping"].__setitem__(
            "reorder", "zigzag"), "V205"),
        (lambda m: m["convs"][0]["mapping"].__setitem__(
            "ou_cols", 4096), "V206"),
    ],
    ids=["stored-bad-block-order", "stored-bad-reorder",
         "stored-ou-wider-than-crossbar"],
)
def test_corrupt_mapping_manifest_is_semantic(saved_auto, corrupt, rule):
    """A type-correct but invalid stored candidate passes the structural
    M-rules and is caught by the semantic verifier at load."""
    _mutate_manifest(saved_auto, corrupt)
    with pytest.raises(VerificationError) as ei:
        serialize.load_program(saved_auto)
    assert rule in ei.value.report.rules("error")
    report = verify_saved(saved_auto)
    assert report.rules("error") == {rule}, report.format()
    # opt-out still loads the raw payload
    assert serialize.load_program(saved_auto, verify=False).convs


# ---------------------------------------------------------------------------
# seeded random-site sweep (hypothesis-style corruption of one field)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_random_single_field_corruption_is_caught(prog_int8, seed):
    rng = np.random.default_rng(seed)
    bp = prog_int8.convs[0].bp
    family = rng.integers(4)
    if family == 0:  # corrupt a random permutation entry
        order = _np(bp, "new_order")
        order[rng.integers(len(order))] += 1
        bp = dataclasses.replace(bp, new_order=order % len(order))
        expect = {"V101", "V102"}
    elif family == 1:  # corrupt a random block id
        ids = _np(bp, "block_ids")
        t = rng.integers(ids.shape[0])
        ids[t, 0] = bp.k_in // bp.block + rng.integers(3)
        bp = dataclasses.replace(bp, block_ids=ids)
        expect = {"V105"}
    elif family == 2:  # shift a random nnz (row-group count)
        nnz = _np(bp, "nnz")
        nnz[rng.integers(len(nnz))] = -1 - rng.integers(3)
        bp = dataclasses.replace(bp, nnz=nnz)
        expect = {"V106"}
    else:  # zero a random active scale over a nonzero brick
        s = _np(bp, "w_scales")
        t, k = _active_slot(bp)
        s[t, k] = 0.0
        bp = dataclasses.replace(bp, w_scales=s)
        expect = {"V112"}
    report = verify_bp(bp, layer="conv1")
    assert report.rules("error") & expect, (
        f"seed {seed} family {family}: {report.format()}"
    )


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_verify(saved, capsys):
    from repro.analysis.__main__ import main

    assert main(["verify", saved]) == 0
    out = capsys.readouterr().out
    assert "clean" in out
    assert main(["verify", saved, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is True and doc["errors"] == 0

    fname = os.path.join(saved, "conv1.new_order.npy")
    order = np.load(fname)
    order[[0, 1]] = order[[1, 0]]
    np.save(fname, order)
    assert main(["verify", saved, "--json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["errors"] >= 1
    assert any(d["rule"] == "V102" for d in doc["diagnostics"])
