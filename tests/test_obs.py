"""obs/: span tracer (fake clock, ring buffer, Chrome export) + metrics
(exact percentiles, Prometheus rendering, registry isolation)."""

import json
import threading

import pytest

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
)
from repro.obs.trace import NULL_TRACER, Tracer, get_tracer, set_tracer


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


# ---------------------------------------------------------------- tracing


def test_span_nesting_and_timing_is_deterministic():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("outer", cat="compile", layers=3) as outer:
        clock.t += 1.0
        with tr.span("inner", cat="compile"):
            clock.t += 0.25
        clock.t += 0.5
    assert outer.dur == pytest.approx(1.75)
    spans = {s.name: s for s in tr.spans("compile")}
    # timestamps are relative to tracer creation, on the injected clock
    assert spans["outer"].ts == pytest.approx(0.0)
    assert spans["inner"].ts == pytest.approx(1.0)
    assert spans["inner"].dur == pytest.approx(0.25)
    assert spans["outer"].args == {"layers": 3}
    # the inner span nests inside the outer on the exported timeline
    assert (
        spans["outer"].ts <= spans["inner"].ts
        and spans["inner"].ts + spans["inner"].dur
        <= spans["outer"].ts + spans["outer"].dur
    )


def test_span_closes_and_flags_on_exception():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    (s,) = tr.spans()
    assert s.dur is not None and s.args["error"] is True


def test_chrome_export_schema():
    clock = FakeClock()
    tr = Tracer(clock=clock, pid=7, process_name="test-proc")
    with tr.span("work", cat="execute"):
        clock.t += 0.002
    tr.instant("mark", cat="execute")
    tr.counter("depth", queued=3)
    tr.async_begin("req", 42, cat="request")
    tr.async_end("req", 42, cat="request")
    doc = tr.to_chrome()
    events = doc["traceEvents"]
    # every event carries the trace-event schema fields
    for e in events:
        assert {"ph", "ts", "pid", "tid", "name"} <= set(e)
        assert e["pid"] == 7
    meta = [e for e in events if e["ph"] == "M"]
    assert any(e["args"]["name"] == "test-proc" for e in meta)
    (x,) = [e for e in events if e["ph"] == "X"]
    assert x["name"] == "work" and x["cat"] == "execute"
    assert x["dur"] == pytest.approx(2000.0)  # 2 ms in microseconds
    assert [e["args"] for e in events if e["ph"] == "C"] == [{"queued": 3.0}]
    pair = [e for e in events if e["ph"] in "be"]
    assert [e["ph"] for e in pair] == ["b", "e"]
    assert all(e["id"] == 42 for e in pair)
    # the whole document round-trips through JSON
    assert json.loads(json.dumps(doc)) == doc


def test_ring_buffer_bounds_and_counts_drops():
    tr = Tracer(clock=FakeClock(), max_events=5)
    for i in range(12):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 5
    assert tr.dropped_events == 7
    assert tr.to_chrome()["otherData"]["dropped_events"] == 7
    # the newest events survive
    assert [e["name"] for e in tr.events()] == [f"e{i}" for i in range(7, 12)]
    tr.reset()
    assert tr.events() == [] and tr.dropped_events == 0


def test_disabled_tracer_is_free_and_recordless():
    clock = FakeClock()
    tr = Tracer(clock=clock, enabled=False)
    with tr.span("x") as sp:
        clock.t += 5.0
    tr.instant("i")
    tr.counter("c", v=1)
    tr.async_begin("a", 1)
    assert sp.dur == 0.0  # the shared null span, untouched
    assert tr.events() == []
    assert NULL_TRACER.events() == []


def test_default_tracer_install_and_clear():
    tr = Tracer(clock=FakeClock())
    assert get_tracer() is NULL_TRACER
    try:
        assert set_tracer(tr) is tr and get_tracer() is tr
    finally:
        set_tracer(None)
    assert get_tracer() is NULL_TRACER


def test_tracer_is_thread_safe_and_names_threads():
    tr = Tracer()  # real clock: only counts matter here
    barrier = threading.Barrier(4)  # force all workers to overlap

    def work():
        barrier.wait(timeout=10)
        for _ in range(200):
            with tr.span("w"):
                pass

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    with tr.span("main"):
        pass
    for t in threads:
        t.join()
    assert len(tr.spans()) == 4 * 200 + 1
    tids = {e["tid"] for e in tr.events()}
    assert len(tids) == 5  # stable small tids, one per thread
    names = [
        e for e in tr.to_chrome()["traceEvents"] if e["name"] == "thread_name"
    ]
    assert len(names) == 5


def test_slowest_aggregates_by_name():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    for dur in (0.1, 0.1, 0.1):  # layer:a total 0.3
        with tr.span("layer:a", cat="execute"):
            clock.t += dur
    with tr.span("layer:b", cat="execute"):
        clock.t += 0.25
    with tr.span("other", cat="execute"):
        clock.t += 9.0
    top = tr.slowest(2, cat="execute", prefix="layer:")
    assert [n for n, _ in top] == ["layer:a", "layer:b"]
    assert top[0][1] == pytest.approx(0.3)


# ---------------------------------------------------------------- metrics


def test_histogram_percentiles_are_exact():
    h = Histogram(buckets=(1.0, 10.0, 100.0))
    for v in range(100, 0, -1):  # insertion order must not matter
        h.observe(float(v))
    assert h.percentile(50) == 50.0
    assert h.percentile(95) == 95.0
    assert h.percentile(99) == 99.0
    assert h.percentile(100) == 100.0
    assert h.count == 100 and h.sum == pytest.approx(5050.0)
    assert h.mean == pytest.approx(50.5)
    snap = h.snapshot()
    assert snap["p50"] == 50.0 and snap["p99"] == 99.0
    # cumulative buckets: le=1 -> 1 sample, le=10 -> 10, le=100 -> all
    assert snap["buckets"] == [[1.0, 1], [10.0, 10], [100.0, 100]]


def test_histogram_sample_ring_is_bounded():
    h = Histogram(buckets=(1e9,), max_samples=10)
    for v in range(1, 101):
        h.observe(float(v))
    # count/sum see everything; percentiles see the newest window
    assert h.count == 100
    assert h.percentile(50) == 95.0  # exact over 91..100
    assert h.percentile(100) == 100.0


def test_counter_and_gauge_basics():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    g = Gauge()
    g.set(10)
    g.inc(5)
    g.dec(2)
    assert g.value == pytest.approx(13.0)
    assert g.prom_lines("depth") == ["# TYPE depth gauge", "depth 13"]


def test_histogram_edge_cases():
    h = Histogram()
    assert h.percentile(99) == 0.0  # empty
    with pytest.raises(ValueError):
        h.percentile(0)
    with pytest.raises(ValueError):
        h.percentile(101)
    with pytest.raises(ValueError):
        Histogram(buckets=(2.0, 1.0))  # unsorted
    with pytest.raises(ValueError):
        Counter().inc(-1)


def test_prometheus_exposition():
    h = Histogram(buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    lines = h.prom_lines("lat_seconds")
    assert lines[0] == "# TYPE lat_seconds histogram"
    assert 'lat_seconds_bucket{le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{le="1"} 2' in lines
    assert 'lat_seconds_bucket{le="+Inf"} 3' in lines
    assert "lat_seconds_count 3" in lines


def test_registry_get_or_create_and_kind_conflicts():
    r = MetricsRegistry()
    c = r.counter("requests")
    c.inc(3)
    assert r.counter("requests") is c  # same object back
    with pytest.raises(ValueError):
        r.gauge("requests")  # kind conflict
    g = r.gauge("depth")
    g.set(4)
    r.histogram("lat", buckets=(1.0,)).observe(0.5)
    snap = r.snapshot()
    assert snap["requests"] == {"kind": "counter", "value": 3.0}
    assert snap["depth"] == {"kind": "gauge", "value": 4.0}
    assert snap["lat"]["value"]["count"] == 1
    text = r.to_prometheus()
    assert "requests 3" in text and "depth 4" in text
    # non-prometheus characters in names are sanitized in the rendering
    r.counter("scheduler/queue.depth").inc()
    assert "scheduler_queue_depth 1" in r.to_prometheus()


def test_meter_windowed_rate():
    from repro.obs.metrics import Meter

    t = {"now": 0.0}
    m = Meter(window_s=10.0, clock=lambda: t["now"])
    assert m.rate == 0.0 and m.total == 0.0
    m.mark(5)
    t["now"] = 2.0
    assert m.total == 5.0
    assert m.rate == pytest.approx(5.0 / 2.0)  # over the elapsed span
    m.mark(5)
    t["now"] = 4.0
    assert m.rate == pytest.approx(10.0 / 4.0)
    # events older than the window fall out of the rate, not the total
    t["now"] = 20.0
    assert m.rate == 0.0
    assert m.total == 10.0
    lines = m.prom_lines("serve_requests")
    assert "serve_requests_total 10" in lines
    with pytest.raises(ValueError):
        m.mark(-1)
    with pytest.raises(ValueError):
        Meter(window_s=0)


def test_meter_in_registry():
    r = MetricsRegistry()
    m = r.meter("reqs", window_s=5.0)
    assert r.meter("reqs") is m
    with pytest.raises(ValueError):
        r.counter("reqs")  # kind conflict
    m.mark(3)
    assert r.snapshot()["reqs"]["kind"] == "meter"
    assert r.snapshot()["reqs"]["value"]["total"] == 3.0
    assert "reqs_total 3" in r.to_prometheus()


def test_global_registry_reset_isolation():
    reg = get_registry()
    reg.reset()
    reg.counter("leaky").inc(7)
    assert reg.names() == ["leaky"]
    reg.reset()
    assert reg.names() == []
    # a fresh counter under the same name starts from zero
    assert reg.counter("leaky").value == 0.0
    reg.reset()
