"""Accelerator simulator (paper §V) — regression vs the paper's claims."""

import numpy as np
import pytest

from repro.core.crossbar import EnergyModel
from repro.core.simulator import simulate_dataset
from repro.core.synthetic import (
    TABLE_II,
    network_sparsity,
    network_zero_pattern_ratio,
    synthesize_network,
)


@pytest.mark.parametrize("dataset", ["cifar10", "cifar100", "imagenet"])
def test_synthetic_matches_table2(dataset):
    stats, layers = synthesize_network(dataset, seed=0)
    assert abs(network_sparsity(layers) - stats.sparsity) < 0.01
    assert abs(network_zero_pattern_ratio(layers) - stats.zero_pattern_ratio) < 0.02
    for layer, n_pat in zip(layers, stats.patterns_per_layer):
        assert layer.pdict.num_patterns <= max(n_pat, 2)


def test_energy_model_constants():
    e = EnergyModel()
    # Table I: one full OU = 4.8 + 8*1.67 + 9*0.0182 pJ
    expect = 4.8 + 8 * 1.67 + 9 * 0.0182
    assert abs(float(e.ou_energy(9, 8)) - expect) < 1e-9


@pytest.mark.slow
def test_cifar10_reproduces_paper_ranges():
    """Headline claims (§V-C): area 4.16-5.20x, energy 1.98-2.15x,
    speedup 1.15-1.35x.  Synthetic-statistics reproduction bands are
    wider (the true checkpoints are unavailable): we assert the same
    regime, not the third decimal."""
    rep = simulate_dataset("cifar10", seed=0)
    s = rep.summary()
    assert 3.0 <= s["area_efficiency"] <= 6.5
    assert 1.5 <= s["energy_efficiency"] <= 3.5
    assert 1.0 <= s["speedup"] <= 2.0
    # ADC energy dominates (paper Fig 8 discussion)
    bd = rep.breakdown("ours")
    assert bd["adc_pj"] > bd["array_pj"] > bd["dac_pj"]


def test_input_skip_is_lossless(rng):
    """All-zero input OU skipping changes no numerics (it only skips
    products that are zero) — checked via the ou_mvm kernel elsewhere;
    here: the simulator's skip fraction is within [0,1] and larger for
    smaller patterns."""
    rep = simulate_dataset("cifar10", seed=1)
    for layer in rep.layers:
        assert layer.ours_energy_pj >= 0
        assert layer.naive_energy_pj >= layer.ours_energy_pj * 0.8
