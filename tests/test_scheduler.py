"""SlotScheduler: refill order, backpressure, metrics (deterministic clock)."""

import numpy as np
import pytest

from repro.engine.scheduler import SchedulerFull, SlotScheduler


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_refill_is_fifo_into_lowest_slots():
    s = SlotScheduler(batch_slots=3)
    for name in "abcde":
        s.submit(name)
    admitted = s.refill()
    assert admitted == [(0, "a"), (1, "b"), (2, "c")]
    assert s.queued() == 2
    np.testing.assert_array_equal(s.valid_mask(), [True, True, True])

    # freeing the middle slot refills it with the next queued request
    assert s.complete(1) == "b"
    np.testing.assert_array_equal(s.valid_mask(), [True, False, True])
    assert s.refill() == [(1, "d")]
    assert s.live() == [(0, "a"), (1, "d"), (2, "c")]

    # drain everything
    for slot, _ in list(s.live()):
        s.complete(slot)
    assert s.refill() == [(0, "e")]
    s.complete(0)
    assert not s.has_work()
    assert s.refill() == []


def test_backpressure_bounded_queue():
    s = SlotScheduler(batch_slots=2, max_queue=2)
    assert s.has_capacity()
    assert s.try_submit("a") and s.try_submit("b")
    assert not s.has_capacity()
    assert s.metrics.rejected == 0  # the probe counts nothing
    assert not s.try_submit("c")  # queue full
    with pytest.raises(SchedulerFull):
        s.submit("d")
    assert s.metrics.rejected == 2
    assert s.metrics.enqueued == 2
    # admitted requests free queue capacity
    s.refill()
    assert s.has_capacity() and s.try_submit("c")
    # an unbounded queue never rejects
    u = SlotScheduler(batch_slots=1)
    for i in range(100):
        u.submit(i)
    assert u.metrics.rejected == 0 and u.queued() == 100


def test_latency_and_occupancy_metrics():
    clock = FakeClock()
    s = SlotScheduler(batch_slots=4, clock=clock)
    s.submit("a")  # enqueued at t=0
    clock.t = 1.0
    s.submit("b")  # enqueued at t=1
    s.refill()
    s.record_step()  # 2 live of 4
    clock.t = 3.0
    s.complete(0)  # a: 3.0 - 0.0
    s.complete(1)  # b: 3.0 - 1.0
    s.submit("c")
    s.refill()
    s.record_step()  # 1 live of 4
    clock.t = 4.0
    s.complete(0)  # c: 4.0 - 3.0

    m = s.metrics
    assert m.completed == 3 and m.steps == 2
    assert m.latency_max == pytest.approx(3.0)
    assert m.latency_mean == pytest.approx((3.0 + 2.0 + 1.0) / 3)
    assert m.occupancy_mean == pytest.approx((2 + 1) / (2 * 4))
    snap = m.snapshot()
    assert snap["latency_max_s"] == pytest.approx(3.0)
    assert snap["batch_slots"] == 4


def test_invalid_arguments_and_states():
    with pytest.raises(ValueError):
        SlotScheduler(batch_slots=0)
    with pytest.raises(ValueError):
        SlotScheduler(batch_slots=1, max_queue=-1)
    s = SlotScheduler(batch_slots=2)
    with pytest.raises(ValueError, match="not occupied"):
        s.complete(0)


def test_empty_scheduler_metrics_are_zero():
    m = SlotScheduler(batch_slots=4).metrics
    assert m.occupancy_mean == 0.0 and m.latency_mean == 0.0


def test_reset_metrics_opens_fresh_window():
    """A warm-up batch can be dropped from the metrics; in-flight
    requests are re-anchored to the reset instant (here the reset
    happens at the enqueue time, so the measured latency is unchanged)."""
    clock = FakeClock()
    s = SlotScheduler(batch_slots=2, clock=clock)
    s.submit("warm")
    s.refill()
    s.record_step()
    s.complete(0)
    s.submit("real")  # enqueued at t=0, completes after the reset
    s.refill()
    s.reset_metrics()
    assert s.metrics.steps == 0 and s.metrics.completed == 0
    s.record_step()
    clock.t = 2.0
    s.complete(0)
    m = s.metrics
    assert m.completed == 1 and m.steps == 1
    assert m.latency_mean == pytest.approx(2.0)  # measured from enqueue
    assert m.occupancy_mean == pytest.approx(0.5)


def test_reset_metrics_reanchors_in_flight_requests():
    """Regression: reset_metrics used to leave live slots' enqueue
    timestamps pointing into the previous window, so a request admitted
    long before the reset polluted the fresh window with its whole
    pre-reset wait.  Live entries are re-anchored to the reset instant."""
    clock = FakeClock()
    s = SlotScheduler(batch_slots=1, clock=clock)
    s.submit("r")  # enqueued at t=0
    s.refill()
    clock.t = 5.0
    s.reset_metrics()  # request has been in flight for 5s already
    clock.t = 6.0
    s.complete(0)
    m = s.metrics
    # only the post-reset second lands in the fresh window, not 6.0
    assert m.latency_mean == pytest.approx(1.0)
    assert m.latency_max == pytest.approx(1.0)
    assert m.in_flight_mean == pytest.approx(1.0)
    assert m.latency_hist.count == 1


def test_latency_percentiles_and_wait_breakdown():
    """Histogram-backed p50/p99 are exact, and enqueue->done splits into
    queue wait (enqueue->admit) plus in-flight (admit->done)."""
    clock = FakeClock()
    s = SlotScheduler(batch_slots=1, clock=clock)
    # request i: enqueued at t, admitted 1s later, completes i s after
    for i in range(1, 101):
        t0 = clock.t
        s.submit(i)
        clock.t = t0 + 1.0
        s.refill()
        clock.t = t0 + 1.0 + float(i)
        s.complete(0)
    m = s.metrics
    assert m.latency_p50 == pytest.approx(51.0)  # 1 + 50
    assert m.latency_p99 == pytest.approx(100.0)  # 1 + 99
    assert m.queue_wait_mean == pytest.approx(1.0)
    assert m.in_flight_mean == pytest.approx(50.5)
    assert m.latency_mean == pytest.approx(
        m.queue_wait_mean + m.in_flight_mean
    )
    snap = m.snapshot()
    assert snap["latency_p50_s"] == pytest.approx(51.0)
    assert snap["latency_p99_s"] == pytest.approx(100.0)
    assert snap["queue_wait_mean_s"] == pytest.approx(1.0)
    assert snap["queue_wait_p99_s"] == pytest.approx(1.0)
    assert snap["in_flight_mean_s"] == pytest.approx(50.5)
    assert snap["admitted"] == 100
    text = m.to_prometheus(prefix="test_sched")
    assert "test_sched_completed_total 100" in text
    assert "test_sched_latency_seconds_count 100" in text


def test_first_result_latency_and_complete_fallback():
    clock = FakeClock()
    s = SlotScheduler(batch_slots=2, clock=clock)
    s.submit("a")  # enqueued at t=0
    clock.t = 1.0
    s.refill()
    clock.t = 3.0
    s.record_first_result(0)  # first usable output: 3.0 after enqueue
    s.record_first_result(0)  # idempotent per occupancy
    clock.t = 5.0
    s.complete(0)
    m = s.metrics
    assert m.first_results == 1
    assert m.first_result_mean == pytest.approx(3.0)
    assert m.latency_mean == pytest.approx(5.0)  # completion unaffected
    # single-step workloads never call record_first_result: complete()
    # records the fallback so the SLO series is populated either way
    s.submit("b")  # t=5
    s.refill()
    clock.t = 6.5
    s.complete(0)
    assert s.metrics.first_results == 2
    assert s.metrics.first_result_sum == pytest.approx(3.0 + 1.5)
    snap = s.snapshot()
    assert snap["first_result_mean_s"] == pytest.approx(2.25)
    assert snap["first_result_p99_s"] == pytest.approx(3.0)
    text = s.metrics.to_prometheus(prefix="svc")
    assert "svc_first_result_seconds_count 2" in text


def test_retry_after_hint_tracks_backpressure():
    clock = FakeClock()
    s = SlotScheduler(batch_slots=2, clock=clock)
    base = s.retry_after_hint()  # pre-traffic fallback, still positive
    assert 0 < base <= 60.0
    for i in range(6):
        s.submit(i)
    assert s.retry_after_hint() > base  # deeper queue -> longer hint
    # once steps have run, the hint uses the measured step cadence
    s.refill()
    s.record_step()
    clock.t = 0.2
    s.record_step()  # inter-step wall time: 0.2s
    # 4 queued + the retrying request = ceil(5/2) = 3 waves x 0.2s
    assert s.retry_after_hint() == pytest.approx(3 * 0.2)


def test_resubmit_is_a_priority_lane():
    s = SlotScheduler(batch_slots=1, max_queue=1)
    s.submit("a")
    assert not s.try_submit("b")  # bounded queue is full
    s.resubmit("replay")  # admitted work bypasses max_queue...
    assert s.queued() == 2
    assert s.refill() == [(0, "replay")]  # ...and jumps the line
    s.complete(0)
    assert s.refill() == [(0, "a")]
    s.complete(0)


def test_scheduler_thread_safe_under_concurrent_load():
    """Producers try_submit from several threads while a consumer
    refills/steps/completes and a scraper snapshots: bookkeeping must
    conserve every request (this is the HTTP server's exact topology:
    event-loop admission + worker stepping + /metrics scraping)."""
    import threading

    s = SlotScheduler(batch_slots=4)
    n_threads, per_thread = 4, 200
    total = n_threads * per_thread
    errors, done = [], []
    stop_scraper = threading.Event()

    def producer(base):
        try:
            for i in range(per_thread):
                s.submit((base, i))
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    def consumer():
        try:
            import time as _t
            deadline = _t.monotonic() + 60
            while len(done) < total and _t.monotonic() < deadline:
                s.refill()
                if s.live():
                    s.record_step()
                    for slot, _item in list(s.live()):
                        done.append(s.complete(slot))
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    def scraper():
        try:
            while not stop_scraper.is_set():
                snap = s.snapshot()
                assert snap["enqueued"] >= snap["completed"]
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [
        threading.Thread(target=producer, args=(b,)) for b in range(n_threads)
    ] + [threading.Thread(target=consumer), threading.Thread(target=scraper)]
    for t in threads[:-1]:
        t.start()
    threads[-1].start()
    for t in threads[:-1]:
        t.join(timeout=120)
    stop_scraper.set()
    threads[-1].join(timeout=10)

    assert not errors, errors
    assert len(done) == total
    assert sorted(done) == sorted(
        (b, i) for b in range(n_threads) for i in range(per_thread)
    )
    m = s.metrics
    assert m.enqueued == m.completed == total
    assert s.queued() == 0 and not s.live()


def test_scheduler_emits_request_lifecycle_spans():
    """With a tracer, each request becomes an async begin/admit/end trio
    and queue depth / live slots land as counter tracks."""
    from repro.obs.trace import Tracer

    clock = FakeClock()
    tr = Tracer(clock=clock)
    s = SlotScheduler(batch_slots=2, max_queue=2, clock=clock, tracer=tr)
    s.submit("a")
    s.submit("b")
    assert not s.try_submit("c")  # rejected: instant event, no lifecycle
    s.refill()
    s.record_step()
    clock.t = 1.0
    s.complete(0)
    s.complete(1)
    ev = tr.events()
    begins = [e for e in ev if e["ph"] == "b" and e["cat"] == "request"]
    admits = [
        e for e in ev
        if e["ph"] == "n" and e["cat"] == "request"
        and e["args"].get("event") == "admit"
    ]
    ends = [e for e in ev if e["ph"] == "e" and e["cat"] == "request"]
    assert len(begins) == len(admits) == len(ends) == 2
    # lifecycles are keyed so Perfetto can pair them up
    assert {e["id"] for e in begins} == {e["id"] for e in ends}
    assert [e["args"]["slot"] for e in admits] == [0, 1]
    assert any(e["ph"] == "i" and e["name"] == "request_rejected"
               for e in ev)
    counters = [e for e in ev if e["ph"] == "C"]
    assert {"scheduler/queue_depth", "scheduler/slots_live"} <= {
        e["name"] for e in counters
    }
    # untraced schedulers pay nothing: the shared no-op tracer records 0
    s2 = SlotScheduler(batch_slots=1, clock=clock)
    s2.submit("x")
    s2.refill()
    s2.complete(0)
    from repro.obs.trace import NULL_TRACER

    assert NULL_TRACER.events() == []
