"""SlotScheduler: refill order, backpressure, metrics (deterministic clock)."""

import numpy as np
import pytest

from repro.engine.scheduler import SchedulerFull, SlotScheduler


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_refill_is_fifo_into_lowest_slots():
    s = SlotScheduler(batch_slots=3)
    for name in "abcde":
        s.submit(name)
    admitted = s.refill()
    assert admitted == [(0, "a"), (1, "b"), (2, "c")]
    assert s.queued() == 2
    np.testing.assert_array_equal(s.valid_mask(), [True, True, True])

    # freeing the middle slot refills it with the next queued request
    assert s.complete(1) == "b"
    np.testing.assert_array_equal(s.valid_mask(), [True, False, True])
    assert s.refill() == [(1, "d")]
    assert s.live() == [(0, "a"), (1, "d"), (2, "c")]

    # drain everything
    for slot, _ in list(s.live()):
        s.complete(slot)
    assert s.refill() == [(0, "e")]
    s.complete(0)
    assert not s.has_work()
    assert s.refill() == []


def test_backpressure_bounded_queue():
    s = SlotScheduler(batch_slots=2, max_queue=2)
    assert s.has_capacity()
    assert s.try_submit("a") and s.try_submit("b")
    assert not s.has_capacity()
    assert s.metrics.rejected == 0  # the probe counts nothing
    assert not s.try_submit("c")  # queue full
    with pytest.raises(SchedulerFull):
        s.submit("d")
    assert s.metrics.rejected == 2
    assert s.metrics.enqueued == 2
    # admitted requests free queue capacity
    s.refill()
    assert s.has_capacity() and s.try_submit("c")
    # an unbounded queue never rejects
    u = SlotScheduler(batch_slots=1)
    for i in range(100):
        u.submit(i)
    assert u.metrics.rejected == 0 and u.queued() == 100


def test_latency_and_occupancy_metrics():
    clock = FakeClock()
    s = SlotScheduler(batch_slots=4, clock=clock)
    s.submit("a")  # enqueued at t=0
    clock.t = 1.0
    s.submit("b")  # enqueued at t=1
    s.refill()
    s.record_step()  # 2 live of 4
    clock.t = 3.0
    s.complete(0)  # a: 3.0 - 0.0
    s.complete(1)  # b: 3.0 - 1.0
    s.submit("c")
    s.refill()
    s.record_step()  # 1 live of 4
    clock.t = 4.0
    s.complete(0)  # c: 4.0 - 3.0

    m = s.metrics
    assert m.completed == 3 and m.steps == 2
    assert m.latency_max == pytest.approx(3.0)
    assert m.latency_mean == pytest.approx((3.0 + 2.0 + 1.0) / 3)
    assert m.occupancy_mean == pytest.approx((2 + 1) / (2 * 4))
    snap = m.snapshot()
    assert snap["latency_max_s"] == pytest.approx(3.0)
    assert snap["batch_slots"] == 4


def test_invalid_arguments_and_states():
    with pytest.raises(ValueError):
        SlotScheduler(batch_slots=0)
    with pytest.raises(ValueError):
        SlotScheduler(batch_slots=1, max_queue=-1)
    s = SlotScheduler(batch_slots=2)
    with pytest.raises(ValueError, match="not occupied"):
        s.complete(0)


def test_empty_scheduler_metrics_are_zero():
    m = SlotScheduler(batch_slots=4).metrics
    assert m.occupancy_mean == 0.0 and m.latency_mean == 0.0


def test_reset_metrics_opens_fresh_window():
    """A warm-up batch can be dropped from the metrics; in-flight
    requests keep their enqueue times across the reset."""
    clock = FakeClock()
    s = SlotScheduler(batch_slots=2, clock=clock)
    s.submit("warm")
    s.refill()
    s.record_step()
    s.complete(0)
    s.submit("real")  # enqueued at t=0, completes after the reset
    s.refill()
    s.reset_metrics()
    assert s.metrics.steps == 0 and s.metrics.completed == 0
    s.record_step()
    clock.t = 2.0
    s.complete(0)
    m = s.metrics
    assert m.completed == 1 and m.steps == 1
    assert m.latency_mean == pytest.approx(2.0)  # measured from enqueue
    assert m.occupancy_mean == pytest.approx(0.5)
