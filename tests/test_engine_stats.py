"""Measured activation-skip statistics: counters vs numpy reference,
aggregation, and energy pricing monotonicity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.simulator import SkipDistribution
from repro.engine import (
    InferenceService,
    compile_network,
    make_forward,
    skip_patterns_and_masks,
)
from repro.engine.executor import zero_selection_counts
from repro.engine.stats import ActivationStats, LayerSkipStats
from repro.models.cnn import (
    conv_weight_names,
    init_cnn,
    mini_cnn_config,
)
from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)


def _reference_counts(patches: np.ndarray, c_in: int, kk: int,
                      masks: np.ndarray) -> np.ndarray:
    """Independent numpy double-loop: all-zero selections per (c, p)."""
    m = patches.shape[0]
    z = (patches.reshape(m, c_in, kk) == 0.0)
    counts = np.zeros((c_in, masks.shape[0]), np.int64)
    for c in range(c_in):
        for i, mask in enumerate(masks):
            pos = np.nonzero(mask)[0]
            if pos.size == 0:
                counts[c, i] = m  # all-zero pattern: vacuously skippable
            else:
                counts[c, i] = int(np.all(z[:, c, pos], axis=1).sum())
    return counts


@pytest.fixture(scope="module")
def mini():
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    return cfg, params, bits, compile_network(cfg, params, bits)


MASKS = np.array([
    [1, 1, 0, 0, 1, 0, 0, 0, 0],
    [0, 0, 0, 0, 0, 0, 0, 1, 1],
    [1, 1, 1, 1, 1, 1, 1, 1, 1],
    [0, 0, 0, 0, 0, 0, 0, 0, 0],  # the all-zero pattern
], bool)


@pytest.mark.parametrize("case", ["zero_columns", "dense", "mixed"])
def test_counts_match_numpy_reference_on_crafted_activations(case, rng):
    """The jitted counter equals the double-loop reference on crafted
    inputs: whole channels zero, fully dense, and a random zero mixture."""
    m, c_in, kk = 64, 5, 9
    if case == "zero_columns":
        a = rng.normal(size=(m, c_in, kk)).astype(np.float32)
        a[np.abs(a) < 0.05] = 0.0
        a[:, 1, :] = 0.0  # an all-zero channel: every selection skips
        a[:, 3, :5] = 0.0  # partial: skips only patterns inside taps 0..4
    elif case == "dense":
        a = rng.normal(size=(m, c_in, kk)).astype(np.float32)
        a[a == 0.0] = 1.0  # no zeros: only the all-zero pattern skips
    else:
        a = rng.normal(size=(m, c_in, kk)).astype(np.float32)
        a[rng.random(size=a.shape) < 0.6] = 0.0
    patches = a.reshape(m, c_in * kk)
    got = np.asarray(
        jax.jit(
            lambda p: zero_selection_counts(p, c_in, kk, MASKS)
        )(jnp.asarray(patches))
    )
    expect = _reference_counts(patches, c_in, kk, MASKS)
    np.testing.assert_array_equal(got, expect)
    if case == "zero_columns":
        assert (got[1] == m).all()  # the dead channel always skips
    if case == "dense":
        # only the all-zero pattern (row 3 of MASKS) is skippable
        assert (got[:, :3] == 0).all() and (got[:, 3] == m).all()


def test_forward_stats_match_reference_on_first_layer(mini, rng):
    """End-to-end: the executor's conv1 counters equal the reference
    computed from an independent numpy im2col of the same input."""
    cfg, params, bits, prog = mini
    x = rng.normal(size=(3, 1, 12, 12)).astype(np.float32)
    x[np.abs(x) < 0.3] = 0.0  # plant real zeros in the input image
    logits, stats = make_forward(prog, backend="xla", collect_stats=True)(
        jnp.asarray(x)
    )
    op = prog.convs[0]
    kk = op.kernel * op.kernel
    patterns, masks = skip_patterns_and_masks(op.pattern_bits, kk)
    assert stats.layers["conv1"].patterns == patterns

    # independent im2col (stride-1 'same'), layout c*kk + (dy*k + dx)
    b, c, h, w = x.shape
    k, pad = op.kernel, op.kernel // 2
    xp = np.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    taps = np.stack(
        [xp[:, :, dy:dy + h, dx:dx + w] for dy in range(k) for dx in range(k)],
        axis=-1,
    )  # [B, C, H, W, kk]
    patches = taps.transpose(0, 2, 3, 1, 4).reshape(b * h * w, c * kk)
    expect = _reference_counts(patches, c, kk, masks)

    st = stats.layers["conv1"]
    np.testing.assert_array_equal(st.counts, expect)
    assert st.windows == b * h * w
    # logits unchanged by the instrumentation
    ref = make_forward(prog, backend="xla")(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))


def test_backends_agree_on_counts(mini):
    cfg, params, bits, prog = mini
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 1, 12, 12))
    _, s_xla = make_forward(prog, backend="xla", collect_stats=True)(x)
    _, s_pal = make_forward(
        prog, backend="pallas", interpret=True, collect_stats=True
    )(x)
    for name in s_xla.layers:
        np.testing.assert_array_equal(
            s_xla.layers[name].counts, s_pal.layers[name].counts
        )


def test_stats_merge_accumulates(mini):
    cfg, params, bits, prog = mini
    fwd = make_forward(prog, backend="xla", collect_stats=True)
    xa = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 12, 12))
    xb = jax.random.normal(jax.random.PRNGKey(2), (3, 1, 12, 12))
    _, sa = fwd(xa)
    _, sb = fwd(xb)
    merged = sa.merge(sb)
    _, sab = fwd(jnp.concatenate([xa, xb]))
    # per-sample channel_norm makes every layer's counts independent of
    # batch composition, so two merged batches equal the concatenated one
    for name in sab.layers:
        assert merged.layers[name].windows == sab.layers[name].windows
        np.testing.assert_array_equal(
            merged.layers[name].counts, sab.layers[name].counts
        )


def test_counts_over_row_shards_sum_to_global(rng):
    """The per-device counter is additive over batch-row shards — the
    invariant the sharded executor's psum over the data axis relies on."""
    m, c_in, kk, shards = 64, 5, 9, 4
    a = rng.normal(size=(m, c_in, kk)).astype(np.float32)
    a[rng.random(size=a.shape) < 0.5] = 0.0
    patches = a.reshape(m, c_in * kk)
    total = np.asarray(
        zero_selection_counts(jnp.asarray(patches), c_in, kk, MASKS)
    )
    per_shard = [
        np.asarray(zero_selection_counts(jnp.asarray(chunk), c_in, kk, MASKS))
        for chunk in np.split(patches, shards)
    ]
    np.testing.assert_array_equal(sum(per_shard), total)


def test_stats_merge_over_device_shards_equals_global(rng):
    """ActivationStats.merge over per-device shard stats == the global
    count (windows and counters) — the host-side equivalent of the psum."""
    m, c_in, kk, shards = 64, 3, 9, 4
    a = rng.normal(size=(m, c_in, kk)).astype(np.float32)
    a[rng.random(size=a.shape) < 0.5] = 0.0
    patches = a.reshape(m, c_in * kk)
    patterns = (0, 19, 274, 511)

    def stats_of(rows: np.ndarray) -> ActivationStats:
        counts = np.asarray(
            zero_selection_counts(jnp.asarray(rows), c_in, kk, MASKS)
        ).astype(np.int64)
        return ActivationStats(layers={"conv1": LayerSkipStats(
            name="conv1", kernel_size=kk, patterns=patterns,
            windows=rows.shape[0], counts=counts,
        )})

    merged = stats_of(np.split(patches, shards)[0])
    for chunk in np.split(patches, shards)[1:]:
        merged = merged.merge(stats_of(chunk))
    glob = stats_of(patches)
    assert merged.layers["conv1"].windows == glob.layers["conv1"].windows == m
    np.testing.assert_array_equal(
        merged.layers["conv1"].counts, glob.layers["conv1"].counts
    )
    assert merged.mean_skip() == pytest.approx(glob.mean_skip())


def test_service_accumulates_stats(mini):
    cfg, params, bits, prog = mini
    svc = InferenceService(prog, batch_slots=4, backend="xla",
                           collect_stats=True)
    imgs = np.asarray(
        jax.random.normal(jax.random.PRNGKey(11), (10, 1, 12, 12)),
        np.float32,
    )
    svc.classify(imgs)
    assert svc.batches_run == 3  # 4 + 4 + 2
    assert svc.activation_stats.layers["conv1"].windows == 10 * 12 * 12
    rep = svc.hardware_report(assumed_skip=0.5)
    assert rep["energy_pj_measured"] <= rep["energy_pj"]
    assert rep["skip"]["measured_windows"] == 10 * 12 * 12
    svc.reset_stats()
    assert svc.activation_stats is None


def _uniform_stats(prog, frac: float) -> ActivationStats:
    """Synthetic measured stats: every (channel, pattern) skips `frac`."""
    windows = 1000
    layers = {}
    for op in prog.convs:
        kk = op.kernel * op.kernel
        patterns, _ = skip_patterns_and_masks(op.pattern_bits, kk)
        counts = np.full(
            (op.c_in, len(patterns)), int(frac * windows), np.int64
        )
        layers[op.name] = LayerSkipStats(
            name=op.name, kernel_size=kk, patterns=patterns,
            windows=windows, counts=counts,
        )
    return ActivationStats(layers=layers)


def test_energy_strictly_decreases_with_measured_sparsity(mini):
    cfg, params, bits, prog = mini
    energies = [
        prog.hardware_report(
            skip_stats=_uniform_stats(prog, f)
        )["energy_pj_measured"]
        for f in (0.0, 0.25, 0.5, 0.75)
    ]
    assert all(a > b for a, b in zip(energies, energies[1:])), energies
    # zero measured sparsity reproduces the no-skip upper bound
    assert energies[0] == pytest.approx(prog.hardware_report()["energy_pj"])


def test_assumed_path_matches_uniform_distribution(mini):
    """The scalar assumed-probability fallback equals a SkipDistribution
    with the same probability everywhere."""
    cfg, params, bits, prog = mini
    p = 0.3
    via_scalar = prog.hardware_report(assumed_skip=p)["energy_pj_assumed"]
    dists = {
        op.name: SkipDistribution(probs={}, windows=0, default=p)
        for op in prog.convs
    }
    via_dist = prog.hardware_report(skip_stats=dists)["energy_pj_measured"]
    assert via_scalar == pytest.approx(via_dist)


def test_assumed_accepts_int_and_np_scalars(mini):
    """The scalar fallback is type-robust: int 0 and np.float32 work."""
    cfg, params, bits, prog = mini
    noskip = prog.hardware_report()["energy_pj"]
    assert prog.hardware_report(assumed_skip=0)["energy_pj_assumed"] \
        == pytest.approx(noskip)
    assert prog.hardware_report(
        assumed_skip=np.float32(0.3)
    )["energy_pj_assumed"] == pytest.approx(
        prog.hardware_report(assumed_skip=0.3)["energy_pj_assumed"]
    )


def test_partial_measurement_coverage_is_explicit(mini):
    """Layers without measured stats price at no-skip inside the measured
    total, and the report says exactly which layers were observed."""
    cfg, params, bits, prog = mini
    only_conv1 = {"conv1": SkipDistribution(probs={}, windows=50,
                                            default=0.5)}
    rep = prog.hardware_report(skip_stats=only_conv1)
    assert rep["skip"]["measured_layers"] == ["conv1"]
    rows = {r["name"]: r for r in rep["layers"]}
    assert "energy_pj_measured" in rows["conv1"]
    assert "energy_pj_measured" not in rows["conv2"]
    # total = measured conv1 + no-skip rest
    expect = rows["conv1"]["energy_pj_measured"] + sum(
        rows[n]["energy_pj"] for n in rows if n != "conv1"
    )
    assert rep["energy_pj_measured"] == pytest.approx(expect)


def test_mean_skip_excludes_all_zero_pattern():
    """The vacuous always-skip column of the all-zero pattern must not
    inflate the summary statistic."""
    st = LayerSkipStats(
        name="conv", kernel_size=9, patterns=(0, 7), windows=100,
        counts=np.array([[100, 10], [100, 30]], np.int64),
    )
    assert st.mean_skip() == pytest.approx(0.2)  # (10 + 30) / 200
    weighted = LayerSkipStats(
        name="conv", kernel_size=9, patterns=(0, 7), windows=100,
        counts=np.array([[100, 10], [100, 30]], np.int64),
        occurrences=np.array([[2, 3], [1, 1]], np.int64),
    )
    # (10*3 + 30*1) / (100 * 4); the pattern-0 occurrences don't count
    assert weighted.mean_skip() == pytest.approx(60 / 400)


def test_report_delta_section(mini):
    cfg, params, bits, prog = mini
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 1, 12, 12))
    _, stats = make_forward(prog, backend="xla", collect_stats=True)(x)
    rep = prog.hardware_report(skip_stats=stats, assumed_skip=0.5)
    skip = rep["skip"]
    assert skip["assumed_probability"] == 0.5
    assert skip["energy_pj_noskip"] == rep["energy_pj"]
    assert skip["measured_vs_assumed_delta_pj"] == pytest.approx(
        rep["energy_pj_measured"] - rep["energy_pj_assumed"]
    )
    # per-layer rows carry all three pricings
    for row in rep["layers"]:
        assert row["energy_pj_measured"] <= row["energy_pj"]
        assert "energy_pj_assumed" in row
    # legacy keys keep their no-skip meaning
    plain = prog.hardware_report()
    assert plain["energy_pj"] == rep["energy_pj"]
    assert plain["crossbars"] == rep["crossbars"]
