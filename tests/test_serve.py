"""The unified ``repro.serve`` surface: session verbs, load shedding,
the asyncio HTTP front end, and per-slot mid-decode admission.

The acceptance properties pinned here:

  * **mid-decode admission** — a freed slot is refilled while other
    slots are between decode steps; every request's tokens are
    bit-identical to running it alone, and the decode forward is traced
    exactly once no matter how requests arrive;
  * **load shedding** — a full bounded queue sheds with
    :class:`~repro.serve.Overloaded` (HTTP 429 + ``Retry-After``);
    work the scheduler admitted is never dropped; the internal
    ``SchedulerFull`` never escapes the public serve path;
  * **HTTP e2e** — real sockets, concurrent clients, chunked NDJSON
    streaming, Prometheus ``/metrics``;
  * the deprecated request shims keep working while warning.
"""

import http.client
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.engine import InferenceService, execute
from repro.engine import compile_network
from repro.engine.scheduler import SchedulerFull, SlotScheduler
from repro.models.cnn import conv_weight_names, init_cnn, mini_cnn_config
from repro.serve import (
    Overloaded,
    Request,
    Response,
    ServeSession,
    ServingServer,
    classify_session,
)


@pytest.fixture(scope="module")
def mini_prog():
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    return cfg, compile_network(cfg, params, bits)


def _images(n, seed=7):
    return np.array(
        jax.random.normal(jax.random.PRNGKey(seed), (n, 1, 12, 12)),
        np.float32,
    )


# ---------------------------------------------------------------------------
# session verbs
# ---------------------------------------------------------------------------


def test_session_run_matches_direct_execute(mini_prog):
    cfg, prog = mini_prog
    sess = classify_session(prog, batch_slots=4)
    imgs = _images(10)
    reqs = sess.run([Request(image=img) for img in imgs])
    ref = np.asarray(execute(prog, jnp.asarray(imgs)))
    assert [r.label for r in reqs] == [int(np.argmax(l)) for l in ref]
    assert all(r.done for r in reqs)
    assert sess.trace_count() == 1


def test_session_stream_yields_completed_requests(mini_prog):
    cfg, prog = mini_prog
    sess = classify_session(prog, batch_slots=2, max_queue=2)
    imgs = _images(7, seed=11)
    submitted = [Request(image=img) for img in imgs]
    seen = []
    for req in sess.stream(submitted):
        assert req.done  # yielded the moment it completes
        seen.append(req)
    assert {id(r) for r in seen} == {id(r) for r in submitted}
    # a bounded queue throttles the drain instead of rejecting
    assert sess.scheduler.metrics.rejected == 0


def test_warmup_traces_once_and_resets_metrics(mini_prog):
    cfg, prog = mini_prog
    sess = classify_session(prog, batch_slots=4)
    sess.warmup()
    assert sess.trace_count() == 1
    assert sess.metrics["completed"] == 0 and sess.metrics["steps"] == 0
    sess.run([Request(image=img) for img in _images(9, seed=3)])
    assert sess.trace_count() == 1  # real traffic does not retrace
    assert sess.metrics["completed"] == 9


# ---------------------------------------------------------------------------
# load shedding
# ---------------------------------------------------------------------------


def test_submit_sheds_with_overloaded_never_schedulerfull(mini_prog):
    cfg, prog = mini_prog
    sess = classify_session(prog, batch_slots=2, max_queue=2)
    admitted = [sess.submit(Request(image=img)) for img in _images(2)]
    with pytest.raises(Overloaded) as ei:
        sess.submit(Request(image=_images(1)[0]))
    assert ei.value.retry_after_s > 0
    assert not isinstance(ei.value, SchedulerFull)
    assert sess.scheduler.metrics.rejected == 1
    # work the scheduler admitted is never dropped
    while sess.has_work():
        sess.step()
    assert all(r.done for r in admitted)
    assert sess.scheduler.metrics.completed == 2


def test_shed_retry_after_matches_live_backpressure(mini_prog):
    cfg, prog = mini_prog
    sess = classify_session(prog, batch_slots=1, max_queue=3)
    sess.submit(Request(image=_images(1)[0]))
    shallow = sess.scheduler.retry_after_hint()
    for img in _images(2, seed=2):
        sess.submit(Request(image=img))
    deep = sess.scheduler.retry_after_hint()
    assert deep > shallow  # deeper queue -> longer hint
    with pytest.raises(Overloaded) as ei:
        sess.submit(Request(image=_images(1)[0]))
    assert ei.value.retry_after_s == pytest.approx(
        sess.scheduler.retry_after_hint()
    )
    sess.run([])  # drain


# ---------------------------------------------------------------------------
# deprecated shims
# ---------------------------------------------------------------------------


def test_classify_request_shim_warns_and_serves(mini_prog):
    from repro.engine.service import ClassifyRequest

    cfg, prog = mini_prog
    with pytest.warns(DeprecationWarning, match="repro.serve.Request"):
        req = ClassifyRequest(_images(1)[0])
    assert isinstance(req, Request)
    svc = InferenceService(prog, batch_slots=2)
    svc.submit(req)
    svc.run()
    assert req.done and req.label is not None


def test_runtime_request_shim_warns():
    from repro.runtime.serve import Request as OldRequest

    with pytest.warns(DeprecationWarning, match="repro.serve.Request"):
        req = OldRequest(prompt=np.ones(4, np.int32), max_new_tokens=2)
    assert isinstance(req, Request)
    assert req.kind == "generate"


# ---------------------------------------------------------------------------
# per-slot mid-decode admission (generation backend)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_lm():
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_params

    cfg = get_smoke_config("granite_3_2b")
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, statics


def _solo_tokens(cfg, statics, params, prompt, n):
    from repro.runtime.serve import DecodeService, ServeConfig

    svc = DecodeService(
        cfg, statics, params,
        ServeConfig(batch_slots=2, max_seq=32, eos_id=-1),
    )
    req = Request(prompt=prompt, max_new_tokens=n)
    svc.submit(req)
    svc.run()
    return list(req.output)


def test_mid_decode_admission_bit_identical_and_single_trace(smoke_lm):
    from repro.obs.trace import Tracer
    from repro.runtime.serve import DecodeService, ServeConfig

    cfg, params, statics = smoke_lm
    tr = Tracer()
    svc = DecodeService(
        cfg, statics, params,
        ServeConfig(batch_slots=2, max_seq=32, eos_id=-1),
        tracer=tr,
    )
    rng = np.random.default_rng(0)
    p1 = rng.integers(1, cfg.vocab, 6).astype(np.int32)
    p2 = rng.integers(1, cfg.vocab, 4).astype(np.int32)
    p3 = rng.integers(1, cfg.vocab, 5).astype(np.int32)
    r1 = Request(prompt=p1, max_new_tokens=10)
    r2 = Request(prompt=p2, max_new_tokens=3)
    r3 = Request(prompt=p3, max_new_tokens=4)
    svc.submit(r1)
    svc.submit(r2)
    while not r2.done:
        svc.step()
    assert not r1.done  # its neighbour finished mid-generation
    svc.submit(r3)
    svc.step()  # refills the freed slot while r1 is between decode steps
    assert r3.output and not r1.done
    svc.run()

    # the decode forward traced exactly once across all of that
    assert svc.trace_count() == 1
    # per-slot positions: each request's tokens are bit-identical to a
    # solo run in a fresh service
    for prompt, req in ((p1, r1), (p2, r2), (p3, r3)):
        assert list(req.output) == _solo_tokens(
            cfg, statics, params, prompt, req.max_new_tokens
        )
    # the trace records the mid-decode admission at r3's prefill position
    admits = [
        e for e in tr.events()
        if e.get("args", {}).get("event") == "admit_mid_decode"
    ]
    assert len(admits) == 1
    assert admits[0]["args"]["pos"] == len(p3)
    # first-result SLO latency recorded once per request
    assert svc.scheduler.metrics.first_results == 3
    assert svc.metrics["first_result_p50_s"] >= 0.0


# ---------------------------------------------------------------------------
# HTTP front end, over real sockets
# ---------------------------------------------------------------------------


def _post(host, port, path, payload, timeout=60):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request(
            "POST", path, json.dumps(payload),
            {"Content-Type": "application/json"},
        )
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


def _get(host, port, path, timeout=30):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def test_http_server_end_to_end(mini_prog):
    cfg, prog = mini_prog
    sess = classify_session(prog, batch_slots=4)
    srv = ServingServer(sess, admit_wait_s=0.002)
    host, port = srv.start_in_thread()
    try:
        imgs = _images(8, seed=13)
        ref = np.asarray(execute(prog, jnp.asarray(imgs)))

        status, _, body = _post(
            host, port, "/v1/run", {"image": imgs[0].tolist()}
        )
        assert status == 200
        out = json.loads(body)
        assert out["ok"] and out["label"] == int(np.argmax(ref[0]))

        # concurrent burst from real client threads
        results: list = [None] * 8
        def client(i):
            st, _, b = _post(host, port, "/v1/run",
                             {"image": imgs[i].tolist()})
            results[i] = (st, json.loads(b))
        threads = [
            threading.Thread(target=client, args=(i,)) for i in range(8)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        for i, (st, out) in enumerate(results):
            assert st == 200
            assert out["label"] == int(np.argmax(ref[i]))

        # streaming: chunked NDJSON, one line per request with its index
        status, _, body = _post(
            host, port, "/v1/stream",
            {"requests": [{"image": imgs[i].tolist()} for i in range(5)]},
        )
        assert status == 200
        lines = [json.loads(l) for l in body.decode().strip().splitlines()]
        assert sorted(l["index"] for l in lines) == list(range(5))
        for line in lines:
            assert line["ok"]
            assert line["label"] == int(np.argmax(ref[line["index"]]))

        status, body = _get(host, port, "/healthz")
        health = json.loads(body)
        assert status == 200 and health["ok"]
        assert health["batch_slots"] == 4

        status, body = _get(host, port, "/metrics")
        text = body.decode()
        assert status == 200
        assert "engine_service_completed_total" in text
        assert "engine_service_first_result_seconds" in text
        assert "serve_http_requests_total" in text

        # error paths keep the server alive
        status, _, _ = _post(host, port, "/v1/run", {"nope": 1})
        assert status == 400
        status, _ = _get(host, port, "/nothing")
        assert status == 404

        # single trace through warmup + every socket-driven request
        assert sess.trace_count() == 1
        assert srv.completed == 14
        assert srv.meter.total == 14
    finally:
        srv.shutdown()


class _SlowBackend:
    """Protocol-conforming fake backend with a controllable step time —
    makes HTTP-level shedding deterministic without jit in the loop."""

    def __init__(self, batch_slots=1, max_queue=1, step_s=0.3):
        self.scheduler = SlotScheduler(batch_slots, max_queue=max_queue)
        self.step_s = step_s

    def try_submit(self, req):
        return self.scheduler.try_submit(req)

    def submit(self, req):
        self.scheduler.submit(req)

    def has_work(self):
        return self.scheduler.has_work()

    def step(self):
        self.scheduler.refill()
        live = list(self.scheduler.live())
        if not live:
            return []
        time.sleep(self.step_s)
        self.scheduler.record_step()
        done = []
        for slot, req in live:
            req.label = 0
            req.done = True
            self.scheduler.complete(slot)
            done.append(req)
        return done

    def trace_count(self):
        return 1

    @property
    def metrics(self):
        return self.scheduler.snapshot()

    def metrics_text(self):
        return self.scheduler.metrics.to_prometheus(prefix="fake")

    def reset_metrics(self):
        self.scheduler.reset_metrics()

    def warmup(self):
        pass


def test_http_load_shedding_429_and_admitted_never_dropped():
    backend = _SlowBackend(batch_slots=1, max_queue=1, step_s=0.4)
    srv = ServingServer(ServeSession(backend), admit_wait_s=0.0)
    host, port = srv.start_in_thread()
    outcomes = []
    lock = threading.Lock()

    def client():
        st, headers, body = _post(
            host, port, "/v1/run", {"image": [[0.0]]}, timeout=120
        )
        with lock:
            outcomes.append((st, headers, body))

    threads = [threading.Thread(target=client) for _ in range(6)]
    # admit up to capacity (1 slot + 1 queued) ...
    for t in threads[:2]:
        t.start()
    time.sleep(0.15)
    # ... then burst while the worker is mid-step: the queue is full
    for t in threads[2:]:
        t.start()
    for t in threads:
        t.join(timeout=120)
    srv.shutdown()

    ok = [o for o in outcomes if o[0] == 200]
    shed = [o for o in outcomes if o[0] == 429]
    assert len(ok) + len(shed) == 6
    assert ok and shed  # some served, some shed — never an exception
    for _, headers, body in shed:
        assert int(headers["Retry-After"]) >= 1  # honors backpressure
        payload = json.loads(body)
        assert payload["ok"] is False
        assert payload["error"] == "overloaded"
        assert payload["retry_after_s"] > 0
    # conservation: every admitted request completed, every shed request
    # was counted, nothing vanished
    m = backend.scheduler.metrics
    assert m.completed == m.admitted == len(ok)
    assert m.rejected == len(shed)


def test_http_stream_sheds_per_request_not_per_connection():
    backend = _SlowBackend(batch_slots=1, max_queue=1, step_s=0.2)
    srv = ServingServer(ServeSession(backend), admit_wait_s=0.0)
    host, port = srv.start_in_thread()
    try:
        status, _, body = _post(
            host, port, "/v1/stream",
            {"requests": [{"image": [[0.0]]} for _ in range(5)]},
            timeout=120,
        )
        assert status == 200  # the stream itself succeeds
        lines = [json.loads(l) for l in body.decode().strip().splitlines()]
        assert len(lines) == 5
        assert sorted(l["index"] for l in lines) == list(range(5))
        served = [l for l in lines if l["ok"]]
        shed = [l for l in lines if not l["ok"]]
        assert served and shed  # already-admitted work still ran
        for line in shed:
            assert line["error"] == "overloaded"
            assert line["retry_after_s"] > 0
    finally:
        srv.shutdown()


def test_response_shed_shape():
    r = Response.shed(2.5)
    out = r.to_json()
    assert out == {"ok": False, "error": "overloaded", "retry_after_s": 2.5}
