import os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
arch, shape = sys.argv[1], sys.argv[2]
mesh = make_production_mesh(multi_pod=False)
t0 = time.time()
built = build_step(arch, shape, mesh)
print("built", round(time.time()-t0,1), flush=True)
lowered = built.fn.lower(*built.args)
print("lower", round(time.time()-t0,1), flush=True)
