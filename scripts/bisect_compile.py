import os, sys, time, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.configs import get_config
import signal

mesh = make_production_mesh(multi_pod=False)
base = get_config('deepseek_v2_236b', 'train_4k')

class TO(Exception): pass
def hdl(*a): raise TO()
signal.signal(signal.SIGALRM, hdl)

def probe(tag, cfg, budget=240):
    t0=time.time()
    try:
        signal.alarm(budget)
        built = build_step('deepseek_v2_236b', 'train_4k', mesh, cfg=cfg)
        lowered = built.fn.lower(*built.args)
        t1=time.time()
        compiled = lowered.compile()
        signal.alarm(0)
        print(f'{tag}: lower {t1-t0:.0f}s compile {time.time()-t1:.0f}s', flush=True)
    except TO:
        print(f'{tag}: TIMEOUT >{budget}s', flush=True)
    except Exception as e:
        signal.alarm(0)
        print(f'{tag}: ERROR {type(e).__name__}: {str(e)[:150]}', flush=True)

r = dataclasses.replace
# (c) tiny layer count, full MoE width
probe('2-layer-160e', r(base, n_layers=2, layer_types=(('mla','mlp'),('mla','moe'))))
# (a) full layers, 16 experts
probe('60-layer-16e', r(base, moe=r(base.moe, n_experts=16)))
# (b) full layers, 160e, top-2
probe('60-layer-160e-top2', r(base, moe=r(base.moe, top_k=2)))
# (e) no remat
probe('60-layer-160e-noremat', r(base, remat=False))
# full
probe('60-layer-160e-full', base, budget=300)
