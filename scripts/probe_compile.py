"""Compile-time probe for a single (arch, shape, mesh) cell."""
import os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

arch, shape = sys.argv[1], sys.argv[2]
multi = len(sys.argv) > 3 and sys.argv[3] == "multi"
mesh = make_production_mesh(multi_pod=multi)
t0 = time.time()
built = build_step(arch, shape, mesh)
lowered = built.fn.lower(*built.args)
t1 = time.time()
print(f"lower {t1-t0:.1f}s", flush=True)
compiled = lowered.compile()
print(f"compile {time.time()-t1:.1f}s", flush=True)
print("mem:", str(compiled.memory_analysis())[:200], flush=True)
