"""Render the §Dry-run and §Roofline markdown tables from
experiments/dryrun/*.json.  Usage:

  PYTHONPATH=src python scripts/make_roofline_table.py [--mesh single]
"""

import argparse
import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

ARCH_ORDER = [
    "qwen2_5_32b", "granite_3_2b", "phi3_medium_14b", "h2o_danube_1_8b",
    "whisper_small", "jamba_1_5_large_398b", "mamba2_780m",
    "deepseek_v2_236b", "deepseek_v3_671b", "paligemma_3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def load(mesh, sparse=False):
    recs = {}
    for p in glob.glob(os.path.join(DRYRUN, "*.json")):
        with open(p) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and bool(r.get("sparse", False)) == sparse:
            recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_table(mesh="single", sparse=False):
    recs = load(mesh, sparse)
    lines = [
        "| arch | shape | kind | compute (ms) | memory (ms) | collective (ms)"
        " | dominant | step lower-bound (ms) | MODEL/HLO flops | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|---|".replace("|---|---|---|---|---|---|---|---|---|---|",
            "|---|---|---|---:|---:|---:|---|---:|---:|---:|"),
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(
                    f"| {arch} | {shape} | - | - | - | - | SKIP "
                    f"(full-attention, sub-quadratic required) | - | - | - |"
                )
                continue
            if r["status"] != "ok":
                lines.append(
                    f"| {arch} | {shape} | - | - | - | - | "
                    f"ERROR {r.get('error','')[:40]} | - | - | - |"
                )
                continue
            t = r["roofline"]
            mem = r.get("memory_analysis", {})
            hbm = mem.get("bytes_per_device")
            dom = r["dominant_term"].replace("_s", "")
            lb = max(t.values()) * 1e3
            ratio = r.get("useful_flops_ratio")
            lines.append(
                f"| {arch} | {shape} | {r['kind']} "
                f"| {t['compute_s']*1e3:.3f} | {t['memory_s']*1e3:.3f} "
                f"| {t['collective_s']*1e3:.3f} | **{dom}** | {lb:.3f} "
                f"| {ratio:.2f} | {fmt_bytes(hbm)} |"
                if ratio is not None else
                f"| {arch} | {shape} | {r['kind']} "
                f"| {t['compute_s']*1e3:.3f} | {t['memory_s']*1e3:.3f} "
                f"| {t['collective_s']*1e3:.3f} | **{dom}** | {lb:.3f} "
                f"| - | {fmt_bytes(hbm)} |"
            )
    return "\n".join(lines)


def dryrun_summary(mesh):
    recs = load(mesh)
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    skip = sum(1 for r in recs.values() if r["status"] == "skip")
    err = sum(1 for r in recs.values() if r["status"] == "error")
    lines = [f"mesh={mesh}: {ok} ok, {skip} documented skips, {err} errors"]
    for (a, s), r in sorted(recs.items()):
        if r["status"] == "error":
            lines.append(f"  ERROR {a} {s}: {r.get('error','')[:150]}")
    return "\n".join(lines)


def collective_detail(mesh="single"):
    recs = load(mesh)
    lines = [
        "| arch | shape | AG | AR | RS | A2A | CP | total bytes |",
        "|---|---|---:|---:|---:|---:|---:|---:|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if not r or r["status"] != "ok":
                continue
            c = r["collectives"]
            lines.append(
                f"| {arch} | {shape} "
                f"| {c['all-gather']['count']} | {c['all-reduce']['count']} "
                f"| {c['reduce-scatter']['count']} | {c['all-to-all']['count']}"
                f" | {c['collective-permute']['count']} "
                f"| {fmt_bytes(c['total_bytes'])} |"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--what", default="roofline",
                    choices=["roofline", "summary", "collectives"])
    ap.add_argument("--sparse", action="store_true")
    args = ap.parse_args()
    if args.what == "roofline":
        print(roofline_table(args.mesh, args.sparse))
    elif args.what == "collectives":
        print(collective_detail(args.mesh))
    else:
        print(dryrun_summary("single"))
        print(dryrun_summary("multi"))
