import os, sys, time, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.configs import get_config

variant = sys.argv[1]
mesh = make_production_mesh(multi_pod=False)
base = get_config('deepseek_v2_236b', 'train_4k')
r = dataclasses.replace
cfgs = {
    '16e': r(base, moe=r(base.moe, n_experts=16)),
    '160e-top2': r(base, moe=r(base.moe, top_k=2)),
    'noremat': r(base, remat=False),
    'nozero': base,  # handled via env flag below
    'full': base,
    '8layer': r(base, n_layers=8, layer_types=(('mla','mlp'),)+(('mla','moe'),)*7),
    '20layer': r(base, n_layers=20, layer_types=(('mla','mlp'),)+(('mla','moe'),)*19),
}
cfg = cfgs[variant]
t0 = time.time()
built = build_step('deepseek_v2_236b', 'train_4k', mesh, cfg=cfg)
lowered = built.fn.lower(*built.args)
t1 = time.time()
print(f"{variant}: lower {t1-t0:.0f}s", flush=True)
compiled = lowered.compile()
print(f"{variant}: compile {time.time()-t1:.0f}s", flush=True)
