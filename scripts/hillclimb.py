import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede all other imports — see launch/dryrun.py)

"""§Perf hillclimb driver: lowers named variants of the three chosen cells
and records their loop-aware roofline terms next to the baselines.

  PYTHONPATH=src python scripts/hillclimb.py <variant> [...]

Variants (hypothesis -> change; results land in experiments/perf/):
  qwen_train_sparse          paper technique: block-pattern MLPs d=0.25
  qwen_train_sparse_lean     + kmax_slack 1.5 -> 1.05 (fewer padded bricks)
  qwen_train_sparse_d125     + density 0.125, 12 patterns
  qwen_decode_flash          shard_map flash-decode (kill cache all-gather)
  qwen_decode_flash_multi    same on the 2-pod mesh
  whisper_train_scanenc      scanned encoder (baseline rerun after change)
  whisper_train_dots         + remat policy dots_saveable (less recompute)
"""

import dataclasses
import json
import sys
import time

import jax

from repro.configs import get_config
from repro.launch.dryrun import collective_stats, roofline_terms
from repro.launch.hlo_stats import parse_hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models.layers import PatternSparseConfig

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")


def lower_and_record(tag, arch, shape, cfg, multi_pod=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    built = build_step(arch, shape, mesh, cfg=cfg)
    lowered = built.fn.lower(*built.args)
    compiled = lowered.compile()
    st = parse_hlo_stats(compiled.as_text())
    terms = roofline_terms(st.flops, st.bytes, st.collective_bytes, 0)
    terms["memory_flashattn_s"] = (st.bytes - st.score_bytes) / 819e9
    rec = {
        "tag": tag, "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "flops_per_device": st.flops,
        "bytes_per_device": st.bytes,
        "score_bytes_per_device": st.score_bytes,
        "collective_bytes_per_device": st.collective_bytes,
        "collective_counts": dict(st.collective_counts),
        "roofline": terms,
        "dominant": max(
            {k: v for k, v in terms.items() if not k.startswith("memory_fl")},
            key=terms.get,
        ),
        "step_lower_bound_s": max(
            v for k, v in terms.items() if not k.startswith("memory_fl")
        ),
        "compile_s": round(time.time() - t0, 1),
        "hbm_bytes": getattr(compiled.memory_analysis(),
                             "temp_size_in_bytes", None),
    }
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    r = terms
    print(f"[{tag}] dom={rec['dominant']} "
          f"c={r['compute_s']*1e3:.3f}ms m={r['memory_s']*1e3:.3f}ms "
          f"x={r['collective_s']*1e3:.3f}ms "
          f"(compile {rec['compile_s']}s)", flush=True)
    return rec


def variant(name):
    r = dataclasses.replace
    if name == "qwen_train_sparse":
        cfg = get_config("qwen2_5_32b", "train_4k")
        cfg = r(cfg, sparse=PatternSparseConfig(density=0.25, num_patterns=8))
        return ("qwen2_5_32b", "train_4k", cfg, False)
    if name == "qwen_train_sparse_lean":
        cfg = get_config("qwen2_5_32b", "train_4k")
        cfg = r(cfg, sparse=PatternSparseConfig(
            density=0.25, num_patterns=8, kmax_slack=1.05))
        return ("qwen2_5_32b", "train_4k", cfg, False)
    if name == "qwen_train_sparse_d125":
        cfg = get_config("qwen2_5_32b", "train_4k")
        cfg = r(cfg, sparse=PatternSparseConfig(
            density=0.125, num_patterns=12, kmax_slack=1.1))
        return ("qwen2_5_32b", "train_4k", cfg, False)
    if name == "qwen_decode_flash":
        cfg = r(get_config("qwen2_5_32b", "decode_32k"),
                decode_strategy="flash")
        return ("qwen2_5_32b", "decode_32k", cfg, False)
    if name == "qwen_decode_flash_multi":
        cfg = r(get_config("qwen2_5_32b", "decode_32k"),
                decode_strategy="flash")
        return ("qwen2_5_32b", "decode_32k", cfg, True)
    if name == "whisper_train_scanenc":
        return ("whisper_small", "train_4k",
                get_config("whisper_small", "train_4k"), False)
    if name == "whisper_train_dots":
        # remat policy change is baked via cfg.remat False: save everything
        cfg = r(get_config("whisper_small", "train_4k"), remat=False)
        return ("whisper_small", "train_4k", cfg, False)
    raise SystemExit(f"unknown variant {name}")


if __name__ == "__main__":
    for name in sys.argv[1:]:
        arch, shape, cfg, multi = variant(name)
        lower_and_record(name, arch, shape, cfg, multi_pod=multi)
