"""Activation sharding constraints (sequence parallelism between layers).

The launcher installs a mesh + rules context; model code calls
``shard_activation(x, spec)`` at layer boundaries.  Outside a context (unit
tests, single-device smoke runs) it is a no-op, so model code never needs
to know whether it is distributed.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import AxisRules, DEFAULT_RULES, logical_to_pspec

__all__ = ["activation_sharding_ctx", "shard_activation", "current_mesh"]

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None
)


@contextlib.contextmanager
def activation_sharding_ctx(mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh() -> Mesh | None:
    ctx = _CTX.get()
    return ctx[0] if ctx is not None else None


def shard_activation(x: jax.Array, spec: tuple[str | None, ...]) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    pspec = logical_to_pspec(spec, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
