"""GPipe-style pipeline parallelism over a homogeneous layer stack.

Opt-in (DESIGN §6): the assigned production mesh uses DP x TP, but at
1000+-node scale a pipeline axis bounds the TP collective diameter.  This
module implements the classic shard_map pipeline: each 'stage' shard holds
a contiguous slice of the stacked layer params; microbatches flow through
a rotating buffer moved by ``collective_permute``; the schedule runs
``n_micro + n_stages - 1`` ticks (GPipe fill/drain bubble, whose cost the
caller amortises by choosing n_micro >> n_stages).

``pipeline_apply(layer_fn, stacked_params, x_micro, mesh, axis)`` is
numerically identical to folding ``layer_fn`` over the full stack (tested
in tests/test_pipeline.py on a fake 4-device mesh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(
    layer_fn,
    stacked_params,
    x_micro: jax.Array,  # [n_micro, B_micro, ...] microbatched input
    mesh: Mesh,
    axis: str = "stage",
):
    """Run ``layer_fn`` over a stage-sharded layer stack.

    Args:
      layer_fn: (params_slice, x) -> x, applied per layer.
      stacked_params: pytree with leading layer dim L (L %% n_stages == 0).
      x_micro: microbatched inputs; n_micro >= 1.
      mesh: mesh containing ``axis``.
      axis: pipeline axis name.

    Returns [n_micro, B_micro, ...] outputs after all L layers.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    def stage_fn(params_local, x):
        # apply this stage's layers (L/n_stages of them) sequentially
        def body(carry, p):
            return layer_fn(p, carry), None
        y, _ = jax.lax.scan(body, x, params_local)
        return y

    def pipe(params_local, xs):
        # params_local: [L/n_stages, ...]; xs: [n_micro_local...] — the
        # microbatch stream is fed entirely on stage 0 and read on the
        # last stage; all stages execute the same program.
        stage = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])  # inter-stage rotating buffer
        outs = jnp.zeros_like(xs)

        def tick(state, t):
            buf, outs = state
            # stage 0 ingests microbatch t (when valid); others take buf
            fresh = xs[jnp.clip(t, 0, n_micro - 1)]
            inp = jnp.where(stage == 0, fresh, buf)
            out = stage_fn(params_local, inp)
            # last stage records its result for microbatch t - (S-1)
            slot = t - (n_stages - 1)
            valid = (stage == n_stages - 1) & (slot >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(slot, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # rotate: stage s -> stage s+1 (ring; the wraparound value
            # into stage 0 is ignored — stage 0 always takes `fresh`)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(out, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(
            tick, (buf, outs), jnp.arange(n_ticks)
        )
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis,
        )
        return outs

    in_specs = (P(axis), P())
    return shard_map(
        pipe, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_rep=False,
    )(stacked_params, x_micro)
