"""Logical-axis sharding rules (DP / TP / EP / SP + pod axis).

Every parameter leaf carries a tuple of *logical* axis names (its "spec");
``logical_to_pspec`` resolves those through a rules table into a
PartitionSpec for the active mesh.  Divisibility is checked: a dimension
that does not divide evenly over its mesh axes falls back to replication
(and the caller is expected to have padded anything that matters — heads
and vocab are padded in the model configs precisely so the big tables do
shard).

Rules (defaults):
  batch        -> ('pod', 'data')   data parallel, pods are extra DP
  seq_shard    -> 'model'           sequence parallelism (residual stream
                                    between layers, long KV caches)
  heads/ff/... -> 'model'           tensor parallel
  expert       -> 'model'           expert parallel (EP shares the TP axis:
                                    activations are replicated across
                                    'model' at the MoE boundary, each shard
                                    runs its local experts, the down-proj
                                    psum folds the combine)
  embed/state  -> None              replicated
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "BP_LOGICAL_SPECS",
    "logical_to_pspec",
    "tree_pspecs",
    "tree_shardings",
    "shard_block_pattern",
    "pad_to_multiple",
    "padded_heads",
]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    rules: tuple[tuple[str, tuple[str, ...] | None], ...]

    def get(self, name: str) -> tuple[str, ...] | None:
        for k, v in self.rules:
            if k == name:
                return v
        raise KeyError(f"no sharding rule for logical axis {name!r}")


DEFAULT_RULES = AxisRules(
    rules=(
        ("batch", ("pod", "data")),
        ("data_only", ("data",)),
        ("seq", None),
        ("seq_shard", ("model",)),
        ("embed", None),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("ff", ("model",)),
        ("vocab", ("model",)),
        ("expert", ("model",)),
        ("tiles", ("model",)),  # block-pattern compressed weight tiles
        ("kv_lora", None),
        ("q_lora", None),
        ("state", None),
        ("conv", None),
        ("layers", None),
        ("unsharded", None),
    )
)


def logical_to_pspec(
    spec: tuple[str | None, ...] | None,
    shape: tuple[int, ...],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> P:
    """Resolve a logical spec to a PartitionSpec, checking divisibility."""
    if spec is None:
        return P()
    assert len(spec) == len(shape), f"spec {spec} vs shape {shape}"
    out: list[Any] = []
    for name, dim in zip(spec, shape):
        if name is None:
            out.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            out.append(None)
            continue
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size != 0:
            out.append(None)  # replicate non-divisible dims
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_pspecs(specs, shapes, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Map logical-spec tree + shape tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda s, sh: logical_to_pspec(s, sh, mesh, rules),
        specs,
        shapes,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)),
    )


def tree_shardings(specs, shapes, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    pspecs = tree_pspecs(specs, shapes, mesh, rules)
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# Logical axis specs of a BlockPatternWeight's device operands: the tile
# axis is the tensor-parallel dimension of the compressed spmm (the
# 'tiles' rule above), everything else replicates.  ``w_scales`` only
# exists on quantized weights and shards the same way as its bricks.
BP_LOGICAL_SPECS: dict[str, tuple[str | None, ...]] = {
    "w_comp": ("tiles", None, None, None),
    "block_ids": ("tiles", None),
    "w_scales": ("tiles", None),
}


def shard_block_pattern(bp, mesh: Mesh, model_axis: str = "model"):
    """Tile-shard a ``BlockPatternWeight``'s device operands over ``mesh``.

    Places ``w_comp`` / ``block_ids`` (and ``w_scales`` when quantized)
    with a NamedSharding that splits the tile axis over ``model_axis``
    (replicating when the axis is absent from the mesh or does not divide
    ``n_tiles`` — callers pad first, see
    ``engine/partition.pad_bp_tiles``).  Host-side metadata (``nnz``,
    permutations) is untouched.  Returns a new dataclass instance.
    """
    rules = AxisRules(rules=(("tiles", (model_axis,)),))
    placed = {}
    for field, spec in BP_LOGICAL_SPECS.items():
        arr = getattr(bp, field, None)
        if arr is None:
            continue
        pspec = logical_to_pspec(spec, tuple(arr.shape), mesh, rules)
        placed[field] = jax.device_put(arr, NamedSharding(mesh, pspec))
    return dataclasses.replace(bp, **placed)


def pad_to_multiple(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def padded_heads(n_heads: int, shards: int = 16) -> int:
    """Head count padded so the head axis shards (MaxText-style padding).

    Padded heads carry zero weights in the in/out projections, so they are
    numerically inert; they cost shards/(shards-pad) extra attention FLOPs,
    which the roofline table reports honestly.
    """
    return pad_to_multiple(n_heads, shards)
