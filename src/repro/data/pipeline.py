"""Token data pipeline: synthetic corpus, document packing, sharded batches.

Two sources:
  * SyntheticCorpus — a seeded random bigram LM.  Deterministic, infinite,
    and *learnable* (a model that trains should drive loss toward the
    bigram entropy), which is what convergence tests assert.
  * TokenFileDataset — memory-mapped ``.bin`` token files (uint16/uint32)
    with EOS-delimited documents, shuffled shard order, and greedy packing
    into fixed-length sequences — the standard production layout.

``shard_batch`` places host batches onto the mesh with the DP sharding
(('pod','data') on batch).  In a multi-host deployment each process feeds
its addressable shard; the single-process container exercises the same
code path via ``jax.device_put`` with a NamedSharding.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DataConfig", "SyntheticCorpus", "TokenFileDataset", "packed_batches",
           "shard_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0


class SyntheticCorpus:
    """Seeded bigram language model over ``vocab`` tokens."""

    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.default_rng(seed)
        logits = rng.gumbel(size=(vocab, vocab)) / concentration
        self.probs = np.exp(logits - logits.max(-1, keepdims=True))
        self.probs /= self.probs.sum(-1, keepdims=True)
        self.vocab = vocab

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        tok = int(rng.integers(self.vocab))
        for i in range(length):
            tok = int(rng.choice(self.vocab, p=self.probs[tok]))
            out[i] = tok
        return out

    def bigram_entropy(self) -> float:
        p = self.probs
        return float(-(p * np.log(p + 1e-12)).sum(-1).mean())


class TokenFileDataset:
    """Memmapped token file with EOS-delimited documents."""

    def __init__(self, path: str, dtype=np.uint16, eos_id: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.eos_id = eos_id

    def __len__(self) -> int:
        return len(self.tokens)

    def documents(self, seed: int = 0) -> Iterator[np.ndarray]:
        """Yield documents in shuffled boundary order."""
        bounds = np.flatnonzero(self.tokens == self.eos_id)
        starts = np.concatenate([[0], bounds + 1])
        ends = np.concatenate([bounds + 1, [len(self.tokens)]])
        order = np.random.default_rng(seed).permutation(len(starts))
        for i in order:
            doc = np.asarray(self.tokens[starts[i] : ends[i]], np.int32)
            if doc.size:
                yield doc


def packed_batches(
    cfg: DataConfig,
    source: SyntheticCorpus | TokenFileDataset | None = None,
) -> Iterator[dict]:
    """Yield {'tokens': [B, S+1]} batches (inputs=[:, :-1], labels=[:, 1:]).

    Documents are greedily packed back-to-back (separated by EOS) into
    S+1-length rows — no padding waste, the production default.
    """
    source = source or SyntheticCorpus(cfg.vocab, cfg.seed)
    rng = np.random.default_rng(cfg.seed + 1)
    row_len = cfg.seq_len + 1
    buf = np.empty(0, np.int32)

    if isinstance(source, SyntheticCorpus):
        def doc_iter():
            while True:
                yield source.sample(rng, int(rng.integers(64, 512)))
        docs = doc_iter()
    else:
        def doc_iter():
            epoch = 0
            while True:
                yield from source.documents(seed=cfg.seed + epoch)
                epoch += 1
        docs = doc_iter()

    while True:
        rows = []
        for _ in range(cfg.global_batch):
            while buf.size < row_len:
                doc = next(docs)
                buf = np.concatenate([buf, doc, [cfg.eos_id]])
            rows.append(buf[:row_len])
            buf = buf[row_len:]
        yield {"tokens": np.stack(rows)}


def shard_batch(batch: dict, mesh: Mesh) -> dict:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sharding = NamedSharding(mesh, P(axes))
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
