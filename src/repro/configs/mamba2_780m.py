"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Technique note (DESIGN §4): pattern sparsity applies to in/out projections;
the SSD recurrence has no weight matrix to prune.  long_500k RUNS (state
recurrence, O(1) decode).
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="mamba2_780m",
        n_layers=48,
        d_model=1536,
        vocab=50280,
        layer_types=(("ssm", "none"),) * 48,
        d_ff=0,
        norm="rmsnorm",
        tie_embeddings=True,
        ssm=SSMConfig(
            d_model=1536, d_state=128, d_conv=4, expand=2, head_dim=64,
            n_groups=1, chunk=128, model_shards=16,
        ),
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_smoke",
        n_layers=4,
        d_model=64,
        vocab=512,
        layer_types=(("ssm", "none"),) * 4,
        d_ff=0,
        tie_embeddings=True,
        ssm=SSMConfig(d_model=64, d_state=16, head_dim=16, chunk=8,
                      model_shards=1),
        model_shards=1,
        max_seq=64,
    )
