"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; hf]

SWA (window 4096) makes decode O(window): long_500k RUNS for this arch.
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.layers import PatternSparseConfig
from repro.models.transformer import ModelConfig

WINDOW = 4096


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="h2o_danube_1_8b",
        n_layers=24,
        d_model=2560,
        vocab=32000,
        layer_types=(("swa", "mlp"),) * 24,
        n_heads=32,
        n_kv_heads=8,
        d_head=80,
        window=WINDOW,
        rope_theta=10000.0,
        d_ff=6912,
        act="swiglu",
        norm="rmsnorm",
        sparse=PatternSparseConfig(density=0.25, num_patterns=8) if sparse
        else None,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o_danube_1_8b_smoke",
        n_layers=2,
        d_model=128,
        vocab=512,
        layer_types=(("swa", "mlp"),) * 2,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        window=16,
        d_ff=256,
        model_shards=1,
        max_seq=64,
    )
