"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-32B family; hf]

Paper technique: block-pattern sparse MLP (gate/up/down) — the flagship
dense target (DESIGN §4).
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.layers import PatternSparseConfig
from repro.models.transformer import ModelConfig


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="qwen2_5_32b",
        n_layers=64,
        d_model=5120,
        vocab=152064,
        layer_types=(("attn", "mlp"),) * 64,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        qkv_bias=True,
        rope_theta=1e6,
        d_ff=27648,
        act="swiglu",
        norm="rmsnorm",
        sparse=PatternSparseConfig(density=0.25, num_patterns=8) if sparse
        else None,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2_5_32b_smoke",
        n_layers=2,
        d_model=128,
        vocab=512,
        layer_types=(("attn", "mlp"),) * 2,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        qkv_bias=True,
        d_ff=256,
        model_shards=1,
        max_seq=64,
    )
