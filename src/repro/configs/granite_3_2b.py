"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA.  [hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.layers import PatternSparseConfig
from repro.models.transformer import ModelConfig


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="granite_3_2b",
        n_layers=40,
        d_model=2048,
        vocab=49155,
        layer_types=(("attn", "mlp"),) * 40,
        n_heads=32,
        n_kv_heads=8,
        d_head=64,
        rope_theta=10000.0,
        d_ff=8192,
        act="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
        sparse=PatternSparseConfig(density=0.25, num_patterns=8) if sparse
        else None,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite_3_2b_smoke",
        n_layers=2,
        d_model=128,
        vocab=515,  # non-multiple, exercises vocab padding
        layer_types=(("attn", "mlp"),) * 2,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=256,
        tie_embeddings=True,
        model_shards=1,
        max_seq=64,
    )
