"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

Layer pattern: 9 blocks of 8 layers; one attention layer per block
(position 4), Mamba elsewhere (1:7); MoE replaces the MLP on every other
layer.  long_500k RUNS (hybrid: SSM state + 9 attention layers whose decode
is O(S) reads on a sequence-sharded cache).
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig


def _layer_types(n_layers: int = 72) -> tuple:
    out = []
    for i in range(n_layers):
        mixer = "attn" if i % 8 == 4 else "ssm"
        ffn = "moe" if i % 2 == 1 else "mlp"
        out.append((mixer, ffn))
    return tuple(out)


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="jamba_1_5_large_398b",
        n_layers=72,
        d_model=8192,
        vocab=65536,
        layer_types=_layer_types(72),
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        rope_theta=10000.0,
        d_ff=24576,
        act="swiglu",
        norm="rmsnorm",
        moe=MoEConfig(
            d_model=8192, n_experts=16, top_k=2, d_ff_expert=24576,
            model_shards=16,
        ),
        ssm=SSMConfig(
            d_model=8192, d_state=16, d_conv=4, expand=2, head_dim=64,
            n_groups=1, chunk=128, model_shards=16,
        ),
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba_smoke",
        n_layers=8,
        d_model=64,
        vocab=512,
        layer_types=_layer_types(8),
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        moe=MoEConfig(d_model=64, n_experts=4, top_k=2, d_ff_expert=32,
                      model_shards=1),
        ssm=SSMConfig(d_model=64, d_state=8, head_dim=16, chunk=8,
                      model_shards=1),
        model_shards=1,
        max_seq=64,
    )
