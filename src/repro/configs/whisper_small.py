"""whisper-small [audio]: 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865
— enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

The 12-layer figure is per stack (12 encoder + 12 decoder).  The conv
frontend is a stub per the assignment: ``extra_inputs`` provides
precomputed frame embeddings [B, 1500, d_model].  Decoder uses learned
positions (rope_theta=None) and layernorm, per the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.models.transformer import ModelConfig

ENC_SEQ = 1500


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="whisper_small",
        n_layers=12,
        d_model=768,
        vocab=51865,
        layer_types=(("xattn", "mlp"),) * 12,
        n_heads=12,
        n_kv_heads=12,
        d_head=64,
        rope_theta=None,  # learned positions
        d_ff=3072,
        act="gelu",
        norm="layernorm",
        encoder_layers=12,
        enc_seq=ENC_SEQ,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def extra_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return {
        "frames": jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    }


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper_small_smoke",
        n_layers=2,
        d_model=64,
        vocab=512,
        layer_types=(("xattn", "mlp"),) * 2,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        rope_theta=None,
        d_ff=128,
        act="gelu",
        norm="layernorm",
        encoder_layers=2,
        enc_seq=24,
        model_shards=1,
        max_seq=64,
    )
