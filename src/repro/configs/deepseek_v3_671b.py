"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048 vocab=129280,
MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]

First 3 layers dense (d_ff 18432); MTP adds the next-next-token layer
sharing the output head.  Optimizer states run in bf16 at this scale
(DESIGN §6 memory budget: 671B x 8B/param over 512 chips).
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="deepseek_v3_671b",
        n_layers=61,
        d_model=7168,
        vocab=129280,
        layer_types=(("mla", "mlp"),) * 3 + (("mla", "moe"),) * 58,
        d_ff=18432,  # the three dense layers
        act="swiglu",
        norm="rmsnorm",
        mla=MLAConfig(
            d_model=7168, n_heads=128, kv_lora=512, q_lora=1536,
            d_nope=128, d_rope=64, d_v=128, model_shards=16,
        ),
        moe=MoEConfig(
            d_model=7168, n_experts=256, top_k=8, d_ff_expert=2048,
            n_shared=1, model_shards=16,
        ),
        mtp=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v3_smoke",
        n_layers=4,
        d_model=64,
        vocab=512,
        layer_types=(("mla", "mlp"),) * 2 + (("mla", "moe"),) * 2,
        d_ff=128,
        mla=MLAConfig(d_model=64, n_heads=4, kv_lora=32, q_lora=48,
                      d_nope=16, d_rope=8, d_v=16, model_shards=1),
        moe=MoEConfig(d_model=64, n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared=1, model_shards=1),
        mtp=True,
        model_shards=1,
        max_seq=64,
    )
