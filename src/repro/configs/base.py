"""Architecture + shape registry.

Every assigned architecture has a module exporting:
  config(shape: ShapeSpec|None) -> ModelConfig   (full published config)
  smoke_config() -> ModelConfig                  (reduced, CPU-runnable)
  extra_inputs(cfg, shape) -> dict[str, ShapeDtypeStruct]  (stub frontends)

Shapes (assigned; seq_len x global_batch):
  train_4k     4,096 x 256   training       -> train_step
  prefill_32k  32,768 x 32   inference      -> prefill_step
  decode_32k   32,768 x 128  inference      -> decode_step (1 new token,
                                              KV cache of seq_len)
  long_500k    524,288 x 1   long-context   -> decode_step; requires
                                              sub-quadratic attention ->
                                              runs only for ssm / hybrid /
                                              SWA archs (DESIGN §4)
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ShapeSpec", "SHAPES", "ARCH_NAMES", "get_config",
           "get_smoke_config", "input_specs", "runnable", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_NAMES = [
    "qwen2_5_32b",
    "granite_3_2b",
    "phi3_medium_14b",
    "h2o_danube_1_8b",
    "whisper_small",
    "jamba_1_5_large_398b",
    "mamba2_780m",
    "deepseek_v2_236b",
    "deepseek_v3_671b",
    "paligemma_3b",
]

# archs with sub-quadratic sequence mixing -> long_500k runs
_LONG_OK = {"jamba_1_5_large_398b", "mamba2_780m", "h2o_danube_1_8b"}


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str, shape: str | ShapeSpec | None = None):
    spec = SHAPES[shape] if isinstance(shape, str) else shape
    return _module(arch).config(spec)


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()


def runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in _LONG_OK
    return True


def skip_reason(arch: str, shape: str) -> str | None:
    if runnable(arch, shape):
        return None
    return (
        "long_500k requires sub-quadratic attention; "
        f"{arch} is a pure full-attention architecture (DESIGN §4)"
    )


def input_specs(arch: str, shape: str | ShapeSpec, cfg=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the step function
    this (arch, shape) lowers — no device allocation."""
    spec = SHAPES[shape] if isinstance(shape, str) else shape
    cfg = cfg or get_config(arch, spec)
    mod = _module(arch)
    b, s = spec.global_batch, spec.seq_len
    # VLM: seq_len is the *total* backbone context; the patch-embedding
    # prefix (stub frontend) takes prefix_len of it, text takes the rest.
    s_text = s - (cfg.prefix_len or 0)
    out: dict = {}
    if spec.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s_text + 1), jnp.int32)
    elif spec.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    else:  # decode: one new token against a cache of seq_len
        out["tokens"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    if hasattr(mod, "extra_inputs"):
        out.update(mod.extra_inputs(cfg, spec))
    return out
