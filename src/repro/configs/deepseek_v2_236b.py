"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400,
MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]

First layer uses a dense MLP (d_ff 12288); layers 1..59 route over 160
experts (d_ff_expert=1536) + 2 shared experts.  MLA cache = 576 floats
per token (kv_lora 512 + rope 64), decode runs the absorbed path.
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="deepseek_v2_236b",
        n_layers=60,
        d_model=5120,
        vocab=102400,
        layer_types=(("mla", "mlp"),) + (("mla", "moe"),) * 59,
        d_ff=12288,  # the single dense layer
        act="swiglu",
        norm="rmsnorm",
        mla=MLAConfig(
            d_model=5120, n_heads=128, kv_lora=512, q_lora=1536,
            d_nope=128, d_rope=64, d_v=128, model_shards=16,
        ),
        moe=MoEConfig(
            d_model=5120, n_experts=160, top_k=6, d_ff_expert=1536,
            n_shared=2, model_shards=16,
        ),
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v2_smoke",
        n_layers=3,
        d_model=64,
        vocab=512,
        layer_types=(("mla", "mlp"),) + (("mla", "moe"),) * 2,
        d_ff=128,
        mla=MLAConfig(d_model=64, n_heads=4, kv_lora=32, q_lora=48,
                      d_nope=16, d_rope=8, d_v=16, model_shards=1),
        moe=MoEConfig(d_model=64, n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared=2, model_shards=1),
        model_shards=1,
        max_seq=64,
    )
