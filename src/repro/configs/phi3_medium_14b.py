"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]

kv=10 does not divide the 16-way TP axis: kv projections replicate, q heads
pad 40->48, and the kv *cache* shards on (batch, seq) — DESIGN §4.
"""

from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models.layers import PatternSparseConfig
from repro.models.transformer import ModelConfig


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="phi3_medium_14b",
        n_layers=40,
        d_model=5120,
        vocab=100352,
        layer_types=(("attn", "mlp"),) * 40,
        n_heads=40,
        n_kv_heads=10,
        d_head=128,
        rope_theta=10000.0,
        d_ff=17920,
        act="swiglu",
        norm="rmsnorm",
        sparse=PatternSparseConfig(density=0.25, num_patterns=8) if sparse
        else None,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3_medium_14b_smoke",
        n_layers=2,
        d_model=120,
        vocab=512,
        layer_types=(("attn", "mlp"),) * 2,
        n_heads=6,
        n_kv_heads=3,  # non-divisible into heads*2: exercises kv repeat
        d_head=20,
        d_ff=256,
        model_shards=1,
        max_seq=64,
    )
