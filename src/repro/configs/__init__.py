from repro.configs.base import (  # noqa: F401
    ARCH_NAMES,
    SHAPES,
    ShapeSpec,
    get_config,
    get_smoke_config,
    input_specs,
    runnable,
    skip_reason,
)
