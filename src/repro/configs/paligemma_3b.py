"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma.  [arXiv:2407.07726; hf]

The SigLIP vision tower is a stub per the assignment: ``extra_inputs``
provides precomputed patch embeddings [B, 256, d_model] that prefix the
token sequence.  Backbone is gemma-2b style: MQA (kv=1), gelu MLP, tied
embeddings scaled by sqrt(d_model).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.models.transformer import ModelConfig

N_PATCHES = 256


def config(shape: ShapeSpec | None = None, sparse: bool = False) -> ModelConfig:
    max_seq = shape.seq_len if shape else 4096
    return ModelConfig(
        name="paligemma_3b",
        n_layers=18,
        d_model=2048,
        vocab=257216,
        layer_types=(("attn", "mlp"),) * 18,
        n_heads=8,
        n_kv_heads=1,
        d_head=256,
        rope_theta=10000.0,
        d_ff=16384,
        act="gelu",
        norm="rmsnorm",
        tie_embeddings=True,
        prefix_len=N_PATCHES,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        model_shards=16,
        max_seq=max_seq,
    )


def extra_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    if shape.kind == "decode":
        return {}  # patches were consumed at prefill; cache holds them
    return {
        "prefix_embeds": jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.prefix_len, cfg.d_model), jnp.bfloat16
        )
    }


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma_smoke",
        n_layers=2,
        d_model=64,
        vocab=512,
        layer_types=(("attn", "mlp"),) * 2,
        n_heads=4,
        n_kv_heads=1,
        d_head=16,
        d_ff=128,
        act="gelu",
        tie_embeddings=True,
        prefix_len=8,
        model_shards=1,
        max_seq=64,
    )
