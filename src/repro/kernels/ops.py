"""Public jit'd wrappers around the Pallas kernels.

Each op dispatches between:
  * the Pallas TPU kernel (``backend='pallas'`` — real TPU, or
    ``interpret=True`` on CPU for validation), and
  * the XLA fallback (``backend='xla'``) used by the CPU dry-run, where
    TPU Pallas kernels cannot lower.

Dispatch default: Pallas on TPU devices, XLA elsewhere.  Shapes are padded
to tile multiples here so kernels only see aligned sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import quantize_rows
from repro.core.sparse import (
    BlockPatternWeight,
    pattern_spmm_xla,
    pattern_spmm_xla_quant,
)
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ou_mvm import ou_mvm_pallas
from repro.kernels.pattern_spmm import (
    pattern_spmm_pallas,
    pattern_spmm_pallas_quant,
)

__all__ = [
    "default_backend",
    "pattern_spmm",
    "pattern_spmm_raw",
    "flash_attention",
    "ou_mvm",
]


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_bm(m: int, dtype) -> int:
    """Row-tile for pattern_spmm, autotuned from the (static) batch M.

    Serving batches are often tiny; padding 1 row up to bm=128 wastes a
    128x factor of MXU work, so pick the smallest sublane-aligned tile that
    covers M.  The floor keeps the second-minor dimension at the dtype's
    minimum TPU tile (8 for 4-byte, 16 for 2-byte, 32 for 1-byte types).
    """
    floor = {4: 8, 2: 16, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)
    for cand in (8, 32, 128):
        if m <= cand:
            return max(cand, floor)
    return 128


def pattern_spmm_raw(
    xm: jax.Array,
    w_comp: jax.Array,
    block_ids: jax.Array,
    block: int,
    backend: str | None = None,
    interpret: bool | None = None,
    bm: int | None = None,
    w_scales: jax.Array | None = None,
) -> jax.Array:
    """Compressed spmm in *reordered* column order (no inverse permutation).

    xm: [M, K]; returns [M, T*tile] where T = w_comp.shape[0].  This is
    the per-shard building block of the tile-parallel executor: each
    device runs it on its slab of tiles and the partial outputs are
    psum-combined *before* the Output Indexing Unit un-permutes columns.
    ``pattern_spmm`` is this plus the inverse permutation.

    With ``w_scales`` (int8 ``w_comp`` + per-brick row-group scales,
    ``core/quantize.py``) the activations are dynamically quantized per
    row and the int8-input/int32-accumulate kernel variant runs; the
    weight-scale dequant folds into the accumulator and the activation
    row scale multiplies in the output epilogue here.  Output is fp32.
    """
    backend = backend or default_backend()
    quant = w_scales is not None
    if quant:
        xq, x_scale = quantize_rows(xm)
    if backend == "pallas":
        interp = (
            interpret if interpret is not None else jax.default_backend() != "tpu"
        )
        xin = xq if quant else xm
        m = xin.shape[0]
        if bm is None:
            bm = _pick_bm(m, xin.dtype)
        xp = _pad_to(xin, 0, bm)
        if quant:
            y = pattern_spmm_pallas_quant(
                xp, w_comp, block_ids, w_scales,
                block=block, bm=bm, interpret=interp,
            )[:m]
            return y * x_scale[:, None]
        return pattern_spmm_pallas(
            xp, w_comp, block_ids, block=block, bm=bm, interpret=interp
        )[:m]
    if backend == "xla":
        if quant:
            return pattern_spmm_xla_quant(
                xq, x_scale, w_comp, block_ids, w_scales, block
            )
        return pattern_spmm_xla(xm, w_comp, block_ids, block)
    raise ValueError(f"unknown backend {backend!r}")


def pattern_spmm(
    x: jax.Array,
    bp: BlockPatternWeight,
    backend: str | None = None,
    interpret: bool | None = None,
    bm: int | None = None,
) -> jax.Array:
    """y = x @ W for a block-pattern compressed weight.  x: [..., K].

    ``bm=None`` (default) autotunes the row tile from the batch size.
    Quantized weights (``bp.w_scales is not None``) dispatch the int8
    variant transparently; output dtype follows ``x`` either way.
    """
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    y = pattern_spmm_raw(
        xm, bp.w_comp, bp.block_ids, bp.block,
        backend=backend, interpret=interpret, bm=bm, w_scales=bp.w_scales,
    )
    y = jnp.take(y, jnp.asarray(bp.inv_order), axis=1)
    return y.reshape(*lead, bp.n_out).astype(x.dtype)


def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    bq: int = 128,
    bk: int = 128,
) -> jax.Array:
    """GQA flash attention.  Returns [B, Hq, Sq, D]."""
    backend = backend or default_backend()
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    # fold GQA: repeat kv heads (logical; XLA keeps this as a broadcast
    # until the kernel boundary)
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hq, sk, d)
    vf = v.reshape(b * hq, sk, d)
    if backend == "pallas":
        interp = (
            interpret if interpret is not None else jax.default_backend() != "tpu"
        )
        qp = _pad_to(qf, 1, bq)
        kp = _pad_to(kf, 1, bk)
        vp = _pad_to(vf, 1, bk)
        out = flash_attention_pallas(
            qp, kp, vp, scale=scale, causal=causal, window=window,
            kv_len=sk, bq=bq, bk=bk, interpret=interp,
        )[:, :sq]
    elif backend == "xla":
        out = ref.flash_attention_ref(
            qf, kf, vf, scale=scale, causal=causal, window=window
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out.reshape(b, hq, sq, d)


def ou_mvm(
    x: jax.Array,
    w: jax.Array,
    ou_rows: int = 9,
    ou_cols: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Paper-faithful OU-granular MVM with all-zero input skip."""
    return ou_mvm_pallas(x, w, ou_rows=ou_rows, ou_cols=ou_cols, interpret=interpret)
