"""Pallas TPU kernel: chunked causal attention with online softmax.

Needed because the assigned inference shapes (prefill_32k, long_500k) make
materialised [S, S] score matrices impossible: at S = 32k, bf16 scores per
head are 2 GiB.  The kernel streams KV tiles through VMEM, carrying the
running max / denominator / accumulator (Flash-Attention-2 schedule).

Grid: (batch*q_heads, q_tiles, kv_tiles), kv innermost.  Causal kv tiles
strictly above the diagonal are skipped with ``pl.when`` (no FLOPs, no
DMA-to-MXU dependency).  Sliding-window masking (h2o-danube) folds into the
same mask.  GQA is handled by the ops.py wrapper (kv head broadcast via
index_map — no materialised repeat).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale, causal, window, kv_len, bq, bk, lanes):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_hi = (iq + 1) * bq - 1  # last query position in this tile
    k_lo = jk * bk  # first key position in this tile

    def _body():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)  # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]

        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len  # padded keys never win the softmax
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, :1]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        p = jnp.exp(s - m_new)  # [bq, bk]
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        # skip kv tiles strictly in the future of every query in the tile
        pl.when(k_lo <= q_hi)(_body)
    else:
        _body()

    @pl.when(jk == nk - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0, ...] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "kv_len", "bq", "bk", "interpret", "scale"
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # [BH, S, D]
    k: jax.Array,  # [BH, S, D]
    v: jax.Array,  # [BH, S, D]
    scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
    kv_len: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    kv_len = kv_len if kv_len is not None else sk
    grid = (bh, pl.cdiv(sq, bq), pl.cdiv(sk, bk))
    lanes = 128

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, kv_len=kv_len,
        bq=bq, bk=bk, lanes=lanes,
    )
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, lanes), jnp.float32),
            pltpu.VMEM((bq, lanes), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
        name="flash_attention",
    )
    return fn(q, k, v)
