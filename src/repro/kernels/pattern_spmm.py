"""Pallas TPU kernel: block-pattern sparse matmul (the paper's OU compute).

y[:, tile_t] = sum_k  x[:, block_ids[t,k]] @ w_comp[t, k]

This is the TPU-native form of the paper's mapping (DESIGN §3):

  * w_comp holds only the *nonzero* 128x128 bricks of each output tile
    (zero-row compression after kernel reordering);
  * ``block_ids`` is the weight-index buffer: it drives the x BlockSpec
    ``index_map`` so each grid step DMAs exactly the input block the brick
    needs — the Input Preprocessing Unit as an index map;
  * each grid step is one MXU-aligned [bm, block] @ [block, bn] — the OU;
  * the fp32 accumulator lives in VMEM scratch across the k dimension.

Grid: (m_tiles, n_tiles, k_max), k innermost so the accumulator stays
resident while bricks stream.  VMEM working set per step:
bm*block + block*bn + bm*bn (+ fp32 acc) — with bm = bn = block = 128 and
bf16 inputs ≈ 96 KiB + 64 KiB acc, comfortably inside 16 MiB VMEM; bm can
be raised to 512 for better MXU pipelining (see ops.py autotile).

Padded brick slots (k >= nnz[t]) carry zero weights: they waste a cycle
but contribute zero — ops.py sorts tiles by nnz so the waste concentrates
in few tiles (the paper's grey area analogue).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pattern_spmm_pallas", "pattern_spmm_pallas_quant"]


def _kernel(ids_ref, x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[0, 0], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "bm", "interpret", "out_dtype")
)
def pattern_spmm_pallas(
    x: jax.Array,
    w_comp: jax.Array,
    block_ids: jax.Array,
    block: int = 128,
    bm: int = 128,
    interpret: bool = False,
    out_dtype=None,
):
    """x: [M, K]; w_comp: [T, k_max, block, tile]; block_ids: [T, k_max].

    Returns y: [M, T*tile] in the *reordered* column order (caller applies
    the inverse permutation — the Output Indexing Unit).
    """
    m, k_in = x.shape
    t, k_max, blk, tile = w_comp.shape
    assert blk == block and k_in % block == 0
    out_dtype = out_dtype or x.dtype

    grid = (pl.cdiv(m, bm), t, k_max)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # x block selected by the prefetched index table
            pl.BlockSpec((bm, block), lambda i, j, k, ids: (i, ids[j, k])),
            # the (j, k) brick
            pl.BlockSpec((1, 1, block, tile), lambda i, j, k, ids: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, tile), lambda i, j, k, ids: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, tile), jnp.float32)],
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, t * tile), out_dtype),
        interpret=interpret,
        name="pattern_spmm",
    )
    return fn(block_ids, x, w_comp)


def _kernel_quant(ids_ref, wscale_ref, x_ref, w_ref, o_ref, acc_ref):
    j = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 on the MXU; the brick's row-group dequant scale
    # (prefetched to SMEM alongside the index table) folds into the fp32
    # accumulator, so accumulation across bricks stays exact in fp32
    part = jnp.dot(
        x_ref[...], w_ref[0, 0], preferred_element_type=jnp.int32
    )
    acc_ref[...] += wscale_ref[j, k] * part.astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "bm", "interpret", "out_dtype")
)
def pattern_spmm_pallas_quant(
    xq: jax.Array,
    w_comp: jax.Array,
    block_ids: jax.Array,
    w_scales: jax.Array,
    block: int = 128,
    bm: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Int-quantized variant: xq int8 [M, K]; w_comp int8 bricks with
    per-brick row-group scales ``w_scales`` [T, k_max].

    Returns fp32 partial output [M, T*tile] in reordered column order,
    already dequantized on the weight side; the caller multiplies the
    per-row activation scale in its epilogue (ops.pattern_spmm_raw) and
    applies the inverse permutation.  Grid and specs mirror
    :func:`pattern_spmm_pallas`; ``w_scales`` is the second scalar-prefetch
    operand so each grid step reads its brick scale from SMEM.
    """
    m, k_in = xq.shape
    t, k_max, blk, tile = w_comp.shape
    assert blk == block and k_in % block == 0

    grid = (pl.cdiv(m, bm), t, k_max)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (bm, block), lambda i, j, k, ids, ws: (i, ids[j, k])
            ),
            pl.BlockSpec(
                (1, 1, block, tile), lambda i, j, k, ids, ws: (j, k, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((bm, tile), lambda i, j, k, ids, ws: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, tile), jnp.float32)],
    )
    fn = pl.pallas_call(
        _kernel_quant,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, t * tile), out_dtype),
        interpret=interpret,
        name="pattern_spmm_quant",
    )
    return fn(block_ids, w_scales, xq, w_comp)
