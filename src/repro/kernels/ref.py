"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pattern_spmm_ref", "flash_attention_ref", "ou_mvm_ref"]


def pattern_spmm_ref(
    x: jax.Array, w_comp: jax.Array, block_ids: jax.Array, block: int
) -> jax.Array:
    """y = x @ W_compressed, naive loops.  x: [M, K] -> y: [M, T*tile]."""
    m, k_in = x.shape
    t, k_max, _, tile = w_comp.shape
    xb = x.reshape(m, k_in // block, block)
    cols = []
    for ti in range(t):
        acc = jnp.zeros((m, tile), jnp.float32)
        for k in range(k_max):
            xs = xb[:, block_ids[ti, k]]
            acc = acc + xs.astype(jnp.float32) @ w_comp[ti, k].astype(jnp.float32)
        cols.append(acc)
    return jnp.concatenate(cols, axis=1).astype(x.dtype)


def flash_attention_ref(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BH, Sk, D]
    v: jax.Array,  # [BH, Sk, D]
    scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    sq, sk = s.shape[-2], s.shape[-1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ou_mvm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain dense MVM — the OU walk and the all-zero skip are exact."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32)
