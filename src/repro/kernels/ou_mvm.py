"""Pallas kernel: OU-granular crossbar matrix-vector multiply (paper-faithful).

Executes y = x @ W exactly the way the paper's accelerator does: the
crossbar is walked in Operation Units of ``ou_rows x ou_cols`` cells, one
OU per grid step, accumulating bitline partial sums — and *skipping* OUs
whose selected input slice is all zero, which is the paper's Input
Preprocessing Unit all-zero detection (§IV-A).  The skip is numerically
lossless (a zero input slice contributes nothing), which tests assert.

This kernel is a fidelity artifact: the 9x8 OU is far below the TPU's
native (8,128) tile, so it is validated in interpret mode (and documented
as such).  The *performant* TPU expression of the same idea is
pattern_spmm.py, where the OU is the 128x128 MXU tile.  ``nonzero`` flags
are scalar-prefetched — exactly the role of the paper's control unit
signal path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ou_mvm_pallas"]


def _kernel(flags_ref, x_ref, w_ref, o_ref):
    band = pl.program_id(0)

    @pl.when(band == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(flags_ref[band] != 0)  # all-zero input detection -> skip OU
    def _accumulate():
        x = x_ref[...].astype(jnp.float32)  # [ou_rows]
        w = w_ref[...].astype(jnp.float32)  # [ou_rows, ou_cols]
        o_ref[...] += x @ w


@functools.partial(
    jax.jit, static_argnames=("ou_rows", "ou_cols", "interpret")
)
def ou_mvm_pallas(
    x: jax.Array,  # [R]
    w: jax.Array,  # [R, C]
    ou_rows: int = 9,
    ou_cols: int = 8,
    interpret: bool = True,
):
    r, c = w.shape
    assert x.shape == (r,)
    n_bands = pl.cdiv(r, ou_rows)
    n_groups = pl.cdiv(c, ou_cols)
    pad_r = n_bands * ou_rows - r
    pad_c = n_groups * ou_cols - c
    xp = jnp.pad(x, (0, pad_r))
    wp = jnp.pad(w, ((0, pad_r), (0, pad_c)))

    # control-unit signal: per input band, is any activation nonzero?
    flags = (
        jnp.any(xp.reshape(n_bands, ou_rows) != 0, axis=1).astype(jnp.int32)
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_bands, n_groups),
        in_specs=[
            pl.BlockSpec((ou_rows,), lambda i, j, flags: (i,)),
            pl.BlockSpec((ou_rows, ou_cols), lambda i, j, flags: (i, j)),
        ],
        out_specs=pl.BlockSpec((ou_cols,), lambda i, j, flags: (j,)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_groups * ou_cols,), jnp.float32),
        interpret=interpret,
        name="ou_mvm",
    )
    y = fn(flags, xp, wp)
    return y[:c]
