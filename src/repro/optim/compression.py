"""Gradient compression for cross-pod data parallelism.

At 512+ chips the data-parallel all-reduce of full-precision gradients over
the (slow) pod-interconnect axis dominates step time for small per-device
batches.  We provide int8 uniform quantization with *error feedback*
(Karimireddy et al., 2019): the quantization residual is carried to the next
step, so compression introduces no asymptotic bias and SGD converges at the
uncompressed rate.

Compressed gradients are a pair of trees ``(int8_tree, scale_tree)`` — 4x
fewer wire bytes than fp32 on the pod axis.  ``error_feedback_allreduce``
bundles compress -> pmean -> decompress for use inside shard_map/pmapped
steps.  Tests check the residual-accumulation property and end-to-end
convergence parity.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "CompressionState",
    "init_compression_state",
    "compress_gradients",
    "decompress_gradients",
    "error_feedback_allreduce",
]

CompressionState = Any  # pytree of fp32 residuals, same structure as grads


def init_compression_state(grads_like) -> CompressionState:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like)


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with a per-tensor scale."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_gradients(
    grads, state: CompressionState
) -> tuple[tuple[Any, Any], CompressionState]:
    """Quantize (grad + residual) to int8; the residual carries the error.

    Returns ((int8_tree, scale_tree), new_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(state)
    qs, scales, residuals = [], [], []
    for g, r in zip(leaves, res_leaves):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x)
        qs.append(q)
        scales.append(s)
        residuals.append(x - q.astype(jnp.float32) * s)
    return (
        (treedef.unflatten(qs), treedef.unflatten(scales)),
        treedef.unflatten(residuals),
    )


def decompress_gradients(comp: tuple[Any, Any]):
    q_tree, s_tree = comp
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, s_tree
    )


def error_feedback_allreduce(
    grads, state: CompressionState, axis_name: str
) -> tuple[Any, CompressionState]:
    """int8 all-reduce with error feedback over ``axis_name`` (for use
    inside shard_map: the wire payload is the int8 tree)."""
    (q_tree, s_tree), new_state = compress_gradients(grads, state)

    def reduce_one(q, s):
        return jax.lax.pmean(q.astype(jnp.float32) * s, axis_name)

    reduced = jax.tree.map(reduce_one, q_tree, s_tree)
    return reduced, new_state
