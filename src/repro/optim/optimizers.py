"""Functional optimizers (AdamW / Lion / SGD), schedules and clipping.

No external optimizer library: each optimizer is a pair of pure functions
``init(params) -> state`` and ``update(grads, state, params, lr) ->
(new_params, new_state)``, pytree-polymorphic, jit/pjit friendly.  Optimizer
state inherits the parameter sharding (same tree structure + shapes), so
pjit shards it with the params for free.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "adamw",
    "lion",
    "sgd",
    "global_norm",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup_cosine",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    mu_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=mu_dtype)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1**c)
            vhat = v / (1 - b2**c)
            step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
                jnp.float32
            )
            return (p - lr * step.astype(p.dtype)).astype(p.dtype), m, v

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        # unzip the 3-tuples
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu, "count": count}

    return Optimizer(init, update)


def lion(b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        def upd(g, m, p):
            g = g.astype(jnp.float32)
            direction = jnp.sign(b1 * m + (1 - b1) * g)
            step = direction + weight_decay * p.astype(jnp.float32)
            return (p - lr * step.astype(p.dtype)).astype(p.dtype), b2 * m + (1 - b2) * g

        flat = jax.tree.map(upd, grads, state["mu"], params)
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "count": state["count"] + 1}

    return Optimizer(init, update)


def sgd(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p - lr * m.astype(p.dtype)).astype(p.dtype), m

        flat = jax.tree.map(upd, grads, state["mu"], params)
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu}

    return Optimizer(init, update)


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * (min_frac + (1 - min_frac) * cos)

    return lr


def linear_warmup_cosine(
    base_lr: float, warmup_steps: int, total_steps: int, min_frac: float = 0.1
):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), min_frac)

    def lr(step):
        warm = base_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return lr
