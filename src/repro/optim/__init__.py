from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    lion,
    sgd,
    cosine_schedule,
    linear_warmup_cosine,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.compression import (  # noqa: F401
    CompressionState,
    compress_gradients,
    decompress_gradients,
    error_feedback_allreduce,
    init_compression_state,
)
