"""GQA / MQA / full / sliding-window attention with KV caches.

Three execution regimes, all pure XLA (the Pallas flash kernel in
``repro.kernels`` is the TPU drop-in; the CPU dry-run lowers this path):

  * full     — einsum attention for short sequences (train_4k);
  * chunked  — lax.scan over KV chunks with online softmax for long
               sequences (prefill_32k): O(S * chunk) score memory;
  * decode   — single-token query against a (possibly sequence-sharded)
               KV cache, with optional sliding-window slicing so SWA decode
               reads O(window) not O(S).

Head padding: q heads are padded to a multiple of the TP degree
(``repro.parallel.sharding.padded_heads``); padded heads have zero in/out
projection weights, so they are numerically inert.  GQA grouping uses the
reshape path when padded_q %% kv == 0, otherwise a kv-repeat fallback
(phi3's 10 kv heads).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, linear, linear_init, rope_frequencies
from repro.parallel.sharding import padded_heads

__all__ = ["AttnConfig", "attention_init", "attention_apply", "init_kv_cache"]

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    causal: bool = True
    window: int | None = None  # sliding window (h2o-danube)
    rope_theta: float | None = 10000.0  # None -> no RoPE (whisper)
    model_shards: int = 16
    chunk: int = 1024  # kv chunk for the online-softmax path
    full_attn_max_seq: int = 8192  # einsum path below this
    # decode against a sequence-sharded KV cache:
    #  'gather' — GSPMD resolves (all-gathers cache chunks): baseline.
    #  'flash'  — shard_map flash-decode: each 'model' shard scores its
    #             local cache chunk, log-sum-exp combine via psum; wire
    #             bytes drop from O(cache) to O(B*H*D).  §Perf hillclimb.
    decode_strategy: str = "gather"

    @property
    def hq_pad(self) -> int:
        return padded_heads(self.n_heads, self.model_shards)

    @property
    def grouped(self) -> bool:
        return self.hq_pad % self.n_kv_heads == 0


def attention_init(key, cfg: AttnConfig, param_dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = cfg.hq_pad, cfg.n_kv_heads
    params, specs = {}, {}
    params["wq"], specs["wq"] = linear_init(
        kq, d, hq * dh, "embed", "heads", bias=cfg.qkv_bias,
        param_dtype=param_dtype,
    )
    if cfg.hq_pad != cfg.n_heads:  # zero the padded head columns
        pad = (cfg.hq_pad - cfg.n_heads) * dh
        w = params["wq"]["w"][:, : cfg.n_heads * dh]
        params["wq"]["w"] = jnp.concatenate(
            [w, jnp.zeros((d, pad), param_dtype)], axis=1
        )
    kv_axis = "kv_heads" if (hkv * dh) % cfg.model_shards == 0 else None
    params["wk"], specs["wk"] = linear_init(
        kk, d, hkv * dh, "embed", kv_axis, bias=cfg.qkv_bias,
        param_dtype=param_dtype,
    )
    params["wv"], specs["wv"] = linear_init(
        kv, d, hkv * dh, "embed", kv_axis, bias=cfg.qkv_bias,
        param_dtype=param_dtype,
    )
    params["wo"], specs["wo"] = linear_init(
        ko, hq * dh, d, "heads", "embed", param_dtype=param_dtype,
        scale=(hq * dh) ** -0.5,
    )
    if cfg.hq_pad != cfg.n_heads:  # zero the padded head rows
        pad = (cfg.hq_pad - cfg.n_heads) * dh
        w = params["wo"]["w"][: cfg.n_heads * dh]
        params["wo"]["w"] = jnp.concatenate(
            [w, jnp.zeros((pad, d), param_dtype)], axis=0
        )
    return params, specs


def init_kv_cache(
    cfg: AttnConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
):
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _expand_kv(cfg: AttnConfig, q: jax.Array, k: jax.Array, v: jax.Array):
    """Align kv head count with q heads.  q: [B,S,Hq,D]; k/v: [B,T,Hkv,D].
    Returns q,k,v as [B,H,S,D] with H = hq_pad."""
    b, s, hq, dh = q.shape
    t = k.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if cfg.grouped:
        rep = hq // cfg.n_kv_heads
    else:  # phi3-style: repeat kv to match q heads
        rep = -(-hq // cfg.n_kv_heads)
    kt = jnp.repeat(kt, rep, axis=1)[:, :hq]
    vt = jnp.repeat(vt, rep, axis=1)[:, :hq]
    return qt, kt, vt


def _mask(
    qpos: jax.Array, kpos: jax.Array, causal: bool, window: int | None,
    kv_len: jax.Array | None,
) -> jax.Array:
    qq = qpos[..., :, None]
    kk = kpos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qq.shape, kk.shape), bool)
    if causal:
        m &= qq >= kk
    if window is not None:
        m &= kk > qq - window
    if kv_len is not None:
        kv = (
            kv_len[..., None, None]
            if getattr(kv_len, "ndim", 0)
            else kv_len
        )
        m &= kk < kv
    return m


def _expand_mask(m: jax.Array) -> jax.Array:
    """Broadcast a mask to score rank 4: [S,T] -> [1,1,S,T] (shared across
    batch) or [B,S,T] -> [B,1,S,T] (per-row positions / cache lengths)."""
    return m[None, None] if m.ndim == 2 else m[:, None]


def _full_attention(q, k, v, qpos, kpos, causal, window, kv_len):
    """q,k,v: [B,H,S,D] / [B,H,T,D]."""
    dh = q.shape[-1]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (dh ** -0.5)
    m = _mask(qpos, kpos, causal, window, kv_len)  # [Sq, Tk] / [B, Sq, Tk]
    s = jnp.where(_expand_mask(m), s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def _chunked_attention(q, k, v, qpos, kpos, causal, window, kv_len, chunk):
    """Online-softmax scan over KV chunks.  q/k: [B,H,S,D], v: [B,H,T,Dv]
    (Dv may differ — MLA has 192-dim keys and 128-dim values)."""
    b, h, sq, dh = q.shape
    t = k.shape[2]
    dv = v.shape[-1]
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=2**30)
    kc = k.reshape(b, h, n_chunks, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    pc = kpos.reshape(n_chunks, chunk)
    qf = q.astype(jnp.float32)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, pb = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb.astype(jnp.float32)) * (
            dh ** -0.5
        )
        msk = _mask(qpos, pb, causal, window, kv_len)
        s = jnp.where(_expand_mask(msk), s, _NEG)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, h, sq, 1), _NEG, jnp.float32),
        jnp.zeros((b, h, sq, 1), jnp.float32),
        jnp.zeros((b, h, sq, dv), jnp.float32),
    )
    (m_f, l_f, acc), _ = jax.lax.scan(step, init, (kc, vc, pc))
    return (acc / jnp.maximum(l_f, 1e-30)).astype(q.dtype)


def _flash_decode_sharded(
    cfg: AttnConfig,
    q: jax.Array,  # [B, Hq, 1, D]
    k: jax.Array,  # [B, T, Hkv, D]  (T sequence-sharded over 'model')
    v: jax.Array,  # [B, T, Hkv, D]
    kv_len: jax.Array,  # scalar valid length
    mesh,
) -> jax.Array:
    """Flash-decode over a sequence-sharded cache (shard_map).

    Each 'model' shard scores all heads against its local cache chunk and
    the partial softmaxes merge with a log-sum-exp reduction: pmax of the
    running max, psum of the rescaled denominators and weighted values.
    Replaces the O(cache-bytes) all-gather the GSPMD baseline emits with
    O(B*H*D) combine traffic."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, hq, _, dh = q.shape
    t = k.shape[1]
    scale = dh ** -0.5
    n_shards = mesh.shape.get("model", 1)
    t_loc = t // n_shards
    # batch stays sharded over the DP axes; only heads are gathered (tiny)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ok = b % max(
        1, int(np.prod([mesh.shape[a] for a in dp]))
    ) == 0
    bspec = dp if (dp and batch_ok) else None

    def body(qb, kb, vb, kv_len_b):
        j = jax.lax.axis_index("model") if "model" in mesh.axis_names else 0
        kpos = j * t_loc + jnp.arange(t_loc)  # [T_loc]
        kh = kb.transpose(0, 2, 1, 3)  # [B, Hkv, T_loc, D]
        vh = vb.transpose(0, 2, 1, 3)
        rep = (hq // cfg.n_kv_heads) if cfg.grouped else -(-hq // cfg.n_kv_heads)
        kh = jnp.repeat(kh, rep, axis=1)[:, :hq]
        vh = jnp.repeat(vh, rep, axis=1)[:, :hq]
        s = jnp.einsum(
            "bhqd,bhtd->bhqt", qb.astype(jnp.float32),
            kh.astype(jnp.float32),
        ) * scale  # [B, Hq, 1, T_loc]
        mask = kpos[None, None, None, :] < kv_len_b
        s = jnp.where(mask, s, _NEG)
        m_loc = jnp.max(s, axis=-1, keepdims=True)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m_glob)
        l_loc = p.sum(-1, keepdims=True)
        o_loc = jnp.einsum("bhqt,bhtd->bhqd", p, vh.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, "model")
        o_glob = jax.lax.psum(o_loc, "model")
        return (o_glob / jnp.maximum(l_glob, 1e-30)).astype(qb.dtype)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(bspec), P(bspec, "model"), P(bspec, "model"), P()),
        out_specs=P(bspec),
        check_rep=False,
    )(q, k, v, kv_len)


def attention_apply(
    params,
    cfg: AttnConfig,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [S] (shared) or [B, S] (per-row) positions
    memory: jax.Array | None = None,  # cross-attention source [B, T, D]
    cache: dict | None = None,  # kv cache to read/update
    cache_pos: jax.Array | None = None,  # scalar or [B] write offset
    cache_len: jax.Array | None = None,  # scalar or [B] valid length
) -> tuple[jax.Array, dict | None]:
    """Returns (output [B,S,D], updated cache).

    ``positions`` / ``cache_pos`` / ``cache_len`` accept either the shared
    (scalar / [S]) form — every batch row at the same decode position — or
    the per-row ([B,S] / [B]) form used by continuous batching, where each
    slot advances independently.  Per-row mode keeps the mask-based paths
    (the SWA slice and sharded flash-decode shortcuts need a shared scalar
    position and are skipped)."""
    b, s, d = x.shape
    dh, hq = cfg.d_head, cfg.hq_pad
    per_row = (
        cache_pos is not None and getattr(cache_pos, "ndim", 0) > 0
    ) or (cache_len is not None and getattr(cache_len, "ndim", 0) > 0)

    q = linear(params["wq"], x).reshape(b, s, hq, dh)
    src = memory if memory is not None else x
    t_src = src.shape[1]
    k = linear(params["wk"], src).reshape(b, t_src, cfg.n_kv_heads, dh)
    v = linear(params["wv"], src).reshape(b, t_src, cfg.n_kv_heads, dh)

    if cfg.rope_theta is not None and memory is None:
        freqs = rope_frequencies(dh, cfg.rope_theta)
        pos_b = positions if positions.ndim == 2 else positions[None, :]
        q = apply_rope(q, pos_b, freqs)
        k = apply_rope(k, pos_b, freqs)

    new_cache = cache
    if cache is not None and memory is None:
        pos0 = cache_pos if cache_pos is not None else jnp.int32(0)
        if getattr(pos0, "ndim", 0):
            rows = jnp.arange(b)[:, None]
            cols = pos0[:, None] + jnp.arange(s)[None, :]
            new_cache = {
                "k": cache["k"].at[rows, cols].set(
                    k.astype(cache["k"].dtype)
                ),
                "v": cache["v"].at[rows, cols].set(
                    v.astype(cache["v"].dtype)
                ),
            }
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, pos0, 0, 0)
                ),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, pos0, 0, 0)
                ),
            }
        k_all, v_all = new_cache["k"], new_cache["v"]
        t = k_all.shape[1]
        kpos = jnp.arange(t)
        kv_len = cache_len
        # SWA decode: only the last `window` positions can score — slice
        # them out so decode work is O(window), not O(max_seq)
        if cfg.window is not None and s == 1 and t > cfg.window and not per_row:
            w = cfg.window
            start = jnp.clip(
                (cache_len if cache_len is not None else t) - w, 0, t - w
            )
            k_all = jax.lax.dynamic_slice(k_all, (0, start, 0, 0),
                                          (b, w, cfg.n_kv_heads, dh))
            v_all = jax.lax.dynamic_slice(v_all, (0, start, 0, 0),
                                          (b, w, cfg.n_kv_heads, dh))
            kpos = start + jnp.arange(w)
        k, v = k_all, v_all
    else:
        kpos = jnp.arange(t_src) if memory is not None else positions
        kv_len = None

    # flash-decode fast path: sequence-sharded cache, shard_map combine
    if (
        cfg.decode_strategy == "flash"
        and s == 1
        and cache is not None
        and memory is None
        and cfg.window is None
        and not per_row
    ):
        from repro.parallel.activations import current_mesh

        mesh = current_mesh()
        if mesh is not None and k.shape[1] % mesh.shape.get("model", 1) == 0:
            qh = q.transpose(0, 2, 1, 3)  # [B, Hq, 1, D]
            kv_len_c = kv_len if kv_len is not None else jnp.int32(k.shape[1])
            out = _flash_decode_sharded(cfg, qh, k, v, kv_len_c, mesh)
            out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
            return linear(params["wo"], out.astype(x.dtype)), new_cache

    qh, kh, vh = _expand_kv(cfg, q, k, v)
    causal = cfg.causal and memory is None
    t = kh.shape[2]
    if max(s, t) <= cfg.full_attn_max_seq:
        out = _full_attention(qh, kh, vh, positions, kpos, causal,
                              cfg.window, kv_len)
    else:
        out = _chunked_attention(qh, kh, vh, positions, kpos, causal,
                                 cfg.window, kv_len, cfg.chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return linear(params["wo"], out.astype(x.dtype)), new_cache
