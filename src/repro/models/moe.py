"""Mixture-of-Experts FFN with shared experts and capacity-based dispatch.

Two execution paths:

  * **shard_map EP** (distributed default): expert parallelism shares the
    'model' mesh axis.  Each (data, model) shard sorts only its *local*
    tokens (65k, not 1M-global) and runs only its *local* experts; the
    weighted combine is a local scatter-add followed by a psum over
    'model'.  This keeps the GSPMD partitioner away from distributed-sort
    (which otherwise dominates compile time at 160-256 experts x 512
    devices) and is the production EP design: the only collective is the
    final all-reduce, which XLA fuses with the layer's existing reduction.

  * **single-device path** (smoke tests, no mesh context): same dispatch
    logic with global tokens and all experts.

Dispatch is sort-based (dropless up to the capacity factor): (token, k)
pairs sort by expert id, each expert takes up to C tokens, overflow drops
(capacity semantics; the drop rate at cf=1.25 is <1% for balanced routers
— reported by the MoE bench).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.layers import linear, linear_init, mlp_apply, mlp_init

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int | None = None  # defaults to n_shared * d_ff_expert
    capacity_factor: float = 1.25
    act: str = "swiglu"
    model_shards: int = 16
    router_scale: bool = True  # normalise top-k weights to sum 1


def moe_init(key, cfg: MoEConfig, param_dtype=jnp.float32):
    k_r, k_e, k_s = jax.random.split(key, 3)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    params, specs, static = {}, {}, {}

    params["router"], specs["router"] = linear_init(
        k_r, d, e, "embed", "unsharded", param_dtype=param_dtype
    )

    ke = jax.random.split(k_e, 3)
    scale_in, scale_out = d ** -0.5, f ** -0.5
    params["experts"] = {
        "gate": jax.random.normal(ke[0], (e, d, f), param_dtype) * scale_in,
        "up": jax.random.normal(ke[1], (e, d, f), param_dtype) * scale_in,
        "down": jax.random.normal(ke[2], (e, f, d), param_dtype) * scale_out,
    }
    specs["experts"] = {
        "gate": ("expert", None, None),
        "up": ("expert", None, None),
        "down": ("expert", None, None),
    }
    if cfg.n_shared:
        f_sh = cfg.d_ff_shared or cfg.n_shared * cfg.d_ff_expert
        params["shared"], specs["shared"], static["shared"] = mlp_init(
            k_s, d, f_sh, act=cfg.act, sparse=None,
            model_shards=cfg.model_shards, param_dtype=param_dtype,
        )
    return params, specs, static


def _dispatch_compute_combine(
    xf: jax.Array,  # [T, D] local tokens
    top_w: jax.Array,  # [T, k]
    top_e: jax.Array,  # [T, k] global expert ids
    experts: dict,  # local expert weights [E_loc, ...]
    cfg: MoEConfig,
    e0: jax.Array | int,  # first global expert id owned locally
) -> jax.Array:
    """Capacity-gather local tokens to local experts, run the FFNs, and
    scatter-add the weighted outputs back.  Returns the *partial* output
    (contributions of local experts only)."""
    t, d = xf.shape
    k = cfg.top_k
    e_loc = experts["up"].shape[0]
    cap = int(max(1, round(t * k / cfg.n_experts * cfg.capacity_factor)))

    flat_e = top_e.reshape(-1) - e0  # local expert index (may be OOB)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    local = (flat_e >= 0) & (flat_e < e_loc)
    sort_key = jnp.where(local, flat_e, e_loc)  # foreign pairs sort last

    order = jnp.argsort(sort_key, stable=True)
    e_sorted = sort_key[order]
    tok_sorted = flat_tok[order]
    w_sorted = jnp.where(local[order], flat_w[order], 0.0)
    seg_pos = jnp.arange(e_sorted.shape[0])
    group_start = jnp.searchsorted(e_sorted, jnp.arange(e_loc + 1), side="left")
    pos_in_group = seg_pos - group_start[jnp.clip(e_sorted, 0, e_loc)]
    keep = (e_sorted < e_loc) & (pos_in_group < cap)

    slot = jnp.where(keep, e_sorted * cap + pos_in_group, e_loc * cap)
    gathered = jnp.zeros((e_loc * cap + 1, d), xf.dtype)
    gathered = gathered.at[slot].set(
        jnp.where(keep[:, None], xf[tok_sorted], 0).astype(xf.dtype)
    )
    xe = gathered[:-1].reshape(e_loc, cap, d)

    h = jnp.einsum("ecd,edf->ecf", xe, experts["up"].astype(xf.dtype))
    if cfg.act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, experts["gate"].astype(xf.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(xf.dtype))
    ye = jnp.concatenate(
        [ye.reshape(e_loc * cap, d), jnp.zeros((1, d), ye.dtype)], axis=0
    )

    contrib = ye[slot] * jnp.where(keep, w_sorted, 0.0)[:, None].astype(xf.dtype)
    return jnp.zeros((t, d), xf.dtype).at[tok_sorted].add(contrib)


def _route(params, cfg: MoEConfig, xf: jax.Array):
    logits = linear(params["router"], xf).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_scale:
        top_w = top_w / (top_w.sum(-1, keepdims=True) + 1e-9)
    return top_w.astype(xf.dtype), top_e


def _moe_local(params, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    top_w, top_e = _route(params, cfg, xf)
    out = _dispatch_compute_combine(xf, top_w, top_e, params["experts"], cfg, 0)
    return out.reshape(b, s, d)


def _moe_shard_map(params, cfg: MoEConfig, x: jax.Array, mesh) -> jax.Array:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    has_model = "model" in mesh.axis_names

    def body(router, experts, xl):
        bl, s, d = xl.shape
        xf = xl.reshape(bl * s, d)
        top_w, top_e = _route({"router": router}, cfg, xf)
        if has_model:
            j = jax.lax.axis_index("model")
            e_loc = experts["up"].shape[0]
            e0 = j * e_loc
        else:
            e0 = 0
        out = _dispatch_compute_combine(xf, top_w, top_e, experts, cfg, e0)
        if has_model:
            out = jax.lax.psum(out, "model")
        return out.reshape(bl, s, d)

    espec = P("model") if has_model else P()
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), {k: espec for k in params["experts"]}, P(dp)),
        out_specs=P(dp),
        check_rep=False,
    )(params["router"], params["experts"], x)


def moe_apply(params, static, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """x: [B, S, D] -> [B, S, D]."""
    from repro.parallel.activations import current_mesh

    mesh = current_mesh()
    b = x.shape[0]
    use_shard_map = (
        mesh is not None
        and b % int(np.prod([mesh.shape[a] for a in ("pod", "data")
                             if a in mesh.axis_names])) == 0
        and cfg.n_experts % mesh.shape.get("model", 1) == 0
    )
    if use_shard_map:
        out = _moe_shard_map(params, cfg, x, mesh)
    else:
        out = _moe_local(params, cfg, x)

    if "shared" in params:
        out = out + mlp_apply(params["shared"], static["shared"], x)
    return out
