"""Functional CNNs in JAX: the paper's (modified) VGG16 and a miniature CNN.

The paper's benchmark is VGG16 with all 13 conv layers kept and the FC
stack reduced to a single layer (§V-A) so the evaluation is dominated by
the convolutions the mapping scheme targets.  Params are plain pytrees
(dict of arrays); conv weights use layout [C_out, C_in, Kh, Kw] to line up
with ``repro.core`` mapping code.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.synthetic import VGG16_CONV_CHANNELS

__all__ = [
    "CNNConfig",
    "vgg16_config",
    "mini_cnn_config",
    "init_cnn",
    "cnn_apply",
    "channel_norm",
    "max_pool_2x2",
    "conv_weight_names",
]


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    conv_channels: tuple[tuple[int, int], ...]  # (c_in, c_out) per conv
    pool_after: frozenset[int]  # 1-based conv indices followed by 2x2 maxpool
    num_classes: int
    input_hw: int
    kernel: int = 3

    @property
    def num_convs(self) -> int:
        return len(self.conv_channels)


def vgg16_config(num_classes: int = 10, input_hw: int = 32) -> CNNConfig:
    return CNNConfig(
        conv_channels=tuple(VGG16_CONV_CHANNELS),
        pool_after=frozenset({2, 4, 7, 10, 13}),
        num_classes=num_classes,
        input_hw=input_hw,
    )


def mini_cnn_config(
    num_classes: int = 4, input_hw: int = 12, widths: Sequence[int] = (8, 16, 16)
) -> CNNConfig:
    chans, c = [], 1
    for w in widths:
        chans.append((c, w))
        c = w
    return CNNConfig(
        conv_channels=tuple(chans),
        pool_after=frozenset({len(widths) - 1}),
        num_classes=num_classes,
        input_hw=input_hw,
    )


def init_cnn(cfg: CNNConfig, key: jax.Array) -> dict:
    params: dict = {}
    k = cfg.kernel
    keys = jax.random.split(key, cfg.num_convs + 1)
    hw = cfg.input_hw
    for i, (ci, co) in enumerate(cfg.conv_channels, start=1):
        fan_in = ci * k * k
        params[f"conv{i}"] = {
            "w": jax.random.normal(keys[i - 1], (co, ci, k, k), jnp.float32)
            * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((co,), jnp.float32),
        }
        if i in cfg.pool_after:
            hw //= 2
    c_last = cfg.conv_channels[-1][1]
    feat = c_last  # global average pool
    params["fc"] = {
        "w": jax.random.normal(keys[-1], (feat, cfg.num_classes), jnp.float32)
        * jnp.sqrt(1.0 / feat),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    return params


def _conv2d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [B, C, H, W], w: [C_out, C_in, Kh, Kw], stride 1, 'same'."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def channel_norm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-sample, per-channel scale normalisation (BN stand-in, stateless).

    Shared by ``cnn_apply`` and the compiled-engine executor so both paths
    apply bit-identical normalisation.  x: [B, C, H, W].

    The reduction runs over the spatial axes ``(2, 3)`` only — never the
    batch axis — so a sample's activations (and therefore its logits) do
    not depend on which other samples share the batch.  That invariance is
    what lets the serving layer zero-pad dead batch slots: an all-zero row
    normalises against its own statistics and stays numerically inert for
    every live row.
    """
    return x / (jnp.std(x, axis=(2, 3), keepdims=True) + eps)


def max_pool_2x2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 max pool.  x: [B, C, H, W]."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def cnn_apply(cfg: CNNConfig, params: dict, x: jax.Array) -> jax.Array:
    """Forward pass -> logits [B, num_classes].  x: [B, C, H, W]."""
    for i in range(1, cfg.num_convs + 1):
        p = params[f"conv{i}"]
        x = _conv2d(x, p["w"]) + p["b"][None, :, None, None]
        x = jax.nn.relu(channel_norm(x))
        if i in cfg.pool_after:
            x = max_pool_2x2(x)
    x = x.mean(axis=(2, 3))  # global average pool
    return x @ params["fc"]["w"] + params["fc"]["b"]


def conv_weight_names(cfg: CNNConfig) -> list[str]:
    return [f"conv{i}" for i in range(1, cfg.num_convs + 1)]
