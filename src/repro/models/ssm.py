"""Mamba-2 SSD (state-space duality) block.

Chunked matmul-form SSD (Dao & Gu 2024): the sequence is split into chunks;
within a chunk the output is a masked quadratic form (MXU-friendly), across
chunks a compact state [H, P, N] is carried by a linear recurrence
(lax.scan).  Decode is the single-step recurrence on the cached state.

Technique note (DESIGN §4): the paper's pattern sparsity applies to
in_proj / out_proj (plain matmuls); the SSD recurrence itself has no weight
matrix to prune — inapplicability documented, arch still fully supported.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import linear, linear_init, rmsnorm, rmsnorm_init

__all__ = ["SSMConfig", "ssm_init", "ssm_apply", "init_ssm_cache"]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    model_shards: int = 16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def ssm_init(key, cfg: SSMConfig, param_dtype=jnp.float32):
    keys = jax.random.split(key, 4)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    params, specs = {}, {}
    # in_proj -> [z, xBC, dt]
    d_in_proj = 2 * di + 2 * cfg.n_groups * cfg.d_state + h
    params["in_proj"], specs["in_proj"] = linear_init(
        keys[0], d, d_in_proj, "embed", "ff", param_dtype=param_dtype
    )
    params["conv_w"] = (
        jax.random.normal(keys[1], (cfg.d_conv, cfg.conv_dim), param_dtype)
        * (cfg.d_conv ** -0.5)
    )
    specs["conv_w"] = ("conv", "ff")
    params["conv_b"] = jnp.zeros((cfg.conv_dim,), param_dtype)
    specs["conv_b"] = ("ff",)
    params["A_log"] = jnp.log(
        jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
    ).astype(param_dtype)
    specs["A_log"] = ("heads",)
    params["D"] = jnp.ones((h,), param_dtype)
    specs["D"] = ("heads",)
    params["dt_bias"] = jnp.zeros((h,), param_dtype)
    specs["dt_bias"] = ("heads",)
    params["norm"], specs["norm"] = rmsnorm_init(di, param_dtype)
    params["out_proj"], specs["out_proj"] = linear_init(
        keys[2], di, d, "ff", "embed", param_dtype=param_dtype
    )
    return params, specs


def init_ssm_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        "state": jnp.zeros(
            (batch, cfg.n_heads, cfg.head_dim, cfg.d_state), dtype
        ),
    }


def _split_in_proj(cfg: SSMConfig, zxbcdt: jax.Array):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim :]  # [.., h]
    return z, xbc, dt


def _causal_conv(cfg: SSMConfig, xbc: jax.Array, w, b, conv_state=None):
    """Depthwise causal conv1d.  xbc: [B,S,C]."""
    k = cfg.d_conv
    if conv_state is not None:
        xin = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    else:
        xin = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    s_out = xbc.shape[1]
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        out = out + xin[:, i : i + s_out].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    out = out + b.astype(jnp.float32)
    new_state = xin[:, -(k - 1) :] if k > 1 else None
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def _ssd_chunked(cfg, xh, dt, a, B, C, init_state):
    """Chunked SSD scan.

    xh: [Bt, S, H, P]; dt: [Bt, S, H]; a = -exp(A_log): [H];
    B, C: [Bt, S, G, N]; init_state: [Bt, H, P, N].
    Returns (y [Bt,S,H,P], final_state).
    """
    bsz, s, h, p = xh.shape
    g, n = B.shape[2], B.shape[3]
    q = cfg.chunk
    nc = s // q
    assert s % q == 0, "sequence must be a multiple of the SSD chunk"

    # per-head log-decay per step: [Bt, S, H]
    da = dt * a[None, None, :]
    dax = xh * dt[..., None]  # dt-weighted input

    # reshape into chunks
    da_c = da.reshape(bsz, nc, q, h)
    x_c = dax.reshape(bsz, nc, q, h, p)
    B_c = B.reshape(bsz, nc, q, g, n)
    C_c = C.reshape(bsz, nc, q, g, n)

    # cumulative decay within chunk
    cum = jnp.cumsum(da_c, axis=2)  # [Bt,nc,q,h]
    total = cum[:, :, -1]  # [Bt,nc,h]

    # intra-chunk (masked quadratic) term
    # L[i,j] = exp(cum[i] - cum[j]) for i >= j.  Mask the exponent BEFORE
    # exp: the upper triangle has positive exponents that overflow to inf,
    # and where(mask, inf, 0) back-propagates 0 * inf = NaN.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [Bt,nc,qi,qj,h]
    mask = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    L = jnp.exp(diff)
    # scores between positions via B,C (broadcast groups over heads)
    rep = h // g
    B_h = jnp.repeat(B_c, rep, axis=3)  # [Bt,nc,q,h,n]
    C_h = jnp.repeat(C_c, rep, axis=3)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", C_h, B_h)  # [Bt,nc,qi,qj,h]
    y_intra = jnp.einsum("bcqkh,bcqkh,bckhp->bcqhp", cb, L, x_c)

    # chunk-final states: S_c = sum_j exp(total - cum[j]) * B_j x_j^T
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # [Bt,nc,q,h]
    states = jnp.einsum(
        "bcqh,bcqhn,bcqhp->bchpn", decay_to_end, B_h, x_c
    )  # [Bt,nc,h,p,n]

    # inter-chunk recurrence over chunk index
    def scan_fn(carry, xs):
        st = carry  # [Bt,h,p,n]
        state_c, tot_c = xs  # [Bt,h,p,n], [Bt,h]
        out_prev = st
        st = st * jnp.exp(tot_c)[:, :, None, None] + state_c
        return st, out_prev

    (final_state, prev_states) = jax.lax.scan(
        scan_fn,
        init_state,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [Bt,nc,h,p,n]

    # inter-chunk contribution: y_j += C_j exp(cum_j) state_prev
    decay_in = jnp.exp(cum)  # [Bt,nc,q,h]
    y_inter = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", C_h, prev_states, decay_in
    )
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final_state


def ssm_apply(
    params,
    cfg: SSMConfig,
    x: jax.Array,  # [B,S,D]
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    b, s, d = x.shape
    h, p, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups

    zxbcdt = linear(params["in_proj"], x)
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # [B,S,H]
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # [H]

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(
        cfg, xbc, params["conv_w"], params["conv_b"], conv_state
    )
    xh = xbc[..., : cfg.d_inner].reshape(b, s, h, p).astype(jnp.float32)
    Bmat = xbc[..., cfg.d_inner : cfg.d_inner + g * n].reshape(b, s, g, n)
    Cmat = xbc[..., cfg.d_inner + g * n :].reshape(b, s, g, n)
    Bmat = Bmat.astype(jnp.float32)
    Cmat = Cmat.astype(jnp.float32)

    init_state = (
        cache["state"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    if s == 1:  # decode: single recurrence step
        rep = h // g
        B_h = jnp.repeat(Bmat[:, 0], rep, axis=1)  # [B,h,n]
        C_h = jnp.repeat(Cmat[:, 0], rep, axis=1)
        da = jnp.exp(dt[:, 0] * a[None, :])  # [B,h]
        dx = xh[:, 0] * dt[:, 0][..., None]  # [B,h,p]
        state = init_state * da[:, :, None, None] + jnp.einsum(
            "bhp,bhn->bhpn", dx, B_h
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, C_h)[:, None]  # [B,1,h,p]
        final_state = state
    else:
        pad = (-s) % cfg.chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, final_state = _ssd_chunked(cfg, xh, dt, a, Bmat, Cmat, init_state)
        y = y[:, :s]

    y = y + xh[:, :s] * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = linear(params["out_proj"], y)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": final_state.astype(cache["state"].dtype)}
    return out, new_cache
