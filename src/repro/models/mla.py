"""Multi-head Latent Attention (DeepSeek-V2/V3).

KV state is compressed to a ``kv_lora``-dim latent (plus a shared RoPE key
of ``d_rope`` dims): the cache per token is kv_lora + d_rope floats
(576 for DeepSeek), independent of head count.

Two compute paths:
  * prefill — decompress K/V per head and run standard (chunked) attention;
  * decode  — *absorbed* form: W_uk is folded into the query and W_uv into
    the output projection, so attention runs entirely in the latent space
    (per-token cost O(h * kv_lora), no per-head KV materialisation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.attention import _chunked_attention, _full_attention
from repro.models.layers import (
    apply_rope,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
    rope_frequencies,
)

__all__ = ["MLAConfig", "mla_init", "mla_apply", "init_mla_cache"]

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora: int = 512
    q_lora: int = 1536
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    rope_theta: float = 10000.0
    model_shards: int = 16
    chunk: int = 1024
    full_attn_max_seq: int = 8192


def mla_init(key, cfg: MLAConfig, param_dtype=jnp.float32):
    keys = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    params, specs = {}, {}
    params["wq_a"], specs["wq_a"] = linear_init(
        keys[0], d, cfg.q_lora, "embed", "q_lora", param_dtype=param_dtype
    )
    params["q_norm"], specs["q_norm"] = rmsnorm_init(cfg.q_lora, param_dtype)
    params["wq_b"], specs["wq_b"] = linear_init(
        keys[1], cfg.q_lora, h * (cfg.d_nope + cfg.d_rope), "q_lora", "heads",
        param_dtype=param_dtype,
    )
    params["wkv_a"], specs["wkv_a"] = linear_init(
        keys[2], d, cfg.kv_lora + cfg.d_rope, "embed", "kv_lora",
        param_dtype=param_dtype,
    )
    params["kv_norm"], specs["kv_norm"] = rmsnorm_init(cfg.kv_lora, param_dtype)
    params["wkv_b"], specs["wkv_b"] = linear_init(
        keys[3], cfg.kv_lora, h * (cfg.d_nope + cfg.d_v), "kv_lora", "heads",
        param_dtype=param_dtype,
    )
    params["wo"], specs["wo"] = linear_init(
        keys[4], h * cfg.d_v, d, "heads", "embed", param_dtype=param_dtype
    )
    return params, specs


def init_mla_cache(cfg: MLAConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.d_rope), dtype),
    }


def _project_q(params, cfg: MLAConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    q = linear(params["wq_b"], rmsnorm(params["q_norm"], linear(params["wq_a"], x)))
    q = q.reshape(b, s, h, cfg.d_nope + cfg.d_rope)
    q_nope, q_rope = q[..., : cfg.d_nope], q[..., cfg.d_nope :]
    freqs = rope_frequencies(cfg.d_rope, cfg.rope_theta)
    pos_b = positions if positions.ndim == 2 else positions[None, :]
    q_rope = apply_rope(q_rope, pos_b, freqs)
    return q_nope, q_rope


def _compress_kv(params, cfg: MLAConfig, x, positions):
    kv = linear(params["wkv_a"], x)  # [B,S,kv_lora + d_rope]
    c_kv = rmsnorm(params["kv_norm"], kv[..., : cfg.kv_lora])
    k_rope = kv[..., cfg.kv_lora :]
    freqs = rope_frequencies(cfg.d_rope, cfg.rope_theta)
    pos_b = positions if positions.ndim == 2 else positions[None, :]
    k_rope = apply_rope(k_rope, pos_b, freqs)
    return c_kv, k_rope


def mla_apply(
    params,
    cfg: MLAConfig,
    x: jax.Array,  # [B,S,D]
    positions: jax.Array,  # [S] (shared) or [B,S] (per-row)
    cache: dict | None = None,
    cache_pos: jax.Array | None = None,  # scalar or [B]
    cache_len: jax.Array | None = None,  # scalar or [B]
    absorbed: bool | None = None,
) -> tuple[jax.Array, dict | None]:
    b, s, d = x.shape
    h = cfg.n_heads
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5

    q_nope, q_rope = _project_q(params, cfg, x, positions)
    c_kv_new, k_rope_new = _compress_kv(params, cfg, x, positions)

    new_cache = cache
    if cache is not None:
        pos0 = cache_pos if cache_pos is not None else jnp.int32(0)
        if getattr(pos0, "ndim", 0):  # per-row write offsets [B]
            rows = jnp.arange(b)[:, None]
            cols = pos0[:, None] + jnp.arange(s)[None, :]
            new_cache = {
                "c_kv": cache["c_kv"].at[rows, cols].set(
                    c_kv_new.astype(cache["c_kv"].dtype)
                ),
                "k_rope": cache["k_rope"].at[rows, cols].set(
                    k_rope_new.astype(cache["k_rope"].dtype)
                ),
            }
        else:
            new_cache = {
                "c_kv": jax.lax.dynamic_update_slice(
                    cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype),
                    (0, pos0, 0),
                ),
                "k_rope": jax.lax.dynamic_update_slice(
                    cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
                    (0, pos0, 0),
                ),
            }
        c_kv, k_rope = new_cache["c_kv"], new_cache["k_rope"]
        t = c_kv.shape[1]
        kpos = jnp.arange(t)
    else:
        c_kv, k_rope = c_kv_new, k_rope_new
        t = s
        kpos = positions

    if absorbed is None:
        absorbed = s == 1  # decode default

    wkv_b = params["wkv_b"]["w"].reshape(cfg.kv_lora, h, cfg.d_nope + cfg.d_v)
    w_uk = wkv_b[..., : cfg.d_nope]  # [kv_lora, h, d_nope]
    w_uv = wkv_b[..., cfg.d_nope :]  # [kv_lora, h, d_v]

    if absorbed:
        # fold W_uk into q: q_abs [B,S,h,kv_lora]
        q_abs = jnp.einsum("bshd,lhd->bshl", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        s_lat = jnp.einsum("bshl,btl->bhst", q_abs,
                           c_kv.astype(jnp.float32))
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                            k_rope.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale
        qq = positions[..., :, None]
        kk = kpos[..., None, :]
        mask = qq >= kk
        if cache_len is not None:
            kv = (
                cache_len[..., None, None]
                if getattr(cache_len, "ndim", 0)
                else cache_len
            )
            mask &= kk < kv
        # scores are [B,h,S,T]: shared masks broadcast as [1,1,S,T],
        # per-row masks as [B,1,S,T]
        mask = mask[None, None] if mask.ndim == 2 else mask[:, None]
        scores = jnp.where(mask, scores, _NEG)
        p = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bshl", p, c_kv.astype(jnp.float32))
        out = jnp.einsum("bshl,lhv->bshv", o_lat, w_uv.astype(jnp.float32))
    else:
        # decompress per head and use the standard attention paths
        kv_len = cache_len
        k_nope = jnp.einsum("btl,lhd->bthd", c_kv.astype(jnp.float32),
                            w_uk.astype(jnp.float32))
        v = jnp.einsum("btl,lhv->bthv", c_kv.astype(jnp.float32),
                       w_uv.astype(jnp.float32))
        k_rope_h = jnp.broadcast_to(
            k_rope[:, :, None, :].astype(jnp.float32),
            (b, t, h, cfg.d_rope),
        )
        k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        q_full = jnp.concatenate(
            [q_nope.astype(jnp.float32), q_rope.astype(jnp.float32)], -1
        )
        qh = q_full.transpose(0, 2, 1, 3)
        kh = k_full.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        if max(s, t) <= cfg.full_attn_max_seq:
            out = _full_attention(qh, kh, vh, positions, kpos, True, None,
                                  kv_len)
        else:
            out = _chunked_attention(qh, kh, vh, positions, kpos, True, None,
                                     kv_len, cfg.chunk)
        out = out.transpose(0, 2, 1, 3)  # [B,S,h,d_v]

    out = out.reshape(b, s, h * cfg.d_v).astype(x.dtype)
    return linear(params["wo"], out), new_cache
