"""Config-driven transformer assembly covering all assigned architectures.

A model is a sequence of layers; each layer is a (mixer, ffn) pair:

  mixer: 'attn' | 'swa' | 'mla' | 'ssm' | 'xattn' (decoder self+cross)
  ffn:   'mlp' | 'moe' | 'none'

``layer_types`` lists every layer.  The stack is factored into an optional
non-periodic *prefix* (DeepSeek's leading dense layers) plus a repeating
*period* (jamba's 8-layer attn/mamba/MoE unit, period 1 for homogeneous
models); period params are stacked [n_periods, ...] and executed with
``lax.scan`` (+ optional remat), so compile time and HLO size are
O(period), not O(n_layers).

Enc-dec (whisper) adds an encoder stack and cross-attention in the decoder;
VLM (paligemma) accepts precomputed prefix embeddings (frontends are stubs
per the assignment).  MTP (DeepSeek-V3) adds the extra next-next-token
layer + shared head.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (
    AttnConfig,
    attention_apply,
    attention_init,
    init_kv_cache,
)
from repro.models.layers import (
    PatternSparseConfig,
    embed_init,
    layernorm,
    layernorm_init,
    linear,
    linear_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.mla import MLAConfig, init_mla_cache, mla_apply, mla_init
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.ssm import SSMConfig, init_ssm_cache, ssm_apply, ssm_init
from repro.parallel.activations import shard_activation
from repro.parallel.sharding import pad_to_multiple

__all__ = ["ModelConfig", "init_params", "apply_model", "init_cache",
           "model_flops_per_token", "count_params"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    layer_types: tuple[tuple[str, str], ...]  # (mixer, ffn) per layer
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 128
    qkv_bias: bool = False
    window: int | None = None
    rope_theta: float | None = 10000.0
    # ffn
    d_ff: int = 0
    act: str = "swiglu"
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    mtp: bool = False
    # enc-dec (whisper): encoder layer count; encoder input is stub frame
    # embeddings [B, enc_seq, d_model]
    encoder_layers: int = 0
    enc_seq: int = 0
    # vlm (paligemma): prefix patch embeddings [B, n_patches, d_model]
    prefix_len: int = 0
    # sparsity (the paper's technique, block-granular)
    sparse: PatternSparseConfig | None = None
    # numerics / distribution
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    model_shards: int = 16
    remat: bool = True
    vocab_pad: int = 256
    max_seq: int = 4096  # cache capacity for serving
    decode_strategy: str = "gather"  # 'gather' | 'flash' (see AttnConfig)

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab, self.vocab_pad)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def attn_cfg(self, window: bool) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.d_head,
            qkv_bias=self.qkv_bias,
            window=self.window if window else None,
            rope_theta=self.rope_theta,
            model_shards=self.model_shards,
            decode_strategy=self.decode_strategy,
        )


def find_structure(
    layer_types: Sequence[tuple[str, str]]
) -> tuple[int, int]:
    """Returns (prefix_len, period) minimizing the period over small
    prefixes — the scan body is O(period), so a 1-layer prefix + period-1
    body (DeepSeek) must win over prefix-0 + period-n (fully unrolled)."""
    n = len(layer_types)
    best = (0, n if n else 1)
    for prefix in range(0, min(n, 5)):
        body = layer_types[prefix:]
        m = len(body)
        if m == 0:
            if 1 < best[1]:
                best = (prefix, 1)
            continue
        for period in range(1, m + 1):
            if m % period:
                continue
            if all(body[i] == body[i % period] for i in range(m)):
                if period < best[1]:
                    best = (prefix, period)
                break
    return best


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, ltype: tuple[str, str], decoder: bool):
    mixer, ffn = ltype
    pdt = cfg.pdtype()
    norm_init = rmsnorm_init if cfg.norm == "rmsnorm" else layernorm_init
    keys = jax.random.split(key, 6)
    params: dict = {}
    specs: dict = {}
    static: dict = {"mixer": mixer, "ffn": ffn}

    params["norm1"], specs["norm1"] = norm_init(cfg.d_model, pdt)
    if mixer in ("attn", "swa"):
        acfg = cfg.attn_cfg(window=mixer == "swa")
        params["attn"], specs["attn"] = attention_init(keys[0], acfg, pdt)
        static["attn_cfg"] = acfg
    elif mixer == "xattn":
        acfg = cfg.attn_cfg(window=False)
        params["attn"], specs["attn"] = attention_init(keys[0], acfg, pdt)
        static["attn_cfg"] = acfg
        xcfg = dataclasses.replace(acfg, causal=False, rope_theta=None)
        params["xnorm"], specs["xnorm"] = norm_init(cfg.d_model, pdt)
        params["xattn"], specs["xattn"] = attention_init(keys[1], xcfg, pdt)
        static["xattn_cfg"] = xcfg
    elif mixer == "mla":
        assert cfg.mla is not None
        params["attn"], specs["attn"] = mla_init(keys[0], cfg.mla, pdt)
        static["mla_cfg"] = cfg.mla
    elif mixer == "ssm":
        assert cfg.ssm is not None
        params["attn"], specs["attn"] = ssm_init(keys[0], cfg.ssm, pdt)
        static["ssm_cfg"] = cfg.ssm
    else:
        raise ValueError(f"unknown mixer {mixer!r}")

    if ffn != "none":
        params["norm2"], specs["norm2"] = norm_init(cfg.d_model, pdt)
    if ffn == "mlp":
        params["mlp"], specs["mlp"], static["mlp"] = mlp_init(
            keys[2], cfg.d_model, cfg.d_ff, act=cfg.act, sparse=cfg.sparse,
            model_shards=cfg.model_shards, param_dtype=pdt,
        )
    elif ffn == "moe":
        assert cfg.moe is not None
        params["moe"], specs["moe"], static["moe"] = moe_init(
            keys[3], cfg.moe, pdt
        )
    elif ffn != "none":
        raise ValueError(f"unknown ffn {ffn!r}")
    return params, specs, static


def init_params(cfg: ModelConfig, key: jax.Array):
    """Returns (params, specs, statics) for the full model."""
    pdt = cfg.pdtype()
    norm_init = rmsnorm_init if cfg.norm == "rmsnorm" else layernorm_init
    keys = jax.random.split(key, 16)
    params: dict = {}
    specs: dict = {}
    statics: dict = {"cfg": cfg}

    params["embed"], specs["embed"] = embed_init(
        keys[0], cfg.padded_vocab, cfg.d_model, pdt
    )
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = linear_init(
            keys[1], cfg.d_model, cfg.padded_vocab, "embed", "vocab",
            param_dtype=pdt,
        )
    params["final_norm"], specs["final_norm"] = norm_init(cfg.d_model, pdt)

    if cfg.rope_theta is None:  # whisper-style learned decoder positions
        params["dec_pos"] = (
            jax.random.normal(keys[11], (cfg.max_seq, cfg.d_model), pdt) * 0.02
        )
        specs["dec_pos"] = ("seq", "embed")

    prefix, period = find_structure(cfg.layer_types)
    statics["prefix"] = prefix
    statics["period"] = period
    n_periods = (cfg.n_layers - prefix) // period
    statics["n_periods"] = n_periods

    params["prefix_layers"] = []
    specs["prefix_layers"] = []
    statics["prefix_layers"] = []
    for i in range(prefix):
        p, s, st = _layer_init(keys[2 + i % 8], cfg, cfg.layer_types[i], True)
        params["prefix_layers"].append(p)
        specs["prefix_layers"].append(s)
        statics["prefix_layers"].append(st)

    # period positions: stack params across periods
    params["body"] = []
    specs["body"] = []
    statics["body"] = []
    for j in range(period):
        stacked_p = []
        sspec = None
        sstatic = None
        for rep in range(n_periods):
            lk = jax.random.fold_in(keys[10], j * 1000 + rep)
            p, s, st = _layer_init(
                lk, cfg, cfg.layer_types[prefix + rep * period + j], True
            )
            stacked_p.append(p)
            sspec, sstatic = s, st
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked_p)
        sspec = jax.tree.map(
            lambda sp: (None,) + tuple(sp),
            sspec,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
        params["body"].append(stacked)
        specs["body"].append(sspec)
        statics["body"].append(sstatic)

    # encoder (whisper): homogeneous stack -> stacked params + lax.scan
    if cfg.encoder_layers:
        params["enc_pos"] = (
            jax.random.normal(keys[12], (cfg.enc_seq, cfg.d_model), pdt) * 0.02
        )
        specs["enc_pos"] = ("seq", "embed")
        enc_ps = []
        enc_spec = enc_st = None
        for i in range(cfg.encoder_layers):
            lk = jax.random.fold_in(keys[13], i)
            p, s, st = _layer_init(lk, cfg, ("attn", "mlp"), False)
            enc_ps.append(p)
            enc_spec, enc_st = s, st
        # encoder attention is bidirectional, no rope (learned positions)
        enc_st = dict(enc_st)
        enc_st["attn_cfg"] = dataclasses.replace(
            enc_st["attn_cfg"], causal=False, rope_theta=None
        )
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_ps)
        specs["encoder"] = jax.tree.map(
            lambda sp: (None,) + tuple(sp),
            enc_spec,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
        statics["encoder"] = enc_st
        params["enc_norm"], specs["enc_norm"] = norm_init(cfg.d_model, pdt)

    if cfg.mtp:
        p, s, st = _layer_init(keys[14], cfg, cfg.layer_types[-1], True)
        params["mtp_layer"], specs["mtp_layer"] = p, s
        statics["mtp_layer"] = st
        params["mtp_proj"], specs["mtp_proj"] = linear_init(
            keys[15], 2 * cfg.d_model, cfg.d_model, "embed", "embed",
            param_dtype=pdt,
        )
        params["mtp_norm"], specs["mtp_norm"] = norm_init(cfg.d_model, pdt)

    return params, specs, statics


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _layer_cache(cfg: ModelConfig, static, batch: int, max_seq: int, dtype):
    mixer = static["mixer"]
    if mixer in ("attn", "swa"):
        return init_kv_cache(static["attn_cfg"], batch, max_seq, dtype)
    if mixer == "xattn":
        return {
            "self": init_kv_cache(static["attn_cfg"], batch, max_seq, dtype),
        }
    if mixer == "mla":
        return init_mla_cache(static["mla_cfg"], batch, max_seq, dtype)
    if mixer == "ssm":
        return init_ssm_cache(static["ssm_cfg"], batch)
    raise ValueError(mixer)


def init_cache(
    statics, batch: int, max_seq: int | None = None, dtype=jnp.bfloat16
):
    cfg: ModelConfig = statics["cfg"]
    max_seq = max_seq or cfg.max_seq
    cache: dict = {"prefix_layers": [], "body": []}
    for st in statics["prefix_layers"]:
        cache["prefix_layers"].append(_layer_cache(cfg, st, batch, max_seq, dtype))
    for st in statics["body"]:
        one = _layer_cache(cfg, st, batch, max_seq, dtype)
        cache["body"].append(
            jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (statics["n_periods"],) + x.shape
                ),
                one,
            )
        )
    if cfg.encoder_layers:
        cache["memory"] = jnp.zeros(
            (batch, cfg.enc_seq, cfg.d_model), dtype
        )
    return cache


def cache_specs(statics):
    """Logical axis specs for the cache pytree (batch/seq sharding)."""
    def leaf_spec(path_leaf):
        x = path_leaf
        if x.ndim == 4 and x.shape[1] > 1:  # [B,S,H,D] kv cache
            return ("data_only", "seq_shard", None, None)
        if x.ndim == 5:  # stacked [L,B,S,H,D]
            return (None, "data_only", "seq_shard", None, None)
        if x.ndim == 3:  # [B,S,D] (mla latent / memory)
            return ("data_only", "seq_shard", None)
        if x.ndim == 2:
            return ("data_only", None)
        return tuple(["data_only"] + [None] * (x.ndim - 1))
    return None  # resolved dynamically in launch (shape-dependent)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _apply_layer(
    params, static, cfg: ModelConfig, x, positions, cache, cache_pos,
    cache_len, memory,
):
    norm = rmsnorm if cfg.norm == "rmsnorm" else layernorm
    mixer = static["mixer"]
    h = norm(params["norm1"], x)
    new_cache = cache
    if mixer in ("attn", "swa"):
        out, new_cache = attention_apply(
            params["attn"], static["attn_cfg"], h, positions,
            cache=cache, cache_pos=cache_pos, cache_len=cache_len,
        )
    elif mixer == "xattn":
        out, self_cache = attention_apply(
            params["attn"], static["attn_cfg"], h, positions,
            cache=cache["self"] if cache else None,
            cache_pos=cache_pos, cache_len=cache_len,
        )
        x = x + out
        h = norm(params["xnorm"], x)
        out, _ = attention_apply(
            params["xattn"], static["xattn_cfg"], h, positions,
            memory=memory,
        )
        new_cache = {"self": self_cache} if cache else None
    elif mixer == "mla":
        out, new_cache = mla_apply(
            params["attn"], static["mla_cfg"], h, positions,
            cache=cache, cache_pos=cache_pos, cache_len=cache_len,
        )
    elif mixer == "ssm":
        out, new_cache = ssm_apply(params["attn"], static["ssm_cfg"], h, cache)
    x = x + out

    ffn = static["ffn"]
    if ffn != "none":
        h = norm(params["norm2"], x)
        if ffn == "mlp":
            x = x + mlp_apply(params["mlp"], static["mlp"], h)
        else:
            x = x + moe_apply(params["moe"], static["moe"], cfg.moe, h)
    x = shard_activation(x, ("batch", "seq_shard", None))
    return x, new_cache


def _encode(params, statics, cfg: ModelConfig, frames: jax.Array):
    """Whisper encoder over stub frame embeddings [B, enc_seq, d]."""
    norm = rmsnorm if cfg.norm == "rmsnorm" else layernorm
    x = frames.astype(cfg.cdtype()) + params["enc_pos"].astype(cfg.cdtype())
    pos = jnp.arange(frames.shape[1])
    st = statics["encoder"]

    def enc_fn(carry, p):
        y, _ = _apply_layer(p, st, cfg, carry, pos, None, None, None, None)
        return y, None

    fn = enc_fn
    if cfg.remat:
        fn = jax.checkpoint(
            enc_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(fn, x, params["encoder"])
    return norm(params["enc_norm"], x)


def apply_model(
    params,
    statics,
    tokens: jax.Array,  # [B, S] int32
    positions: jax.Array | None = None,  # [S] (shared) or [B, S] (per-row)
    cache=None,
    cache_pos: jax.Array | None = None,  # scalar or [B] (per-slot decode)
    cache_len: jax.Array | None = None,  # scalar or [B]
    prefix_embeds: jax.Array | None = None,  # [B, P, d] (vlm stub)
    frames: jax.Array | None = None,  # [B, enc_seq, d] (audio stub)
):
    """Forward pass.  Returns (logits [B, S(+P), vocab_padded], new_cache)."""
    cfg: ModelConfig = statics["cfg"]
    cdt = cfg.cdtype()
    b, s = tokens.shape

    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(cdt)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)  # gemma convention
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cdt), x], axis=1)
        s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s)
    if "dec_pos" in params:
        dp = jnp.take(params["dec_pos"], positions, axis=0).astype(cdt)
        x = x + (dp if positions.ndim == 2 else dp[None])
    x = shard_activation(x, ("batch", "seq_shard", None))

    memory = None
    if cfg.encoder_layers:
        if frames is not None:
            memory = _encode(params, statics, cfg, frames)
        elif cache is not None:
            memory = cache.get("memory")

    new_cache = {"prefix_layers": [], "body": []} if cache is not None else None

    for i, (p, st) in enumerate(
        zip(params["prefix_layers"], statics["prefix_layers"])
    ):
        c = cache["prefix_layers"][i] if cache is not None else None
        x, nc = _apply_layer(
            p, st, cfg, x, positions, c, cache_pos, cache_len, memory
        )
        if cache is not None:
            new_cache["prefix_layers"].append(nc)

    period = statics["period"]
    if statics["n_periods"] > 0:
        body_statics = statics["body"]

        def period_fn(carry, xs):
            x = carry
            p_stk = xs[0]
            c_stk = xs[1] if cache is not None else [None] * period
            new_cs = []
            for j in range(period):
                xj, ncj = _apply_layer(
                    p_stk[j], body_statics[j], cfg, x, positions,
                    c_stk[j] if cache is not None else None,
                    cache_pos, cache_len, memory,
                )
                x = xj
                new_cs.append(ncj if cache is not None else jnp.zeros((), cdt))
            return x, new_cs

        fn = period_fn
        if cfg.remat:
            fn = jax.checkpoint(
                period_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        xs = (params["body"], cache["body"] if cache is not None else None)
        if cache is None:
            xs = (params["body"],)
            fn2 = lambda c, x_: fn(c, (x_[0], None))
        else:
            fn2 = fn
        x, new_body = jax.lax.scan(fn2, x, xs)
        if cache is not None:
            new_cache["body"] = new_body

    norm = rmsnorm if cfg.norm == "rmsnorm" else layernorm
    hidden = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = hidden @ params["embed"]["w"].astype(cdt).T
    else:
        logits = linear(params["lm_head"], hidden)

    if cache is not None and cfg.encoder_layers:
        new_cache["memory"] = memory if memory is not None else cache.get("memory")

    aux = {}
    if cfg.mtp and cache is None:
        # next-next-token head: combine hidden_t with embed(token_{t+1})
        nxt = jnp.roll(tokens, -1, axis=1)
        e_next = jnp.take(params["embed"]["w"], nxt, axis=0).astype(cdt)
        norm_fn = rmsnorm if cfg.norm == "rmsnorm" else layernorm
        h_mtp = linear(
            params["mtp_proj"], jnp.concatenate([hidden, e_next], -1)
        )
        h_mtp, _ = _apply_layer(
            params["mtp_layer"], statics["mtp_layer"], cfg, h_mtp, positions,
            None, None, None, None,
        )
        h_mtp = norm_fn(params["mtp_norm"], h_mtp)
        if cfg.tie_embeddings:
            aux["mtp_logits"] = h_mtp @ params["embed"]["w"].astype(cdt).T
        else:
            aux["mtp_logits"] = linear(params["lm_head"], h_mtp)

    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def model_flops_per_token(cfg: ModelConfig, active_only: bool = True) -> float:
    """6*N(active)*FLOPs-per-token (MODEL_FLOPS for the roofline table)."""
    d = cfg.d_model
    n = 0
    for mixer, ffn in cfg.layer_types:
        if mixer in ("attn", "swa"):
            n += d * cfg.n_heads * cfg.d_head * 2  # q + o
            n += d * cfg.n_kv_heads * cfg.d_head * 2  # k + v
        elif mixer == "xattn":
            n += (d * cfg.n_heads * cfg.d_head * 2
                  + d * cfg.n_kv_heads * cfg.d_head * 2) * 2
        elif mixer == "mla":
            m = cfg.mla
            n += d * m.q_lora + m.q_lora * m.n_heads * (m.d_nope + m.d_rope)
            n += d * (m.kv_lora + m.d_rope)
            n += m.kv_lora * m.n_heads * (m.d_nope + m.d_v)
            n += m.n_heads * m.d_v * d
        elif mixer == "ssm":
            sc = cfg.ssm
            n += d * (2 * sc.d_inner + 2 * sc.n_groups * sc.d_state
                      + sc.n_heads)
            n += sc.d_inner * d
        if ffn == "mlp":
            mult = 3 if cfg.act == "swiglu" else 2
            n += mult * d * cfg.d_ff
        elif ffn == "moe":
            mo = cfg.moe
            active = mo.top_k if active_only else mo.n_experts
            mult = 3 if mo.act == "swiglu" else 2
            n += mult * d * mo.d_ff_expert * active
            if mo.n_shared:
                f_sh = mo.d_ff_shared or mo.n_shared * mo.d_ff_expert
                n += mult * d * f_sh
    n += cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return 6.0 * n
