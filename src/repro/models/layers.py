"""Shared model building blocks: norms, embeddings, RoPE, MLPs, PatternLinear.

Every ``*_init`` returns ``(params, specs)`` — two parallel pytrees, the
second holding logical-axis tuples resolved by ``repro.parallel.sharding``.
All ``*_apply`` are pure functions.  Compute dtype is the caller's; params
are created in ``param_dtype``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse import pattern_spmm_xla

__all__ = [
    "PatternSparseConfig",
    "rmsnorm_init", "rmsnorm",
    "layernorm_init", "layernorm",
    "embed_init",
    "linear_init", "linear",
    "sparse_linear_init", "sparse_linear",
    "mlp_init", "mlp_apply",
    "rope_frequencies", "apply_rope",
]


# ---------------------------------------------------------------------------
# pattern-sparse linear (TPU adaptation of the paper, DESIGN §3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PatternSparseConfig:
    """Config for block-pattern sparse linears (the paper's technique).

    density:      fraction of 128-row blocks kept per output column.
    num_patterns: dictionary size (pattern pruning).
    kmax_slack:   static head-room over ceil(density * n_blocks) for tile
                  unions after reordering (mixed tiles).
    """

    density: float = 0.25
    num_patterns: int = 8
    block: int = 128
    tile: int = 128
    kmax_slack: float = 1.5

    def k_max(self, k_in: int) -> int:
        nb = k_in // self.block
        return max(1, min(nb, int(np.ceil(self.density * nb * self.kmax_slack))))

    def applicable(self, k_in: int, n_out: int, model_shards: int) -> bool:
        # the tile table pads itself to a multiple of model_shards, so only
        # block/tile alignment of the true dims is required
        return k_in % self.block == 0 and n_out % self.tile == 0


def _fake_block_ids(
    n_tiles: int, k_max: int, n_blocks: int, seed: int
) -> np.ndarray:
    """Statistically-plausible block index table for init/dry-run.

    Sorted unique ids per tile (what a real layout produces); padding slots
    repeat the last id (their weight bricks are zero).
    """
    rng = np.random.default_rng(seed)
    ids = np.zeros((n_tiles, k_max), np.int32)
    for t in range(n_tiles):
        pick = np.sort(rng.choice(n_blocks, size=min(k_max, n_blocks), replace=False))
        ids[t, : pick.size] = pick
        ids[t, pick.size :] = pick[-1] if pick.size else 0
    return ids


def _fake_pattern_groups(
    n_tiles: int, k_max: int, n_blocks: int, num_patterns: int, seed: int,
    model_shards: int = 1,
) -> list[dict]:
    """Dictionary-level layout: tiles grouped by shared pattern.

    This is the paper's kernel-reordering invariant at tile granularity —
    after reordering, tiles with the same pattern are contiguous, so the
    XLA path can run ONE gather + ONE dense matmul per dictionary pattern
    (pattern blocks), instead of per-brick gathers.  Group boundaries are
    rounded to shard-chunk multiples so slices of the tiles-sharded weight
    stay local.  Returns [{'tiles': (start, stop), 'blocks': ids}].
    """
    rng = np.random.default_rng(seed)
    chunk = max(1, n_tiles // max(model_shards, 1))
    n_groups = min(num_patterns, max(1, n_tiles // chunk))
    bounds = np.linspace(0, n_tiles, n_groups + 1)
    bounds = np.round(bounds / chunk).astype(int) * chunk
    bounds[0], bounds[-1] = 0, n_tiles
    groups = []
    for g in range(n_groups):
        if bounds[g + 1] <= bounds[g]:
            continue
        pick = np.sort(rng.choice(n_blocks, size=min(k_max, n_blocks),
                                  replace=False))
        groups.append({
            "tiles": (int(bounds[g]), int(bounds[g + 1])),
            "blocks": pick.astype(np.int32),
        })
    return groups


def sparse_linear_init(
    key: jax.Array,
    k_in: int,
    n_out: int,
    cfg: PatternSparseConfig,
    out_axis: str = "tiles",
    param_dtype=jnp.float32,
    seed: int = 0,
    model_shards: int = 16,
):
    """Block-pattern compressed linear.  The layout (block_ids, inv_order)
    is a static constant (the paper's weight-index buffer); w_comp is the
    trainable compressed weight.

    The tile table is padded to a multiple of ``model_shards`` so the tiles
    dim shards evenly on any d_ff (qwen's 27648 -> 224 tiles); padded tiles
    hold zero bricks and their output columns are sliced off.
    """
    nb = k_in // cfg.block
    n_tiles = n_out // cfg.tile
    n_tiles_pad = ((n_tiles + model_shards - 1) // model_shards) * model_shards
    k_max = cfg.k_max(k_in)
    scale = 1.0 / np.sqrt(k_in * cfg.density)
    w = jax.random.normal(
        key, (n_tiles_pad, k_max, cfg.block, cfg.tile), param_dtype
    ) * scale
    if n_tiles_pad != n_tiles:
        w = w.at[n_tiles:].set(0.0)
    params = {"w_comp": w}
    specs = {"w_comp": ("tiles", None, None, None)}
    static = {
        "block_ids": _fake_block_ids(n_tiles_pad, k_max, nb, seed),
        "groups": _fake_pattern_groups(
            n_tiles_pad, k_max, nb, cfg.num_patterns, seed,
            model_shards=model_shards,
        ),
        "inv_order": np.arange(n_out, dtype=np.int32),
        "block": cfg.block,
        "tile": cfg.tile,
        "n_out": n_out,
    }
    return params, specs, static


def sparse_linear(params, static, x: jax.Array) -> jax.Array:
    """y = x @ W_compressed (XLA path; the Pallas kernel is dispatched by
    kernels/ops.py on real TPU backends).

    When the layout carries dictionary groups (tiles sharing a pattern are
    contiguous — the paper's kernel reordering), compute runs as one gather
    + one dense matmul per *pattern* (pattern blocks), which is both the
    paper's compute structure and the XLA-efficient form: x is gathered P
    times total instead of per brick slot.  Falls back to the generic
    per-slot scan for arbitrary block_ids tables.
    """
    groups = static.get("groups")
    w_comp = params["w_comp"].astype(x.dtype)
    block, tile = static["block"], static.get("tile", w_comp.shape[-1])
    if groups:
        lead = x.shape[:-1]
        xm = x.reshape(-1, x.shape[-1])
        m = xm.shape[0]
        xb = xm.reshape(m, -1, block)
        outs = []
        for g in groups:
            t0, t1 = g["tiles"]
            blocks = g["blocks"]  # [s_p] static
            s_p = len(blocks)
            # pattern block: gather once, one dense matmul (paper Fig 4)
            xg = jnp.take(xb, jnp.asarray(blocks), axis=1)  # [M, s_p, blk]
            xg = xg.reshape(m, s_p * block)
            # bricks of this group in tile order -> [s_p*block, cols]
            wg = w_comp[t0:t1, :s_p]  # [T_g, s_p, block, tile]
            wg = wg.transpose(1, 2, 0, 3).reshape(
                s_p * block, (t1 - t0) * tile
            )
            outs.append(
                jnp.dot(xg, wg, preferred_element_type=jnp.float32)
            )
        y = jnp.concatenate(outs, axis=-1).astype(x.dtype)
        y = y.reshape(*lead, y.shape[-1])
    else:
        y = pattern_spmm_xla(
            x,
            w_comp,
            jnp.asarray(static["block_ids"]),
            block,
        )
    n_out = static["n_out"]
    if y.shape[-1] != n_out:  # drop tile-padding columns
        y = y[..., :n_out]
    inv = static["inv_order"]
    if not np.array_equal(inv, np.arange(n_out)):
        y = jnp.take(y, jnp.asarray(inv), axis=-1)
    return y


# ---------------------------------------------------------------------------
# dense primitives
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, param_dtype=jnp.float32):
    return {"scale": jnp.ones((d,), param_dtype)}, {"scale": ("embed",)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, param_dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), param_dtype), "bias": jnp.zeros((d,), param_dtype)}
    return p, {"scale": ("embed",), "bias": ("embed",)}


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embed_init(key, vocab: int, d: int, param_dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d), param_dtype) * (d ** -0.5)
    return {"w": w}, {"w": ("vocab", "embed")}


def linear_init(
    key,
    d_in: int,
    d_out: int,
    in_axis: str | None = "embed",
    out_axis: str | None = "ff",
    bias: bool = False,
    param_dtype=jnp.float32,
    scale: float | None = None,
):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), param_dtype) * scale}
    s = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((d_out,), param_dtype)
        s["b"] = (out_axis,)
    return p, s


def linear(params, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU), optionally pattern-sparse
# ---------------------------------------------------------------------------


def mlp_init(
    key,
    d_model: int,
    d_ff: int,
    act: str = "swiglu",
    sparse: PatternSparseConfig | None = None,
    model_shards: int = 16,
    param_dtype=jnp.float32,
):
    """Returns (params, specs, static).  static carries sparse layouts."""
    k1, k2, k3 = jax.random.split(key, 3)
    params, specs, static = {}, {}, {"act": act, "sparse": None}
    use_sparse = sparse is not None and sparse.applicable(
        d_model, d_ff, model_shards
    ) and sparse.applicable(d_ff, d_model, model_shards)
    if use_sparse:
        static["sparse"] = sparse
        if act == "swiglu":
            params["gate"], specs["gate"], static["gate"] = sparse_linear_init(
                k1, d_model, d_ff, sparse, param_dtype=param_dtype, seed=1,
                model_shards=model_shards,
            )
        params["up"], specs["up"], static["up"] = sparse_linear_init(
            k2, d_model, d_ff, sparse, param_dtype=param_dtype, seed=2,
            model_shards=model_shards,
        )
        params["down"], specs["down"], static["down"] = sparse_linear_init(
            k3, d_ff, d_model, sparse, param_dtype=param_dtype, seed=3,
            model_shards=model_shards,
        )
        # down output tiles stay in compressed order; its inv_order is
        # identity here because _fake layouts don't permute — real layouts
        # from build_block_pattern carry the true inverse permutation.
    else:
        if act == "swiglu":
            params["gate"], specs["gate"] = linear_init(
                k1, d_model, d_ff, "embed", "ff", param_dtype=param_dtype
            )
        params["up"], specs["up"] = linear_init(
            k2, d_model, d_ff, "embed", "ff", param_dtype=param_dtype
        )
        params["down"], specs["down"] = linear_init(
            k3, d_ff, d_model, "ff", "embed", param_dtype=param_dtype
        )
    return params, specs, static


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "silu":
        return jax.nn.silu(x)
    raise ValueError(name)


def mlp_apply(params, static, x: jax.Array) -> jax.Array:
    sparse = static.get("sparse")
    if sparse is not None:
        up = sparse_linear(params["up"], static["up"], x)
        if static["act"] == "swiglu":
            gate = sparse_linear(params["gate"], static["gate"], x)
            h = jax.nn.silu(gate) * up
        else:
            h = _act(static["act"], up)
        return sparse_linear(params["down"], static["down"], h)
    up = linear(params["up"], x)
    if static["act"] == "swiglu":
        h = jax.nn.silu(linear(params["gate"], x)) * up
    else:
        h = _act(static["act"], up)
    return linear(params["down"], h)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(
    x: jax.Array,  # [..., S, H, D] or [..., S, D]
    positions: jax.Array,  # [..., S]
    freqs: jax.Array,  # [D/2]
) -> jax.Array:
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    if x.ndim == angles.ndim + 1:  # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
