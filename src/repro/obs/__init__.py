"""Unified observability substrate: spans + metrics, dependency-free.

Two pillars, both pure stdlib (no jax, no numpy) so every layer of the
stack — compiler, executor, scheduler, serving front ends — can depend
on them without import cycles or accelerator-backend coupling:

  * ``trace`` — a thread-safe span tracer with an injectable monotonic
    clock and a bounded ring buffer, exporting Chrome trace-event JSON
    (complete/instant/async/counter events) loadable in Perfetto or
    ``chrome://tracing``.  One :class:`~repro.obs.trace.Tracer` threaded
    through ``compile_network`` -> ``make_forward`` ->
    ``InferenceService`` puts compile phases, per-layer execution, and
    request lifecycles on a single shared timeline.
  * ``metrics`` — counters, gauges, and fixed-bucket histograms with
    exact sample-backed percentiles, grouped in a process-global but
    resettable :class:`~repro.obs.metrics.MetricsRegistry`, with JSON
    snapshot and Prometheus text exposition.

Everything is opt-in: a ``tracer=None`` default everywhere resolves to
the shared no-op :data:`~repro.obs.trace.NULL_TRACER`, so un-traced hot
paths (in particular the jitted forward) are byte-identical to the
pre-observability code.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    Meter,
    MetricsRegistry,
    get_registry,
)
from repro.obs.trace import NULL_TRACER, Tracer, get_tracer, set_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Meter",
    "MetricsRegistry",
    "get_registry",
    "NULL_TRACER",
    "Tracer",
    "get_tracer",
    "set_tracer",
]
