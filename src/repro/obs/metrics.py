"""Counters, gauges, histograms + a resettable process-global registry.

Pure stdlib.  Three metric kinds:

  * :class:`Counter` — monotonically increasing float.
  * :class:`Gauge` — a settable instantaneous value.
  * :class:`Histogram` — fixed cumulative buckets (the Prometheus shape)
    *plus* a bounded ring of the recorded samples, so quantiles
    (:meth:`Histogram.percentile`) are **exact** over the retained window
    rather than bucket-interpolated.  While fewer than ``max_samples``
    observations have been made, percentiles are exact over *all* of
    them; past the cap they are exact over the most recent window.

:class:`MetricsRegistry` groups metrics by name (get-or-create, kind
conflicts raise) and renders either a JSON-ready :meth:`snapshot` or
Prometheus text exposition (:meth:`to_prometheus`).  The module-level
:func:`get_registry` registry is process-global but resettable —
``get_registry().reset()`` in a test fixture isolates tests without
process-wide import tricks.

Percentiles use the nearest-rank definition: ``percentile(p)`` of *n*
sorted samples is the ``ceil(p/100 * n)``-th smallest, so e.g. the p50
of 1..100 is exactly 50 and the p99 exactly 99.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Meter",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
    "LATENCY_BUCKETS_S",
    "get_registry",
]

# generic magnitude ladder (Prometheus' default, extended one decade up)
DEFAULT_BUCKETS = (
    0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
    50.0, 100.0,
)
# request latencies in seconds: sub-ms service steps up to multi-second
# queue waits under load
LATENCY_BUCKETS_S = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class Counter:
    """Monotonic counter.  ``inc`` by a non-negative amount only."""

    kind = "counter"

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self):
        return self._value

    def prom_lines(self, name: str) -> list[str]:
        return [f"# TYPE {name} counter", f"{name} {_fmt(self._value)}"]


class Gauge:
    """Instantaneous value; ``set`` wins, ``inc``/``dec`` adjust."""

    kind = "gauge"

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self):
        return self._value

    def prom_lines(self, name: str) -> list[str]:
        return [f"# TYPE {name} gauge", f"{name} {_fmt(self._value)}"]


class Histogram:
    """Fixed-bucket histogram with exact sample-backed percentiles.

    ``buckets`` are upper bounds (le) of the cumulative Prometheus
    buckets; an implicit ``+Inf`` bucket always exists.  ``max_samples``
    bounds the raw-sample ring the percentiles are computed from.
    """

    kind = "histogram"

    def __init__(self, buckets=DEFAULT_BUCKETS, max_samples: int = 65_536):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"buckets must be sorted and non-empty: {buckets}")
        self.buckets = tuple(float(b) for b in buckets)
        self._bucket_counts = [0] * (len(self.buckets) + 1)  # + the Inf bucket
        self._samples: deque[float] = deque(maxlen=max_samples)
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self._count += 1
            self._sum += value
            self._samples.append(value)
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    self._bucket_counts[i] += 1
                    break
            else:
                self._bucket_counts[-1] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the retained samples (0 if none)."""
        if not 0 < p <= 100:
            raise ValueError(f"percentile must be in (0, 100], got {p}")
        with self._lock:
            samples = sorted(self._samples)
        if not samples:
            return 0.0
        rank = math.ceil(p / 100.0 * len(samples))
        return samples[rank - 1]

    def snapshot(self) -> dict:
        with self._lock:
            cum, out = 0, []
            for ub, c in zip(self.buckets, self._bucket_counts):
                cum += c
                out.append([ub, cum])
        return {
            "count": self._count,
            "sum": self._sum,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "buckets": out,
        }

    def prom_lines(self, name: str) -> list[str]:
        lines = [f"# TYPE {name} histogram"]
        with self._lock:
            cum = 0
            for ub, c in zip(self.buckets, self._bucket_counts):
                cum += c
                lines.append(f'{name}_bucket{{le="{_fmt(ub)}"}} {cum}')
            lines.append(f'{name}_bucket{{le="+Inf"}} {self._count}')
            lines.append(f"{name}_sum {_fmt(self._sum)}")
            lines.append(f"{name}_count {self._count}")
        return lines


class Meter:
    """Windowed event-rate meter: events/s over a sliding time window.

    Serving front ends use it for *sustained* throughput (req/s over the
    last ``window_s``), which a monotonic :class:`Counter` cannot give
    without a scraper differentiating it.  ``mark(n)`` records *n* events
    now; :attr:`rate` is events/s over the retained window (0 until the
    first mark).  ``clock`` is injectable for deterministic tests.
    """

    kind = "meter"

    def __init__(self, window_s: float = 10.0, clock=time.monotonic):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.window_s = float(window_s)
        self._clock = clock
        self._events: deque[tuple[float, float]] = deque()
        self._total = 0.0
        self._lock = threading.Lock()

    def _prune(self, now: float) -> None:
        horizon = now - self.window_s
        while self._events and self._events[0][0] < horizon:
            self._events.popleft()

    def mark(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"meter mark must be >= 0, got {n}")
        now = self._clock()
        with self._lock:
            self._total += n
            self._events.append((now, float(n)))
            self._prune(now)

    @property
    def total(self) -> float:
        return self._total

    @property
    def rate(self) -> float:
        """Events/s over the sliding window."""
        now = self._clock()
        with self._lock:
            self._prune(now)
            if not self._events:
                return 0.0
            n = sum(c for _, c in self._events)
            # measure over the elapsed fraction of the window so a burst
            # younger than window_s is not diluted by empty history
            span = max(now - self._events[0][0], 1e-9)
        return n / min(max(span, 1e-3), self.window_s)

    def snapshot(self) -> dict:
        return {"total": self._total, "rate_per_s": self.rate}

    def prom_lines(self, name: str) -> list[str]:
        return [
            f"# TYPE {name}_total counter",
            f"{name}_total {_fmt(self._total)}",
            f"# TYPE {name}_rate_per_s gauge",
            f"{name}_rate_per_s {_fmt(self.rate)}",
        ]


def _fmt(v: float) -> str:
    """Prometheus-friendly number: integral values without the '.0'."""
    return str(int(v)) if float(v).is_integer() and abs(v) < 1e15 else repr(v)


_PROM_OK = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")


def _prom_name(name: str) -> str:
    out = "".join(ch if ch in _PROM_OK else "_" for ch in name)
    return out if out and not out[0].isdigit() else "_" + out


class MetricsRegistry:
    """Named metrics, get-or-create, with JSON and Prometheus renderings."""

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, kind: str, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, "counter", Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, "gauge", Gauge)

    def histogram(self, name: str, buckets=None, max_samples: int = 65_536):
        return self._get_or_create(
            name,
            "histogram",
            lambda: Histogram(buckets or DEFAULT_BUCKETS, max_samples),
        )

    def meter(self, name: str, window_s: float = 10.0) -> Meter:
        return self._get_or_create(
            name, "meter", lambda: Meter(window_s=window_s)
        )

    def register(self, name: str, metric) -> None:
        """Attach an externally owned metric (e.g. a scheduler's latency
        histogram) so it appears in this registry's renderings."""
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None and existing is not metric:
                raise ValueError(f"metric {name!r} already registered")
            self._metrics[name] = metric

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def reset(self) -> None:
        """Drop every metric — the test-isolation escape hatch."""
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> dict:
        with self._lock:
            items = sorted(self._metrics.items())
        return {
            name: {"kind": m.kind, "value": m.snapshot()} for name, m in items
        }

    def to_prometheus(self) -> str:
        with self._lock:
            items = sorted(self._metrics.items())
        lines: list[str] = []
        for name, m in items:
            lines.extend(m.prom_lines(_prom_name(name)))
        return "\n".join(lines) + ("\n" if lines else "")


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global registry (reset it between tests)."""
    return _REGISTRY
