"""Span tracer with Chrome trace-event JSON export (Perfetto-loadable).

Design constraints, in order:

  * **zero overhead when off** — every instrumented call site takes a
    ``tracer=None`` default that resolves to :data:`NULL_TRACER`, whose
    methods are no-ops; nothing is recorded, no clock is read, and the
    jitted forward keeps its exact pre-observability code path.
  * **deterministic under test** — the clock is injectable
    (``clock=lambda: fake.t``), so span timestamps and durations are
    exact values, not wall-clock noise.
  * **bounded** — events live in a ring buffer (``max_events``); a
    long-running service can keep a tracer attached without growing
    memory, at the cost of dropping the oldest events (the drop count
    is reported in the export metadata).
  * **thread-safe** — one lock around the ring; thread idents map to
    small stable ``tid`` values with thread-name metadata in the export.

Export follows the Chrome trace-event format "JSON object" flavour:
``{"traceEvents": [...]}`` where each event carries ``ph`` (phase),
``ts``/``dur`` in *microseconds*, ``pid``/``tid``, ``name``, ``cat``,
``args``.  Phases used here:

  ``X``    complete span (ts + dur)          — :meth:`Tracer.span`
  ``i``    instant event                     — :meth:`Tracer.instant`
  ``C``    counter track                     — :meth:`Tracer.counter`
  ``b/e``  async span begin/end (by ``id``)  — request lifecycles
  ``n``    async instant (a step inside one) — e.g. slot admission
  ``M``    metadata (process/thread names)   — added at export time
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Callable, Iterator
from contextlib import contextmanager

__all__ = ["SpanRecord", "Tracer", "NULL_TRACER", "get_tracer", "set_tracer"]


class SpanRecord:
    """One finished (or in-flight) complete span.

    ``ts``/``dur`` are *seconds* on the tracer's clock; the Chrome export
    converts to microseconds.  ``dur`` is ``None`` until the span exits.
    ``args`` may be updated while the span is open (the updated values
    land in the export).
    """

    __slots__ = ("name", "cat", "ts", "dur", "tid", "args")

    def __init__(self, name: str, cat: str, ts: float, tid: int, args: dict):
        self.name = name
        self.cat = cat
        self.ts = ts
        self.dur: float | None = None
        self.tid = tid
        self.args = args


class Tracer:
    """Thread-safe span/instant/counter recorder with Chrome JSON export.

    Args:
      clock: monotonic time source returning *seconds* (injectable for
        deterministic tests).  Timestamps are relative to the tracer's
        creation instant, so exported traces start near ``ts=0``.
      max_events: ring-buffer bound; the oldest events are dropped once
        exceeded (``dropped_events`` in the export metadata counts them).
      enabled: a disabled tracer records nothing and its ``span()`` is a
        no-op context manager — the mechanism behind :data:`NULL_TRACER`.
      pid: the ``pid`` stamped on every event (one logical process).
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.perf_counter,
        max_events: int = 200_000,
        enabled: bool = True,
        pid: int = 0,
        process_name: str = "repro-engine",
    ):
        self.clock = clock
        self.enabled = enabled
        self.pid = pid
        self.process_name = process_name
        self._t0 = clock() if enabled else 0.0
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=max_events)
        self._seen = 0  # total events ever recorded (for drop accounting)
        self._tids: dict[int, int] = {}  # thread ident -> small stable tid
        self._tid_names: dict[int, str] = {}

    # ------------------------------------------------------------ recording

    def _now(self) -> float:
        return self.clock() - self._t0

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids)
                self._tid_names[tid] = threading.current_thread().name
        return tid

    def _record(self, ev: Any) -> None:
        with self._lock:
            self._events.append(ev)
            self._seen += 1

    @contextmanager
    def span(self, name: str, cat: str = "", **args) -> Iterator[SpanRecord]:
        """Record a complete ('X') span around the ``with`` body.

        Yields the :class:`SpanRecord`; after exit its ``dur`` holds the
        measured duration in seconds (on the injectable clock), which
        instrumentation can read back — e.g. the executor accumulates
        per-layer wall time from it.  Exceptions propagate; the span is
        still closed (and flagged ``error=True`` in its args).
        """
        if not self.enabled:
            yield _NULL_SPAN
            return
        rec = SpanRecord(name, cat, self._now(), self._tid(), dict(args))
        try:
            yield rec
        except BaseException:
            rec.args["error"] = True
            raise
        finally:
            rec.dur = max(self._now() - rec.ts, 0.0)
            self._record(rec)

    def instant(self, name: str, cat: str = "", **args) -> None:
        """Record an instant ('i', thread-scoped) event."""
        if not self.enabled:
            return
        self._record(
            {
                "ph": "i",
                "name": name,
                "cat": cat,
                "ts": self._now(),
                "tid": self._tid(),
                "s": "t",
                "args": args,
            }
        )

    def counter(self, name: str, **series: float) -> None:
        """Record a counter ('C') sample: one track, one or more series."""
        if not self.enabled or not series:
            return
        self._record(
            {
                "ph": "C",
                "name": name,
                "cat": "",
                "ts": self._now(),
                "tid": self._tid(),
                "args": {k: float(v) for k, v in series.items()},
            }
        )

    def async_begin(self, name: str, id_: int, cat: str = "", **args) -> None:
        """Open an async ('b') span — e.g. a request lifecycle — keyed by
        ``id_``; close it with :meth:`async_end` using the same id."""
        self._async("b", name, id_, cat, args)

    def async_instant(self, name: str, id_: int, cat: str = "", **args) -> None:
        """An 'n' instant *inside* an open async span (e.g. admission)."""
        self._async("n", name, id_, cat, args)

    def async_end(self, name: str, id_: int, cat: str = "", **args) -> None:
        self._async("e", name, id_, cat, args)

    def _async(self, ph: str, name: str, id_: int, cat: str, args: dict):
        if not self.enabled:
            return
        self._record(
            {
                "ph": ph,
                "name": name,
                "cat": cat,
                "id": int(id_),
                "ts": self._now(),
                "tid": self._tid(),
                "args": args,
            }
        )

    # -------------------------------------------------------------- reading

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self._seen = 0

    @property
    def dropped_events(self) -> int:
        with self._lock:
            return self._seen - len(self._events)

    def events(self) -> list[dict]:
        """The buffered events in Chrome trace-event form (ts/dur in µs)."""
        with self._lock:
            raw = list(self._events)
        out = []
        for ev in raw:
            if isinstance(ev, SpanRecord):
                out.append(
                    {
                        "ph": "X",
                        "name": ev.name,
                        "cat": ev.cat,
                        "ts": ev.ts * 1e6,
                        "dur": (ev.dur or 0.0) * 1e6,
                        "pid": self.pid,
                        "tid": ev.tid,
                        "args": ev.args,
                    }
                )
            else:
                out.append({**ev, "ts": ev["ts"] * 1e6, "pid": self.pid})
        return out

    def spans(self, cat: str | None = None) -> list[SpanRecord]:
        """Finished complete spans, optionally filtered by category."""
        with self._lock:
            raw = [e for e in self._events if isinstance(e, SpanRecord)]
        if cat is not None:
            raw = [e for e in raw if e.cat == cat]
        return raw

    def slowest(
        self, n: int = 3, cat: str | None = None, prefix: str | None = None
    ) -> list[tuple[str, float]]:
        """Top-``n`` span names by *total* duration (seconds), descending.

        Durations aggregate over same-named spans, so a layer executed
        many times ranks by its cumulative time.  ``prefix`` filters by
        span-name prefix (e.g. ``"layer:"``).
        """
        totals: dict[str, float] = {}
        for s in self.spans(cat):
            if prefix is not None and not s.name.startswith(prefix):
                continue
            totals[s.name] = totals.get(s.name, 0.0) + (s.dur or 0.0)
        ranked = sorted(totals.items(), key=lambda kv: -kv[1])
        return ranked[:n]

    # ------------------------------------------------------------- exporting

    def to_chrome(self) -> dict:
        """The full trace as a Chrome trace-event JSON object."""
        meta = [
            {
                "ph": "M",
                "name": "process_name",
                "pid": self.pid,
                "tid": 0,
                "ts": 0,
                "args": {"name": self.process_name},
            }
        ]
        for tid, tname in sorted(self._tid_names.items()):
            meta.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": self.pid,
                    "tid": tid,
                    "ts": 0,
                    "args": {"name": tname},
                }
            )
        return {
            "traceEvents": meta + self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped_events},
        }

    def write(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# a shared open span handed out by disabled tracers, so `with t.span(...)
# as sp` call sites never branch; its dur stays 0.0 and args go nowhere
_NULL_SPAN = SpanRecord("", "", 0.0, 0, {})
_NULL_SPAN.dur = 0.0

NULL_TRACER = Tracer(enabled=False, max_events=1)
"""Shared no-op tracer: the resolution of every ``tracer=None`` default."""

_default: Tracer = NULL_TRACER


def get_tracer() -> Tracer:
    """The process-default tracer (:data:`NULL_TRACER` until one is set)."""
    return _default


def set_tracer(tracer: Tracer | None) -> Tracer:
    """Install (or, with ``None``, clear) the process-default tracer."""
    global _default
    _default = tracer if tracer is not None else NULL_TRACER
    return _default
