"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because smoke tests and
benches must see 1 device while the dry-run forces 512.

Single pod: (data=16, model=16) — 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the pod axis is pure
data parallelism (gradient all-reduce crosses DCN), which is also where
gradient compression applies.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices exist (tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
