"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because smoke tests and
benches must see 1 device while the dry-run forces 512.

Single pod: (data=16, model=16) — 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the pod axis is pure
data parallelism (gradient all-reduce crosses DCN), which is also where
gradient compression applies.

``make_mesh`` is the version-portable constructor every caller (and test)
should use: newer jax grew ``jax.sharding.AxisType`` and a required-ish
``axis_types`` kwarg on ``jax.make_mesh``, older jax has neither.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax <= 0.4.x: meshes are implicitly 'auto'
    _AxisType = None

__all__ = ["make_mesh", "make_production_mesh", "make_local_mesh"]


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API has them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _AxisType is not None:
        kwargs["axis_types"] = (_AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices exist (tests)."""
    return make_mesh((data, model), ("data", "model"))
