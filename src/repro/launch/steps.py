"""Step-function builders shared by dryrun.py / train.py / serve.py.

Everything here is AOT-friendly: given an (arch, shape, mesh) it produces
  * the jitted step function with in/out shardings attached,
  * ShapeDtypeStruct stand-ins (with shardings) for every input,
so ``.lower(...).compile()`` runs without allocating a single parameter —
the multi-pod dry-run contract.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config, input_specs
from repro.models.transformer import (
    ModelConfig,
    apply_model,
    init_cache,
    init_params,
)
from repro.optim import adamw, linear_warmup_cosine
from repro.parallel.activations import activation_sharding_ctx
from repro.parallel.sharding import DEFAULT_RULES, logical_to_pspec
from repro.runtime.serve import ServeConfig, make_decode_step, make_prefill_step
from repro.runtime.train import TrainConfig, init_train_state, make_train_step

__all__ = ["BuiltStep", "build_step", "param_shardings", "cache_pspec"]

_BF16_OPT_THRESHOLD = 50e9  # params above this -> bf16 optimizer states


@dataclasses.dataclass
class BuiltStep:
    """A lowered-ready step: fn is jit-wrapped with shardings; args are
    ShapeDtypeStructs (with shardings) matching fn's signature."""

    fn: Any
    args: tuple
    cfg: ModelConfig
    kind: str
    meta: dict


def _sds(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree,
        shardings,
    )


def param_shardings(specs, shapes, mesh: Mesh):
    def one(spec, sds):
        return NamedSharding(
            mesh, logical_to_pspec(spec, sds.shape, mesh, DEFAULT_RULES)
        )

    return jax.tree.map(
        one,
        specs,
        shapes,
        is_leaf=lambda x: x is None
        or (
            isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x)
        ),
    )


def cache_pspec(path: tuple, shape: tuple, mesh: Mesh) -> P:
    """Sharding for a KV-cache leaf, by name + rank heuristics.

    batch -> 'data', sequence -> 'model' (sequence-sharded caches are what
    make 32k/500k decode fit HBM: DESIGN §4).  Non-divisible dims fall back
    to replication via logical_to_pspec.
    """
    name = [getattr(p, "key", "") for p in path]
    name = [n for n in name if isinstance(n, str)]
    leaf = name[-1] if name else ""
    rank = len(shape)
    stacked = rank >= 1 and "body" in name  # leading n_periods dim

    def spec_for(core: tuple) -> tuple:
        return ((None,) + core) if stacked else core

    if leaf in ("k", "v"):
        core = ("data_only", "seq_shard", None, None)
    elif leaf in ("c_kv", "k_rope"):
        core = ("data_only", "seq_shard", None)
    elif leaf == "conv":
        core = ("data_only", None, "ff")
    elif leaf == "state":
        core = ("data_only", "heads", None, None)
    elif leaf == "memory":
        return logical_to_pspec(("data_only", None, None), shape, mesh)
    else:
        core = ("data_only",) + (None,) * (rank - (2 if stacked else 1))
    spec = spec_for(core)
    if len(spec) != rank:  # unexpected rank: replicate
        return P()
    return logical_to_pspec(spec, shape, mesh)


def cache_shardings(cache_shapes, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = [
        NamedSharding(mesh, cache_pspec(path, leaf.shape, mesh))
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def _batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes)


def _model_kwargs_fn(cfg: ModelConfig):
    def fn(batch):
        kw = {}
        if "frames" in batch:
            kw["frames"] = batch["frames"]
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        return kw

    return fn


def build_step(
    arch: str,
    shape: str | ShapeSpec,
    mesh: Mesh,
    cfg: ModelConfig | None = None,
    tcfg: TrainConfig | None = None,
    sparse: bool = False,
) -> BuiltStep:
    spec = SHAPES[shape] if isinstance(shape, str) else shape
    if cfg is None:
        cfg = get_config(arch, spec) if not sparse else get_config(
            arch, spec
        )
        if sparse:
            import importlib

            cfg = importlib.import_module(f"repro.configs.{arch}").config(
                spec, sparse=True
            )

    key = jax.random.PRNGKey(0)
    # Trace init_params for shapes only; capture specs/statics via closure —
    # they are pure python/numpy (logical axes, layout tables, configs) and
    # stay concrete during tracing.  No parameter is ever allocated.
    aux: dict = {}

    def _init_shapes(k):
        p, s, st = init_params(cfg, k)
        aux["specs"], aux["statics"] = s, st
        return p

    p_shapes = jax.eval_shape(_init_shapes, key)
    specs, statics = aux["specs"], aux["statics"]
    p_shard = param_shardings(specs, p_shapes, mesh)
    batch_spec = _batch_pspec(mesh)
    b_shard = NamedSharding(mesh, batch_spec)

    ins = input_specs(arch, spec, cfg)
    meta = {"arch": arch, "shape": spec.name, "cfg_name": cfg.name}

    if spec.kind == "train":
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p_shapes))
        opt_dtype = jnp.bfloat16 if n_params > _BF16_OPT_THRESHOLD else jnp.float32
        opt = adamw(mu_dtype=opt_dtype)
        tcfg = tcfg or TrainConfig()
        lr_fn = linear_warmup_cosine(3e-4, 100, 10000)
        step = make_train_step(
            cfg, statics, opt, lr_fn, tcfg, _model_kwargs_fn(cfg)
        )

        state_shapes = jax.eval_shape(
            lambda p: init_train_state(p, opt, tcfg), p_shapes
        )
        state_shard = {
            "params": p_shard,
            "opt_state": {
                "mu": _zero1(p_shard, p_shapes, mesh),
                "nu": _zero1(p_shard, p_shapes, mesh),
                "count": NamedSharding(mesh, P()),
            },
            "step": NamedSharding(mesh, P()),
        }
        batch_shapes = {"tokens": ins["tokens"], **{
            k: v for k, v in ins.items() if k not in ("tokens", "pos")
        }}
        batch_shard = {k: b_shard for k in batch_shapes}

        def wrapped(state, batch):
            with activation_sharding_ctx(mesh):
                return step(state, batch)

        fn = jax.jit(
            wrapped,
            in_shardings=(state_shard, batch_shard),
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
        )
        args = (_sds(state_shapes, state_shard), _sds(batch_shapes, batch_shard))
        meta["n_params"] = n_params
        return BuiltStep(fn, args, cfg, "train", meta)

    # serving paths
    scfg = ServeConfig(max_seq=spec.seq_len, cache_dtype="bfloat16")
    cache_shapes = jax.eval_shape(
        lambda: init_cache(statics, spec.global_batch, spec.seq_len,
                           jnp.bfloat16)
    )
    c_shard = cache_shardings(cache_shapes, mesh)

    if spec.kind == "prefill":
        prefill = make_prefill_step(cfg, statics, scfg)

        def wrapped(params, cache, tokens, extras):
            with activation_sharding_ctx(mesh):
                return prefill(params, cache, tokens, extras)

        tok_sds = ins["tokens"]
        extras = {k: v for k, v in ins.items() if k not in ("tokens", "pos")}
        ex_shard = {k: b_shard for k in extras}
        fn = jax.jit(
            wrapped,
            in_shardings=(p_shard, c_shard, b_shard, ex_shard),
            out_shardings=(NamedSharding(mesh, _batch_pspec(mesh)), c_shard),
            donate_argnums=(1,),
        )
        args = (
            _sds(p_shapes, p_shard),
            _sds(cache_shapes, c_shard),
            jax.ShapeDtypeStruct(tok_sds.shape, tok_sds.dtype, sharding=b_shard),
            _sds(extras, ex_shard),
        )
        return BuiltStep(fn, args, cfg, "prefill", meta)

    # decode: one token against a full cache
    decode = make_decode_step(cfg, statics, scfg)

    def wrapped(params, cache, tokens, pos):
        with activation_sharding_ctx(mesh):
            return decode(params, cache, tokens, pos)

    repl = NamedSharding(mesh, P())
    tok_shard = b_shard if spec.global_batch % _dp_size(mesh) == 0 else repl
    fn = jax.jit(
        wrapped,
        in_shardings=(p_shard, c_shard, tok_shard, repl),
        out_shardings=(tok_shard, c_shard),
        donate_argnums=(1,),
    )
    args = (
        _sds(p_shapes, p_shard),
        _sds(cache_shapes, c_shard),
        jax.ShapeDtypeStruct(ins["tokens"].shape, jnp.int32, sharding=tok_shard),
        jax.ShapeDtypeStruct((), jnp.int32, sharding=repl),
    )
    return BuiltStep(fn, args, cfg, "decode", meta)


def _dp_size(mesh: Mesh) -> int:
    return int(
        np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names])
    )


def _zero1(p_shard, p_shapes, mesh: Mesh):
    """ZeRO-1: shard optimizer moments over 'data' on the first dim that is
    currently unsharded and divisible — on top of the param sharding."""
    dsize = mesh.shape.get("data", 1)

    def one(sh: NamedSharding, sds):
        spec = list(sh.spec) + [None] * (len(sds.shape) - len(sh.spec))
        for i, (ax, dim) in enumerate(zip(spec, sds.shape)):
            if ax is None and dim % dsize == 0 and dsize > 1:
                spec[i] = "data"
                return NamedSharding(mesh, P(*spec))
        return sh

    return jax.tree.map(
        one, p_shard, p_shapes,
        is_leaf=lambda x: isinstance(x, NamedSharding),
    )
