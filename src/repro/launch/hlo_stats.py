"""Loop-aware statistics from optimized HLO text.

``compiled.cost_analysis()`` counts every computation once: a lax.scan over
60 layers contributes its body a single time, under-counting FLOPs, bytes
and collective traffic by the trip count.  This parser rebuilds the numbers
correctly from ``compiled.as_text()`` (the per-device SPMD program):

  * computations are parsed into instruction lists with result shapes;
  * while-loop trip counts are recovered from the canonical lax.scan
    condition (``compare(iter, constant), direction=LT``);
  * a multiplier propagates through the call graph (while bodies multiply
    by trip count; fusions/calls/conditionals inherit);
  * FLOPs  = 2 * prod(result_dims) * contraction_size per dot (+ per-op
    multiplier);
  * collective bytes = result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (x multiplier);
  * HBM bytes proxy  = dot operand+result bytes + cache-update traffic
    (dynamic-update-slice / gather / scatter) + entry argument bytes
    (params read once per step).  Pure-elementwise traffic is fused on TPU
    and intentionally not double-counted.

Validated in tests against hand-computed flops of known programs.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["HLOStats", "parse_hlo_stats", "cost_analysis_dict"]


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict across jax versions.

    Older jax returns a per-device list of dicts (usually length 1; summed
    here so 'flops' stays the per-program total), newer jax returns the
    dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, dict):
        return cost
    out: dict = {}
    for entry in cost or []:
        for k, v in entry.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
            else:
                out.setdefault(k, v)
    return out

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLS = re.compile(
    r"(?:body|condition|calls|to_apply|branch_computations)=\{?%?([\w.\-]+)"
)
_CALLS_MULTI = re.compile(r"branch_computations=\{([^}]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_info(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) across possibly-tuple types."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    # HBM traffic of attention-score-shaped tensors (two trailing dims both
    # >= 1024): a flash-attention kernel keeps these in VMEM, so
    # ``bytes - score_bytes`` models the fused memory term.
    score_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    collective_bytes_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    while_trips: list = dataclasses.field(default_factory=list)
    unresolved_whiles: int = 0


def _score_like(type_str: str) -> bool:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return False
    dims = [int(d) for d in m.group(2).split(",") if d]
    return len(dims) >= 2 and dims[-1] >= 1024 and dims[-2] >= 1024


def _parse_computations(text: str):
    comps: dict[str, list[tuple[str, str]]] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR.match(line.strip())
        if m and line.strip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            comps[cur].append((mi.group(1), mi.group(2)))
    return comps, entry


def _split_operands(arglist: str) -> list[str]:
    """Split an HLO operand list on top-level commas.

    Operand tokens may carry inline types whose dims/layouts contain commas
    (``f32[64,128]{1,0} %arg``), so a plain ``split(',')`` is wrong.
    """
    out, depth, cur = [], 0, []
    for ch in arglist:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [t for t in out if t]


def _operand_type(token: str, shapes: dict[str, str]) -> str:
    """Type string of one operand token.

    Newer XLA prints the type inline (``f32[64,128]{1,0} %name``); older
    text has only ``%name`` and the type comes from the computation's
    result-type symbol table.
    """
    if _SHAPE_RE.search(token):
        return token
    return shapes.get(token.strip().lstrip("%"), "")


def _dot_flops(rhs: str, shapes: dict[str, str]) -> float:
    # result type is the prefix of rhs up to ' dot('
    mres = _SHAPE_RE.search(rhs)
    if not mres:
        return 0.0
    res_elems, _ = _shape_info(rhs.split(" dot(")[0])
    # contraction size from lhs operand shape + lhs_contracting_dims
    mops = re.search(r"dot\(([^)]*)\)", rhs)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    if not (mops and mc):
        return 2.0 * res_elems  # dot with unknown contraction: lower bound
    operands = _split_operands(mops.group(1))
    lhs_type = _operand_type(operands[0], shapes) if operands else ""
    dims_m = _SHAPE_RE.search(lhs_type)
    if not dims_m:
        return 2.0 * res_elems
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contract = 1
    for i in mc.group(1).split(","):
        if i and int(i) < len(lhs_dims):
            contract *= lhs_dims[int(i)]
    return 2.0 * res_elems * contract


def _while_trip(cond_name: str, comps, shapes_by_comp) -> int | None:
    """Recover the lax.scan trip count from the condition computation.

    Canonical lowering: the condition holds ``constant(N)`` and compares the
    iteration counter against it (possibly through a wrapped-compare
    fusion).  lax.scan counts 0..N-1 step 1, so the single positive scalar
    constant in the condition *is* the trip count.
    """
    instrs = comps.get(cond_name, [])
    consts: list[int] = []
    for name, rhs in instrs:
        mc = re.match(r"s(?:32|64)\[\]\s+constant\((-?\d+)\)", rhs)
        if mc:
            consts.append(int(mc.group(1)))
    pos = [c for c in consts if c > 0]
    if len(pos) >= 1:
        return max(pos)
    return None


def parse_hlo_stats(text: str) -> HLOStats:
    comps, entry = _parse_computations(text)
    # result-type symbol table per computation
    shapes_by_comp: dict[str, dict[str, str]] = {}
    for cname, instrs in comps.items():
        tbl = {}
        for name, rhs in instrs:
            tbl[name] = rhs.split(" ")[0] if rhs else ""
            # better: type is everything up to the opcode word; keep the
            # full rhs for shape regex fallback
            tbl[name] = rhs
        shapes_by_comp[cname] = tbl

    stats = HLOStats()
    if entry is None:
        return stats

    # propagate multipliers through the call graph (iterative DFS)
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    stack = [entry]
    seen_edges = set()
    while stack:
        cname = stack.pop()
        m = mult[cname]
        for name, rhs in comps.get(cname, []):
            if " while(" in rhs:
                mbody = re.search(r"body=%?([\w.\-]+)", rhs)
                mcond = re.search(r"condition=%?([\w.\-]+)", rhs)
                # XLA annotates statically-known loops directly; prefer that
                # over reverse-engineering the condition's constant.
                mknown = re.search(
                    r"known_trip_count[\"':={\s]+n[\"':\s]*[:=]?\s*\"?(\d+)",
                    rhs,
                )
                trip = int(mknown.group(1)) if mknown else None
                if trip is None and mcond:
                    trip = _while_trip(mcond.group(1), comps, shapes_by_comp)
                if trip is None:
                    trip = 1
                    stats.unresolved_whiles += 1
                else:
                    stats.while_trips.append(trip)
                if mbody:
                    key = (cname, mbody.group(1))
                    if key not in seen_edges:
                        seen_edges.add(key)
                        mult[mbody.group(1)] += m * trip
                        stack.append(mbody.group(1))
                continue
            mbr = _CALLS_MULTI.search(rhs)
            called = []
            if mbr:
                called = [c.strip().lstrip("%") for c in
                          mbr.group(1).split(",")]
            else:
                for cm in _CALLS.finditer(rhs):
                    called.append(cm.group(1))
            for cal in called:
                if cal in comps:
                    key = (cname, name, cal)
                    if key not in seen_edges:
                        seen_edges.add(key)
                        mult[cal] += m
                        stack.append(cal)

    # accumulate statistics
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        tbl = shapes_by_comp[cname]
        for name, rhs in instrs:
            head = rhs.split("(")[0]
            if " dot(" in rhs:
                stats.flops += m * _dot_flops(rhs, tbl)
                res_type = rhs.split(" dot(")[0]
                _, rb = _shape_info(res_type)
                if _score_like(res_type):
                    stats.score_bytes += m * rb
                mops = re.search(r"dot\(([^)]*)\)", rhs)
                ob = 0
                if mops:
                    for op in _split_operands(mops.group(1)):
                        t_op = _operand_type(op, tbl)
                        _, b = _shape_info(t_op)
                        ob += b
                        if _score_like(t_op):
                            stats.score_bytes += m * b
                stats.bytes += m * (rb + ob)
                continue
            for coll in _COLLECTIVES:
                if re.search(rf"\b{coll}(-start)?\(", rhs):
                    _, b = _shape_info(rhs.split(f" {coll}")[0])
                    stats.collective_bytes += m * b
                    stats.collective_counts[coll] += int(m)
                    stats.collective_bytes_by_kind[coll] += m * b
                    break
            else:
                if head.endswith(("dynamic-update-slice", "gather",
                                  "scatter", "dynamic-slice")):
                    # cache/update traffic: result bytes
                    _, b = _shape_info(rhs.split(" " + head.split()[-1])[0])
                    stats.bytes += m * b

    # entry arguments (params/caches) are read once per step
    # (approximation: count parameter instruction types in ENTRY)
    for name, rhs in comps.get(entry, []):
        if " parameter(" in rhs:
            _, b = _shape_info(rhs.split(" parameter(")[0])
            stats.bytes += b
    return stats
