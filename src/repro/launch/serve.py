"""Serving launcher: batched generation over the slot-based ServeLoop.

  PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b --smoke \
      --requests 16 --new-tokens 24
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.transformer import init_params
from repro.runtime.serve import ServeConfig, ServeLoop
from repro.serve import Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, specs, statics = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(
        batch_slots=args.slots,
        max_seq=args.max_seq or min(cfg.max_seq, args.prompt_len
                                    + args.new_tokens + 8),
        eos_id=-1,  # synthetic prompts: never stop early
    )
    loop = ServeLoop(cfg, statics, params, scfg)

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab, size=args.prompt_len).astype(
                np.int32
            ),
            max_new_tokens=args.new_tokens,
        )
        for _ in range(args.requests)
    ]
    t0 = time.time()
    loop.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in reqs)
    print(f"{len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s)")
    m = loop.metrics
    print(f"scheduler: {m['steps']} steps, "
          f"occupancy {m['occupancy_mean']:.0%}, "
          f"mean latency {m['latency_mean_s']:.2f}s")
    for r in reqs[:3]:
        print("out:", r.output[:12])
    return reqs


if __name__ == "__main__":
    main()
