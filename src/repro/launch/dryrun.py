import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder devices for the
# production meshes.  (Smoke tests / benches never import this module.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * builds the jitted step (train/prefill/decode) with full production
    shardings (launch/steps.py),
  * ``.lower().compile()`` against ShapeDtypeStructs — no allocation,
  * records memory_analysis (fits-in-HBM proof), cost_analysis (FLOPs /
    bytes), and the collective schedule: every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute op in the optimized
    HLO with summed operand bytes (cost_analysis has no collective bytes),
  * derives the three roofline terms (EXPERIMENTS.md §Roofline):
      compute   = FLOPs / (chips * 197e12)
      memory    = bytes / (chips * 819e9)
      collective= collective_bytes / (chips * 50e9 * links)
  * writes experiments/dryrun/<arch>__<shape>__<mesh>.json (idempotent:
    existing cells are skipped unless --force).

Usage:
  python -m repro.launch.dryrun [--arch A] [--shape S] [--mesh single|multi]
                                [--force] [--list]
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_NAMES, SHAPES, runnable, skip_reason
from repro.launch.hlo_stats import cost_analysis_dict, parse_hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models.transformer import model_flops_per_token

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
ICI_LINKS = 4  # 2D torus links per chip usable concurrently

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64|c64)\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8, "c64": 8}


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the optimized HLO."""
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # ops look like: %name = TYPE[shape] all-gather(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)", s)
        if not m:
            continue
        rhs = m.group(1)
        for coll in _COLLECTIVES:
            if re.search(rf"\b{coll}(-start|-done)?\(", rhs):
                if f"{coll}-done(" in rhs:
                    break  # counted at -start
                shapes = _SHAPE_RE.findall(rhs.split("(")[0])
                nbytes = 0
                for dt, dims in shapes:
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _BYTES.get(dt, 4)
                out[coll]["count"] += 1
                out[coll]["bytes"] += nbytes
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def roofline_terms(flops: float, bytes_: float, coll_bytes: float,
                   chips: int, per_device: bool = True) -> dict:
    """Roofline terms in seconds.  ``per_device=True`` when the inputs come
    from the per-device SPMD program (hlo_stats parser)."""
    div = 1 if per_device else chips
    return {
        "compute_s": flops / (div * PEAK_FLOPS),
        "memory_s": bytes_ / (div * HBM_BW),
        "collective_s": coll_bytes / (div * ICI_BW * ICI_LINKS),
    }


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, sparse: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape}__{mesh_name}" + ("__sparse" if sparse else "")
    path = os.path.join(out_dir, f"{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "sparse": sparse, "status": "skip"}
    reason = skip_reason(arch, shape)
    if reason:
        rec["skip_reason"] = reason
        _write(path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        built = build_step(arch, shape, mesh, sparse=sparse)
        lowered = built.fn.lower(*built.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        coll = collective_stats(hlo)  # per-appearance counts (no loop mult)

        # Loop-aware per-device statistics (cost_analysis counts while
        # bodies once and misses scan trip counts — see hlo_stats.py).
        st = parse_hlo_stats(hlo)
        flops = st.flops
        bytes_ = st.bytes
        coll = {
            k: {"count": int(st.collective_counts.get(k, 0)),
                "bytes": float(st.collective_bytes_by_kind.get(k, 0.0))}
            for k in _COLLECTIVES
        }
        coll["total_bytes"] = float(st.collective_bytes)
        coll["total_count"] = int(sum(st.collective_counts.values()))
        coll["while_trips"] = st.while_trips[:16]
        spec = SHAPES[shape]
        tokens = (
            spec.global_batch * spec.seq_len
            if spec.kind in ("train", "prefill")
            else spec.global_batch
        )
        # model_flops_per_token is 6N (train fwd+bwd); fwd-only steps = 2N
        mf = model_flops_per_token(built.cfg)
        model_flops = mf * tokens if spec.kind == "train" else mf / 3.0 * tokens

        rec.update(
            status="ok",
            chips=chips,
            kind=built.kind,
            n_params=built.meta.get("n_params"),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            tokens=tokens,
            hlo_flops_per_device=flops,
            hlo_bytes_per_device=bytes_,
            cost_analysis_flops=float(cost.get("flops", 0.0)) if cost else 0.0,
            collectives=coll,
            memory_analysis={
                "bytes_per_device": getattr(
                    mem, "temp_size_in_bytes", None
                ),
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "repr": str(mem)[:2000],
            },
            model_flops=model_flops,
            roofline=roofline_terms(flops, bytes_, coll["total_bytes"], chips),
        )
        terms = rec["roofline"]
        dom = max(terms, key=terms.get)
        rec["dominant_term"] = dom
        rec["useful_flops_ratio"] = (
            model_flops / (flops * chips) if flops else None
        )
    except Exception as e:  # record failures — they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(path, rec)
    return rec


def _write(path: str, rec: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sparse", action="store_true",
                    help="enable the paper's block-pattern sparse MLPs")
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(OUT_DIR)
    archs = [args.arch] if args.arch else ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.list:
        for a in archs:
            for s in shapes:
                print(a, s, "runnable" if runnable(a, s) else "SKIP")
        return

    results = []
    for a in archs:
        for s in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(a, s, mp, out_dir, force=args.force,
                               sparse=args.sparse)
                dt = time.time() - t0
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f"dom={rec['dominant_term']} "
                        f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                        f"x={r['collective_s']:.2e}"
                    )
                elif status == "error":
                    extra = rec["error"][:120]
                print(
                    f"[{status:5}] {a:22} {s:12} "
                    f"{'multi' if mp else 'single':6} {dt:7.1f}s {extra}",
                    flush=True,
                )
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error")


if __name__ == "__main__":
    main()
