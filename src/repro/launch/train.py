"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --smoke \
      --steps 50 --batch 8 --seq 128

Builds the mesh over available devices, shards params/optimizer with the
production rules, feeds the packed synthetic pipeline, and drives the
fault-tolerant Trainer (periodic async checkpoints, resume-from-latest).
On the CPU container use --smoke (reduced config); the full configs are
for real TPU slices and are exercised via the dry-run.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, packed_batches, shard_batch
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import param_shardings
from repro.models.transformer import init_params
from repro.optim import adamw, linear_warmup_cosine
from repro.parallel.activations import activation_sharding_ctx
from repro.runtime.train import (
    TrainConfig,
    Trainer,
    init_train_state,
    make_train_step,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = (
        make_production_mesh()
        if args.production_mesh
        else make_local_mesh(data=n_dev, model=1)
    )

    params, specs, statics = init_params(cfg, jax.random.PRNGKey(0))
    p_shard = param_shardings(specs, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), mesh)
    params = jax.tree.map(jax.device_put, params, p_shard)

    opt = adamw()
    tcfg = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        async_ckpt=True,
    )
    lr_fn = linear_warmup_cosine(args.lr, 20, args.steps)
    step = make_train_step(cfg, statics, opt, lr_fn, tcfg)
    state = init_train_state(params, opt, tcfg)

    def wrapped(state, batch):
        with activation_sharding_ctx(mesh):
            return step(state, batch)

    step_fn = jax.jit(wrapped, donate_argnums=(0,))

    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    )
    batches = packed_batches(dcfg)
    trainer = Trainer(
        step_fn, state, batches, tcfg,
        put_batch=lambda b: shard_batch(b, mesh),
    )
    resumed = trainer.maybe_restore()
    if resumed:
        print(f"resumed from step {resumed}")
    history = trainer.run()
    for h in history[:: max(1, len(history) // 20)]:
        print(
            f"step {h['step']:5d} loss {h['loss']:.4f} "
            f"gnorm {h['grad_norm']:.3f} {h['seconds']*1e3:.0f}ms"
        )
    print(f"final loss {history[-1]['loss']:.4f}")
    return history


if __name__ == "__main__":
    main()
