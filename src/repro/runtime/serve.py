"""Serving runtime: prefill + decode steps and continuous-batching decode.

``make_prefill_step`` / ``make_decode_step`` build the jitted functions the
dry-run lowers for the decode_* / long_* shapes: one new token against a
KV cache of ``seq_len`` (cache donated, so decode is in-place in HBM).

:class:`DecodeService` is the continuous-batching generation backend:
per-slot decode positions (``pos [batch_slots]``) let the shared
:class:`~repro.engine.scheduler.SlotScheduler` admit a queued prompt into
a freed slot *while the other slots are mid-decode* — the vLLM model,
with the backend/metadata split keeping all per-request state (prompt
lengths, emitted counts, completion) host-side in the scheduler and only
fixed-shape arrays (``tokens [B]``, ``pos [B]``, the batched cache)
crossing into the traced function:

  * the decode step always runs at the fixed ``[batch_slots]`` shape and
    is traced exactly once (``trace_count()``); dead slots decode at
    position 0 into cache rows that the next admission overwrites;
  * admission prefills the prompt at its exact length on a fresh
    single-row cache and scatters that row into the batched cache
    (``make_slot_prefill``) — exact for recurrent SSM state too, where a
    padded batch prefill would fold pad garbage into the state.  Like
    vLLM, prefill compiles once per distinct prompt length
    (``prefill_trace_count()``); the single-trace invariant is a decode
    property;
  * a request's logits are bit-identical co-batched or solo: every
    per-row op (masked attention, SSM scan, sampling) is independent
    across batch rows.

:class:`ServeLoop` keeps the old drain-a-list-of-requests API on top of
it.  ``Request`` is a deprecated alias of :class:`repro.serve.Request`.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.scheduler import SlotScheduler
from repro.models.transformer import ModelConfig, apply_model, init_cache
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.api import Request as ServeRequest

__all__ = [
    "ServeConfig",
    "make_prefill_step",
    "make_decode_step",
    "make_slot_prefill",
    "DecodeService",
    "ServeLoop",
    "Request",
]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_seq: int = 1024
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = 0
    cache_dtype: str = "bfloat16"


def make_prefill_step(cfg: ModelConfig, statics, scfg: ServeConfig):
    def prefill(params, cache, tokens, extras=None):
        """tokens: [B, S] -> (next_token [B], cache).  A VLM patch prefix
        (extras['prefix_embeds']) extends the context; positions and cache
        length cover prefix + tokens."""
        kwargs = dict(extras or {})
        total = tokens.shape[1]
        if "prefix_embeds" in kwargs:
            total += kwargs["prefix_embeds"].shape[1]
        logits, cache, _ = apply_model(
            params, statics, tokens,
            positions=jnp.arange(total),
            cache=cache, cache_pos=jnp.int32(0), cache_len=jnp.int32(total),
            **kwargs,
        )
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1)
        return next_tok.astype(jnp.int32), cache

    return prefill


def make_decode_step(cfg: ModelConfig, statics, scfg: ServeConfig):
    def decode(params, cache, tokens, pos, rng=None):
        """tokens: [B] last emitted; pos: the position to write — a
        scalar shared by every slot (legacy generational decode) or a
        [B] vector of per-slot positions (continuous batching)."""
        per_row = getattr(pos, "ndim", 0) > 0
        logits, cache, _ = apply_model(
            params, statics, tokens[:, None],
            positions=pos[:, None] if per_row else pos[None],
            cache=cache, cache_pos=pos, cache_len=pos + 1,
        )
        logits = logits[:, -1, : cfg.vocab].astype(jnp.float32)
        if scfg.temperature > 0 and rng is not None:
            next_tok = jax.random.categorical(
                rng, logits / scfg.temperature, axis=-1
            )
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), cache

    return decode


def _scatter_cache_row(batch_cache, row_cache, slot):
    """Write the single-row ``row_cache`` pytree into row ``slot`` of the
    batched cache.  The cache pytree has heterogeneous batch axes: prefix
    layers and the encoder memory carry batch on axis 0, the scanned body
    stacks periods in front so batch sits on axis 1."""

    def write(dst, src, axis):
        start = [jnp.int32(0)] * dst.ndim
        start[axis] = slot
        return jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), tuple(start)
        )

    out = {
        "prefix_layers": [
            jax.tree.map(lambda d, s: write(d, s, 0), d_, s_)
            for d_, s_ in zip(
                batch_cache["prefix_layers"], row_cache["prefix_layers"]
            )
        ],
        "body": [
            jax.tree.map(lambda d, s: write(d, s, 1), d_, s_)
            for d_, s_ in zip(batch_cache["body"], row_cache["body"])
        ],
    }
    if "memory" in batch_cache:
        out["memory"] = write(
            batch_cache["memory"], row_cache["memory"], 0
        )
    return out


def make_slot_prefill(cfg: ModelConfig, statics, scfg: ServeConfig):
    def prefill(params, caches, tokens, slot):
        """tokens: [1, L] exact-length prompt; slot: scalar slot index.

        Prefills a fresh single-row cache at the prompt's exact length —
        no padding, so recurrent (SSM) state is exact — then scatters the
        row into the batched cache at ``slot``.  Returns
        (first sampled token [], updated batched caches)."""
        length = tokens.shape[1]
        row = init_cache(
            statics, 1, scfg.max_seq, dtype=jnp.dtype(scfg.cache_dtype)
        )
        logits, row, _ = apply_model(
            params, statics, tokens,
            positions=jnp.arange(length),
            cache=row, cache_pos=jnp.int32(0), cache_len=jnp.int32(length),
        )
        caches = _scatter_cache_row(caches, row, slot)
        next_tok = jnp.argmax(logits[0, -1, : cfg.vocab])
        return next_tok.astype(jnp.int32), caches

    return prefill


def _counted(fn, box: list):
    def wrapped(*args, **kwargs):
        box[0] += 1
        return fn(*args, **kwargs)

    return wrapped


class DecodeService:
    """Continuous-batching token generation over per-slot decode positions.

    Speaks the same step-based verb set as
    ``engine.service.InferenceService`` — ``submit``/``try_submit`` to
    enqueue a :class:`repro.serve.Request` (``prompt`` set), ``step()``
    to admit + advance one decode step, ``run()`` to drain — so the
    ``repro.serve`` session facade and HTTP server drive either backend
    identically.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        statics,
        params,
        scfg: ServeConfig,
        max_queue: int = 0,
        clock: Callable[[], float] = time.monotonic,
        tracer: Tracer | None = None,
        capture_logits: bool = False,
    ):
        self.cfg, self.statics, self.scfg = cfg, statics, scfg
        self.params = params
        self._tracer = tracer or NULL_TRACER
        self.scheduler = SlotScheduler(
            scfg.batch_slots, max_queue=max_queue, clock=clock, tracer=tracer
        )
        self.caches = init_cache(
            statics, scfg.batch_slots, scfg.max_seq,
            dtype=jnp.dtype(scfg.cache_dtype),
        )
        self._decode_traces = [0]
        self._prefill_traces = [0]
        decode_fn = make_decode_step(cfg, statics, scfg)
        self.capture_logits = capture_logits
        if capture_logits:
            # debug/test variant: also return the [B, vocab] decode
            # logits (still one jitted callable, still traced once)
            def decode_with_logits(params, cache, tokens, pos):
                logits, cache, _ = apply_model(
                    params, statics, tokens[:, None],
                    positions=pos[:, None], cache=cache,
                    cache_pos=pos, cache_len=pos + 1,
                )
                logits = logits[:, -1, : cfg.vocab].astype(jnp.float32)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return tok, logits, cache

            decode_fn = decode_with_logits
        self._decode = jax.jit(
            _counted(decode_fn, self._decode_traces), donate_argnums=(1,)
        )
        self._prefill = jax.jit(
            _counted(make_slot_prefill(cfg, statics, scfg),
                     self._prefill_traces),
            donate_argnums=(1,),
        )
        self._tokens = np.zeros(scfg.batch_slots, np.int32)
        self._pos = np.zeros(scfg.batch_slots, np.int32)
        self.last_logits: np.ndarray | None = None  # capture_logits only
        self.steps_run = 0

    # ------------------------------------------------------------ admission

    def trace_count(self) -> int:
        """How many times the fixed-shape decode step has been traced
        (the single-trace invariant: 1 for any traffic pattern)."""
        return self._decode_traces[0]

    def prefill_trace_count(self) -> int:
        """Prefill traces = number of distinct prompt lengths served."""
        return self._prefill_traces[0]

    @property
    def metrics(self) -> dict:
        return self.scheduler.snapshot()

    def metrics_text(self) -> str:
        return self.scheduler.metrics.to_prometheus(prefix="decode_service")

    def reset_metrics(self) -> None:
        self.scheduler.reset_metrics()

    def _validate(self, request: ServeRequest) -> ServeRequest:
        if request.prompt is None:
            raise ValueError("generation request needs a prompt")
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        if prompt.size < 1 or prompt.size > self.scfg.max_seq:
            raise ValueError(
                f"prompt length {prompt.size} outside [1, "
                f"{self.scfg.max_seq}]"
            )
        request.prompt = prompt
        return request

    def submit(self, request: ServeRequest) -> ServeRequest:
        """Validate + enqueue (raises ``SchedulerFull`` when bounded
        queue is full — front ends should use ``try_submit``)."""
        self.scheduler.submit(self._validate(request))
        return request

    def try_submit(self, request: ServeRequest) -> bool:
        return self.scheduler.try_submit(self._validate(request))

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # ------------------------------------------------------------- stepping

    def _finish(self, slot: int, req: ServeRequest, finished: list) -> None:
        req.done = True
        self.scheduler.complete(slot)
        self._tokens[slot] = 0
        self._pos[slot] = 0
        finished.append(req)

    def step(self) -> list[ServeRequest]:
        """Admit queued prompts into free slots (prefill), then advance
        every live slot one decode step at its own position.  Returns the
        requests completed by this step."""
        sched = self.scheduler
        scfg = self.scfg
        finished: list[ServeRequest] = []
        was_decoding = bool(sched.live())
        for slot, req in sched.refill():
            prompt = np.asarray(req.prompt, np.int32)[None]
            with self._tracer.span(
                "serve.prefill", cat="serve", slot=slot, len=prompt.shape[1]
            ):
                tok, self.caches = self._prefill(
                    self.params, self.caches, jnp.asarray(prompt),
                    jnp.int32(slot),
                )
                t = int(jax.device_get(tok))
            req.output.append(t)
            self._tokens[slot] = t
            self._pos[slot] = prompt.shape[1]
            sched.record_first_result(slot)
            if was_decoding:
                # the mid-decode admission instant: this slot was refilled
                # while other slots were already between decode steps
                self._tracer.async_instant(
                    "request", sched.slot_rid(slot), cat="request",
                    event="admit_mid_decode", slot=slot,
                    pos=int(prompt.shape[1]),
                )
            if (
                t == scfg.eos_id
                or len(req.output) >= req.max_new_tokens
                or self._pos[slot] >= scfg.max_seq
            ):
                self._finish(slot, req, finished)
        live = sched.live()
        if not live:
            return finished
        with self._tracer.span("serve.decode", cat="serve", live=len(live)):
            out = self._decode(
                self.params, self.caches, jnp.asarray(self._tokens),
                jnp.asarray(self._pos),
            )
            if self.capture_logits:
                tok, logits, self.caches = out
                self.last_logits = np.asarray(jax.device_get(logits))
            else:
                tok, self.caches = out
            tok_np = np.asarray(jax.device_get(tok))
        self.steps_run += 1
        sched.record_step()
        for slot, req in live:
            t = int(tok_np[slot])
            self._tokens[slot] = t
            self._pos[slot] += 1
            req.output.append(t)
            if (
                t == scfg.eos_id
                or len(req.output) >= req.max_new_tokens
                or self._pos[slot] >= scfg.max_seq
            ):
                self._finish(slot, req, finished)
        return finished

    def run(self) -> list[ServeRequest]:
        """Serve until the queue and every slot are drained."""
        finished: list[ServeRequest] = []
        while self.has_work():
            finished.extend(self.step())
        return finished


class Request(ServeRequest):
    """Deprecated: use :class:`repro.serve.Request` (``prompt=`` form)."""

    def __init__(self, prompt, max_new_tokens: int = 32, output=None,
                 done: bool = False):
        warnings.warn(
            "repro.runtime.serve.Request is deprecated; use "
            "repro.serve.Request(prompt=...)",
            DeprecationWarning, stacklevel=2,
        )
        super().__init__(
            prompt=np.asarray(prompt), max_new_tokens=max_new_tokens,
            output=list(output) if output else [], done=done,
        )


class ServeLoop:
    """Drain-a-list-of-requests wrapper over :class:`DecodeService`.

    Admission is now *continuous*: a freed slot refills from the queue on
    the very next step while the remaining slots keep decoding at their
    own per-slot positions (the old generational loop waited for the
    whole batch to finish).  ``loop.metrics`` carries the scheduler
    snapshot after :meth:`generate`.
    """

    def __init__(self, cfg: ModelConfig, statics, params, scfg: ServeConfig,
                 tracer: Tracer | None = None):
        self.cfg, self.statics, self.scfg = cfg, statics, scfg
        self.params = params
        self.tracer = tracer or NULL_TRACER
        self.service = DecodeService(
            cfg, statics, params, scfg, tracer=tracer
        )
        self.metrics: dict | None = None

    def generate(self, requests: list[ServeRequest]) -> list[ServeRequest]:
        for r in requests:
            self.service.submit(r)
        self.service.run()
        self.metrics = self.service.scheduler.snapshot()
        return requests
