"""Serving runtime: prefill + decode steps and a batched request loop.

``make_prefill_step`` / ``make_decode_step`` build the jitted functions the
dry-run lowers for the decode_* / long_* shapes: one new token against a
KV cache of ``seq_len`` (cache donated, so decode is in-place in HBM).

``ServeLoop`` is a miniature *generational* batching loop over the shared
:class:`~repro.engine.scheduler.SlotScheduler` control plane: fixed slot
count, greedy/temperature sampling, per-slot stop handling, and slot
refill from the scheduler's request queue at generation boundaries.
Admission is generational — not mid-decode — because prefill writes the
whole batch's cache at position 0 and the decode step advances one
*shared* scalar position for every slot; admitting a fresh prompt
mid-decode would need per-slot positions and a slot-indexed prefill.
(``engine/service.py`` serves the classification workload through the
same scheduler with true per-batch refill, since its requests complete
in a single step.)  The scheduler still supplies the queue, the slot
bookkeeping, and the per-request latency / occupancy metrics
(``loop.metrics`` after :meth:`ServeLoop.generate`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.scheduler import SlotScheduler
from repro.models.transformer import ModelConfig, apply_model, init_cache
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = ["ServeConfig", "make_prefill_step", "make_decode_step", "ServeLoop"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_seq: int = 1024
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = 0
    cache_dtype: str = "bfloat16"


def make_prefill_step(cfg: ModelConfig, statics, scfg: ServeConfig):
    def prefill(params, cache, tokens, extras=None):
        """tokens: [B, S] -> (next_token [B], cache).  A VLM patch prefix
        (extras['prefix_embeds']) extends the context; positions and cache
        length cover prefix + tokens."""
        kwargs = dict(extras or {})
        total = tokens.shape[1]
        if "prefix_embeds" in kwargs:
            total += kwargs["prefix_embeds"].shape[1]
        logits, cache, _ = apply_model(
            params, statics, tokens,
            positions=jnp.arange(total),
            cache=cache, cache_pos=jnp.int32(0), cache_len=jnp.int32(total),
            **kwargs,
        )
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1)
        return next_tok.astype(jnp.int32), cache

    return prefill


def make_decode_step(cfg: ModelConfig, statics, scfg: ServeConfig):
    def decode(params, cache, tokens, pos, rng=None):
        """tokens: [B] last emitted; pos: scalar position to write."""
        logits, cache, _ = apply_model(
            params, statics, tokens[:, None],
            positions=pos[None],
            cache=cache, cache_pos=pos, cache_len=pos + 1,
        )
        logits = logits[:, -1, : cfg.vocab].astype(jnp.float32)
        if scfg.temperature > 0 and rng is not None:
            next_tok = jax.random.categorical(
                rng, logits / scfg.temperature, axis=-1
            )
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), cache

    return decode


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 32
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Slot-based generational batching over the jitted decode step.

    Prefill is batch-wide (prompts left-padded to a shared length so the
    one scalar decode position lines up for every slot); decode advances
    all live slots together.  Slots refill from the shared scheduler's
    queue at generation boundaries — see the module docstring for why
    admission is not mid-decode.
    """

    def __init__(self, cfg: ModelConfig, statics, params, scfg: ServeConfig,
                 tracer: Tracer | None = None):
        self.cfg, self.statics, self.scfg = cfg, statics, scfg
        self.params = params
        self.prefill = jax.jit(make_prefill_step(cfg, statics, scfg))
        self.decode = jax.jit(
            make_decode_step(cfg, statics, scfg), donate_argnums=(1,)
        )
        # request lifecycles + per-generation prefill/decode spans land on
        # the same timeline as everything else holding this tracer
        self.tracer = tracer or NULL_TRACER
        self.metrics: dict | None = None

    def generate(self, requests: list[Request]) -> list[Request]:
        scfg = self.scfg
        sched = SlotScheduler(scfg.batch_slots, tracer=self.tracer)
        for r in requests:
            sched.submit(r)
        # all prompts in this miniature loop share a length per batch; pad
        maxlen = max(r.prompt.size for r in requests)
        caches = init_cache(
            self.statics, scfg.batch_slots, scfg.max_seq,
            dtype=jnp.dtype(scfg.cache_dtype),
        )
        while sched.has_work():
            admitted = sched.refill()  # generation boundary: all slots free
            if not admitted:
                break
            prompts = np.zeros((scfg.batch_slots, maxlen), np.int32)
            for slot, r in admitted:
                prompts[slot, -r.prompt.size :] = r.prompt  # left-pad
            with self.tracer.span(
                "serve.prefill", cat="serve", batch=len(admitted), len=maxlen
            ):
                tok, caches = self.prefill(
                    self.params, caches, jnp.asarray(prompts)
                )
                tok_np = np.asarray(jax.device_get(tok))
            for slot, r in admitted:
                r.output.append(int(tok_np[slot]))
            sched.record_step()
            pos = maxlen
            budget = max(r.max_new_tokens for _, r in admitted) - 1
            for _ in range(max(budget, 0)):
                if pos >= scfg.max_seq:
                    break
                with self.tracer.span("serve.decode", cat="serve", pos=pos):
                    tok, caches = self.decode(
                        self.params, caches, jnp.asarray(tok_np),
                        jnp.int32(pos),
                    )
                    tok_np = np.asarray(jax.device_get(tok))
                for slot, r in admitted:
                    if not r.done and len(r.output) < r.max_new_tokens:
                        t = int(tok_np[slot])
                        r.output.append(t)
                        if t == scfg.eos_id:
                            r.done = True
                sched.record_step()
                pos += 1
                if all(
                    r.done or len(r.output) >= r.max_new_tokens
                    for _, r in admitted
                ):
                    break
            for slot, r in admitted:
                r.done = True
                sched.complete(slot)
        self.metrics = sched.metrics.snapshot()
        return requests
