"""Serving runtime: prefill + decode steps and a batched request loop.

``make_prefill_step`` / ``make_decode_step`` build the jitted functions the
dry-run lowers for the decode_* / long_* shapes: one new token against a
KV cache of ``seq_len`` (cache donated, so decode is in-place in HBM).

``ServeLoop`` is a miniature continuous-batching scheduler: fixed slot
count, greedy/temperature sampling, per-slot stop handling, slot refill
from a request queue — the control plane a production server runs, minus
the RPC front end.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, apply_model, init_cache

__all__ = ["ServeConfig", "make_prefill_step", "make_decode_step", "ServeLoop"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_seq: int = 1024
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = 0
    cache_dtype: str = "bfloat16"


def make_prefill_step(cfg: ModelConfig, statics, scfg: ServeConfig):
    def prefill(params, cache, tokens, extras=None):
        """tokens: [B, S] -> (next_token [B], cache).  A VLM patch prefix
        (extras['prefix_embeds']) extends the context; positions and cache
        length cover prefix + tokens."""
        kwargs = dict(extras or {})
        total = tokens.shape[1]
        if "prefix_embeds" in kwargs:
            total += kwargs["prefix_embeds"].shape[1]
        logits, cache, _ = apply_model(
            params, statics, tokens,
            positions=jnp.arange(total),
            cache=cache, cache_pos=jnp.int32(0), cache_len=jnp.int32(total),
            **kwargs,
        )
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1)
        return next_tok.astype(jnp.int32), cache

    return prefill


def make_decode_step(cfg: ModelConfig, statics, scfg: ServeConfig):
    def decode(params, cache, tokens, pos, rng=None):
        """tokens: [B] last emitted; pos: scalar position to write."""
        logits, cache, _ = apply_model(
            params, statics, tokens[:, None],
            positions=pos[None],
            cache=cache, cache_pos=pos, cache_len=pos + 1,
        )
        logits = logits[:, -1, : cfg.vocab].astype(jnp.float32)
        if scfg.temperature > 0 and rng is not None:
            next_tok = jax.random.categorical(
                rng, logits / scfg.temperature, axis=-1
            )
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), cache

    return decode


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 32
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Slot-based continuous batching over the jitted decode step.

    Prefill is per-request (left-aligned into the slot's cache region);
    decode advances all live slots together.  Finished slots are refilled
    from the queue between decode steps.
    """

    def __init__(self, cfg: ModelConfig, statics, params, scfg: ServeConfig):
        self.cfg, self.statics, self.scfg = cfg, statics, scfg
        self.params = params
        self.prefill = jax.jit(make_prefill_step(cfg, statics, scfg))
        self.decode = jax.jit(
            make_decode_step(cfg, statics, scfg), donate_argnums=(1,)
        )

    def generate(self, requests: list[Request]) -> list[Request]:
        scfg = self.scfg
        # all prompts in this miniature loop share a length per batch; pad
        maxlen = max(r.prompt.size for r in requests)
        queue = list(requests)
        slots: list[Request | None] = [None] * scfg.batch_slots
        caches = init_cache(
            self.statics, scfg.batch_slots, scfg.max_seq,
            dtype=jnp.dtype(scfg.cache_dtype),
        )
        pos = 0
        # simple generational batching: fill all slots, prefill as one
        # batch, decode until all done, repeat
        while queue or any(s is not None for s in slots):
            batch_reqs = [queue.pop(0) for _ in range(min(len(queue), scfg.batch_slots))]
            if not batch_reqs:
                break
            prompts = np.zeros((scfg.batch_slots, maxlen), np.int32)
            for i, r in enumerate(batch_reqs):
                prompts[i, -r.prompt.size :] = r.prompt  # left-pad
            tok, caches = self.prefill(
                self.params, caches, jnp.asarray(prompts)
            )
            tok_np = np.asarray(jax.device_get(tok))
            for i, r in enumerate(batch_reqs):
                r.output.append(int(tok_np[i]))
            pos = maxlen
            budget = max(r.max_new_tokens for r in batch_reqs) - 1
            for _ in range(max(budget, 0)):
                if pos >= scfg.max_seq:
                    break
                tok, caches = self.decode(
                    self.params, caches, jnp.asarray(tok_np), jnp.int32(pos)
                )
                tok_np = np.asarray(jax.device_get(tok))
                for i, r in enumerate(batch_reqs):
                    if not r.done and len(r.output) < r.max_new_tokens:
                        t = int(tok_np[i])
                        r.output.append(t)
                        if t == scfg.eos_id:
                            r.done = True
                pos += 1
                if all(
                    r.done or len(r.output) >= r.max_new_tokens
                    for r in batch_reqs
                ):
                    break
            for r in batch_reqs:
                r.done = True
        return requests
