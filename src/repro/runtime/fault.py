"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real 1000+-node deployment these hooks attach to the cluster
coordinator (GCS / Borg / SLURM heartbeats); the policies themselves are
host-side Python and identical at any scale, so they are implemented and
tested here directly:

  * HeartbeatMonitor — per-host last-seen bookkeeping; hosts silent longer
    than ``timeout`` are declared dead.
  * StragglerDetector — per-step wall-time EWMA; steps slower than
    ``threshold`` x the median flag the slowest host.  Mitigation at the
    trainer level: checkpoint + elastic re-mesh without the straggler
    (or, within a step, rely on deterministic skip via gradient
    accumulation masks — see Trainer.run docstring).
  * RestartPolicy — bounded exponential backoff restart budget.
  * FailureInjector — deterministic fault schedule for tests/drills
    (fail step k, crash-after-save, etc.).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

__all__ = ["HeartbeatMonitor", "StragglerDetector", "RestartPolicy",
           "FailureInjector", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    pass


class HeartbeatMonitor:
    def __init__(self, hosts: list[str], timeout: float = 60.0):
        self.timeout = timeout
        now = time.monotonic()
        self.last_seen = {h: now for h in hosts}

    def beat(self, host: str, t: float | None = None):
        self.last_seen[host] = time.monotonic() if t is None else t

    def dead_hosts(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]


class StragglerDetector:
    """Flags steps much slower than the running median."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, duration: float) -> bool:
        is_straggler = False
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            if duration > self.threshold * med:
                self.flagged.append((step, duration))
                is_straggler = True
        self.times.append(duration)
        return is_straggler


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_base: float = 1.0
    backoff_cap: float = 60.0
    restarts: int = 0

    def next_delay(self) -> float | None:
        """None when the restart budget is exhausted."""
        if self.restarts >= self.max_restarts:
            return None
        delay = min(self.backoff_cap, self.backoff_base * (2 ** self.restarts))
        self.restarts += 1
        return delay


class FailureInjector:
    """Deterministic failure schedule for drills: {step: kind}."""

    def __init__(self, schedule: dict[int, str] | None = None):
        self.schedule = dict(schedule or {})
        self.fired: list[int] = []

    def maybe_fail(self, step: int):
        kind = self.schedule.get(step)
        if kind and step not in self.fired:
            self.fired.append(step)
            raise SimulatedFailure(f"injected {kind} at step {step}")
