"""Training runtime: loss, jitted train step, fault-tolerant driver loop.

The step function supports:
  * gradient accumulation (``microbatches`` > 1) via lax.scan,
  * global-norm clipping,
  * int8 error-feedback gradient compression across the DP axes
    (``grad_compression``) — see repro.optim.compression,
  * MTP auxiliary loss (DeepSeek-V3),
  * bf16 optimizer states for the trillion-parameter MoEs (configured per
    arch; DESIGN §6 memory budget).

The Trainer drives checkpoint/restart: periodic (async) checkpoints,
failure injection for drills, straggler detection, and resume-from-latest
— a SimulatedFailure mid-run restores and continues bit-exactly (tested).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.models.transformer import ModelConfig, apply_model
from repro.optim import (
    Optimizer,
    clip_by_global_norm,
    init_compression_state,
)
from repro.runtime.fault import FailureInjector, StragglerDetector

__all__ = ["TrainConfig", "cross_entropy", "make_train_step", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1
    grad_clip: float = 1.0
    grad_compression: bool = False
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    async_ckpt: bool = False
    mtp_weight: float = 0.3
    log_every: int = 10


def cross_entropy(
    logits: jax.Array, labels: jax.Array, vocab: int
) -> jax.Array:
    """Mean CE; entries >= vocab (padding columns) are excluded by the
    log-softmax mask."""
    lf = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab:
        neg = jnp.full((logits.shape[-1] - vocab,), -1e30, jnp.float32)
        lf = lf.at[..., vocab:].set(neg)
    logp = jax.nn.log_softmax(lf, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -ll.mean()


def make_train_step(
    cfg: ModelConfig,
    statics,
    opt: Optimizer,
    lr_fn: Callable,
    tcfg: TrainConfig,
    model_kwargs_fn: Callable[[dict], dict] | None = None,
):
    """Returns step(state, batch) -> (state, metrics).

    state = {params, opt_state, step, [comp_state]}.
    batch = {'tokens': [B, S+1], ...extra model inputs}.
    """

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        kwargs = model_kwargs_fn(batch) if model_kwargs_fn else {}
        logits, _, aux = apply_model(params, statics, inputs, **kwargs)
        if logits.shape[1] != labels.shape[1]:  # vlm prefix: score suffix
            logits = logits[:, -labels.shape[1]:]
        loss = cross_entropy(logits, labels, cfg.vocab)
        if "mtp_logits" in aux:
            mtp_labels = jnp.roll(labels, -1, axis=1)
            loss = loss + tcfg.mtp_weight * cross_entropy(
                aux["mtp_logits"][:, : mtp_labels.shape[1]], mtp_labels,
                cfg.vocab,
            )
        return loss

    def step(state, batch):
        params = state["params"]
        nmb = tcfg.microbatches
        if nmb > 1:
            tokens = batch["tokens"]
            b = tokens.shape[0]
            mb = {
                k: v.reshape((nmb, b // nmb) + v.shape[1:])
                for k, v in batch.items()
            }

            def accum(carry, mbatch):
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                return carry, (loss, grads)

            _, (losses, grad_stack) = jax.lax.scan(accum, 0.0, mb)
            loss = losses.mean()
            grads = jax.tree.map(lambda g: g.mean(0), grad_stack)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        if tcfg.grad_compression:
            from repro.optim.compression import (
                compress_gradients,
                decompress_gradients,
            )
            comp, new_comp_state = compress_gradients(
                grads, state["comp_state"]
            )
            # On a pod mesh the int8 tree is what crosses DCN (the pmean of
            # the dequantized values lowers to an int8-payload reduce when
            # the convert fuses); single-host tests exercise the numerics.
            grads = decompress_gradients(comp)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt_state"], params, lr)
        new_state = {
            "params": new_params,
            "opt_state": new_opt,
            "step": state["step"] + 1,
        }
        if tcfg.grad_compression:
            new_state["comp_state"] = new_comp_state
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return step


def init_train_state(params, opt: Optimizer, tcfg: TrainConfig):
    state = {
        "params": params,
        "opt_state": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.grad_compression:
        state["comp_state"] = init_compression_state(params)
    return state


class Trainer:
    """Fault-tolerant training driver (checkpoint / restart / stragglers)."""

    def __init__(
        self,
        step_fn,
        state,
        batches,
        tcfg: TrainConfig,
        injector: FailureInjector | None = None,
        put_batch=None,
    ):
        self.step_fn = step_fn
        self.state = state
        self.batches = batches
        self.tcfg = tcfg
        self.injector = injector or FailureInjector()
        self.put_batch = put_batch or (lambda b: b)
        self.ckpt = Checkpointer(
            tcfg.ckpt_dir, keep=tcfg.ckpt_keep, async_save=tcfg.async_ckpt
        )
        self.straggler = StragglerDetector()
        self.history: list[dict] = []

    def maybe_restore(self) -> int:
        step = self.ckpt.latest_step()
        if step is not None:
            self.state = self.ckpt.restore(step, self.state)
            return step
        return 0

    def run(self, steps: int | None = None):
        """Run (or resume) the training loop.

        A SimulatedFailure propagates to the caller, who restarts by
        constructing a fresh Trainer and calling maybe_restore() + run()
        — the integration test exercises exactly that sequence and asserts
        bit-identical losses vs an uninterrupted run.
        """
        steps = steps if steps is not None else self.tcfg.steps
        start = int(jax.device_get(self.state["step"]))
        for step in range(start, steps):
            batch = self.put_batch(next(self.batches))
            self.injector.maybe_fail(step)
            t0 = time.monotonic()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self.straggler.record(step, dt)
            metrics.update(step=step, seconds=dt)
            self.history.append(metrics)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == steps:
                self.ckpt.save(step + 1, self.state)
        self.ckpt.wait()
        return self.history
