"""``repro.serve`` — the unified serving surface.

One contract (:class:`Request`/:class:`Response`), one verb set
(``submit``/``stream``/``run`` on :class:`ServeSession`), and one HTTP
front door (:class:`ServingServer`) over both serving backends:

  * classification — ``engine.service.InferenceService`` over a compiled
    crossbar program (``classify_session(program)``);
  * generation — ``runtime.serve.DecodeService`` with per-slot mid-decode
    admission (``generate_session(cfg, statics, params, scfg)``).

``api`` is imported eagerly (it is leaf-level: stdlib + numpy, no repro
imports, so ``engine``/``runtime`` modules can depend on it without
cycles); the session facade and HTTP server — which pull in the heavy
engine/runtime stacks — load lazily on first attribute access.
"""

from repro.serve.api import Overloaded, Request, Response

__all__ = [
    "Overloaded",
    "Request",
    "Response",
    "ServeSession",
    "classify_session",
    "generate_session",
    "ServingServer",
]

_LAZY = {
    "ServeSession": "repro.serve.session",
    "classify_session": "repro.serve.session",
    "generate_session": "repro.serve.session",
    "ServingServer": "repro.serve.server",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
