"""Unified serving contract: one ``Request``/``Response`` pair for every
front end.

Historically the repo grew two request models — classification's
``engine/service.ClassifyRequest`` (image in, logits/label out) and
generation's ``runtime/serve.Request`` (prompt in, tokens out).  Both are
now thin deprecation shims over the single :class:`Request` here, and the
HTTP server, the :class:`~repro.serve.session.ServeSession` facade, and
both backends speak only this contract.

This module is deliberately leaf-level: stdlib + numpy only, no imports
from anywhere else in ``repro``, so the engine and runtime packages can
import it without cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = ["Request", "Response", "Overloaded"]


class Overloaded(RuntimeError):
    """The service is shedding load: the bounded queue is full.

    Carries ``retry_after_s`` — the backpressure-derived hint a client
    should wait before retrying (HTTP front ends surface it as a 429
    with a ``Retry-After`` header).  This is the *only* overload signal
    on the public serve path; the scheduler-internal
    ``SchedulerFull`` never escapes a session or the HTTP server.
    """

    def __init__(self, retry_after_s: float):
        super().__init__(
            f"service overloaded; retry after {retry_after_s:.3f}s"
        )
        self.retry_after_s = float(retry_after_s)


@dataclasses.dataclass
class Request:
    """One unit of serving work, for either workload.

    Exactly one of ``image`` (classification: ``[C, H, W]`` float) or
    ``prompt`` (generation: ``[L]`` int tokens) is set.  Result fields
    are filled in place as the backend serves the request —
    ``logits``/``label`` for classification, ``output`` (one appended
    token per decode step, so a streaming front end can flush tokens as
    they land) for generation — and ``done`` flips when it completes.
    """

    image: np.ndarray | None = None
    prompt: np.ndarray | None = None
    max_new_tokens: int = 32
    # results (filled by the serving backend)
    logits: np.ndarray | None = None
    label: int | None = None
    output: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def kind(self) -> str:
        return "classify" if self.image is not None else "generate"

    def response(self) -> "Response":
        """The success :class:`Response` for this (completed) request."""
        return Response(
            ok=self.done,
            kind=self.kind,
            label=self.label,
            logits=self.logits,
            tokens=list(self.output) if self.output else None,
        )


@dataclasses.dataclass
class Response:
    """What a front end returns for one request.

    ``ok=False`` carries an ``error`` string and, for shed requests, the
    ``retry_after_s`` backpressure hint.
    """

    ok: bool = True
    kind: str | None = None
    label: int | None = None
    logits: np.ndarray | None = None
    tokens: list[int] | None = None
    error: str | None = None
    retry_after_s: float | None = None

    @classmethod
    def shed(cls, retry_after_s: float) -> "Response":
        return cls(ok=False, error="overloaded",
                   retry_after_s=float(retry_after_s))

    def to_json(self) -> dict[str, Any]:
        """JSON-serializable dict (numpy arrays listed, Nones dropped)."""
        out: dict[str, Any] = {"ok": self.ok}
        if self.kind is not None:
            out["kind"] = self.kind
        if self.label is not None:
            out["label"] = int(self.label)
        if self.logits is not None:
            out["logits"] = np.asarray(self.logits).tolist()
        if self.tokens is not None:
            out["tokens"] = [int(t) for t in self.tokens]
        if self.error is not None:
            out["error"] = self.error
        if self.retry_after_s is not None:
            out["retry_after_s"] = self.retry_after_s
        return out
