"""The ``submit``/``stream``/``run`` facade over either serving backend.

:class:`ServeSession` wraps a step-based backend —
``engine.service.InferenceService`` (classification) or
``runtime.serve.DecodeService`` (generation) — behind the one public
verb set the HTTP server and clients use:

  * :meth:`submit` — enqueue one request; raises
    :class:`~repro.serve.api.Overloaded` (with a backpressure-derived
    ``retry_after_s``) instead of ever surfacing the scheduler-internal
    ``SchedulerFull``;
  * :meth:`stream` — drain a list of requests, yielding each as it
    completes (completion order, not submission order);
  * :meth:`run` — drain a list of requests and return them.

``stream``/``run`` interleave submission with stepping, so a bounded
queue is backpressure (work waits), never a rejection — shedding only
applies to :meth:`submit`'s one-shot admission, the RPC path.
"""

from __future__ import annotations

from typing import Iterable, Iterator

import numpy as np

from repro.serve.api import Overloaded, Request

__all__ = ["ServeSession", "classify_session", "generate_session"]


class ServeSession:
    """Uniform serving session over a step-based backend.

    The backend protocol (both backends implement it): ``try_submit``,
    ``step``, ``has_work``, ``scheduler``, ``trace_count``, ``metrics``,
    ``metrics_text``, ``reset_metrics``.
    """

    def __init__(self, backend):
        self.backend = backend

    # ------------------------------------------------------------- verbs

    def submit(self, request: Request) -> Request:
        """Enqueue one request for the serving loop.

        Raises :class:`Overloaded` with a retry hint when the bounded
        queue is full (the scheduler counts the rejection), and
        ``ValueError`` on malformed payloads.  Never raises
        ``SchedulerFull``.
        """
        if not self.backend.try_submit(request):
            raise Overloaded(self.backend.scheduler.retry_after_hint())
        return request

    def stream(self, requests: Iterable[Request]) -> Iterator[Request]:
        """Drain ``requests``, yielding each the moment it completes.

        Submission interleaves with stepping: a bounded queue throttles
        admission instead of rejecting, so every request is eventually
        served.
        """
        pending = list(requests)
        while pending or self.backend.has_work():
            while pending and self.backend.scheduler.has_capacity():
                self.backend.submit(pending.pop(0))
            yield from self.backend.step()

    def run(self, requests: Iterable[Request]) -> list[Request]:
        """Drain ``requests`` to completion and return them (in the
        original order; see :meth:`stream` for completion order)."""
        requests = list(requests)
        for _ in self.stream(requests):
            pass
        return requests

    # ------------------------------------------------------- pass-through

    def step(self) -> list[Request]:
        return self.backend.step()

    def has_work(self) -> bool:
        return self.backend.has_work()

    @property
    def scheduler(self):
        return self.backend.scheduler

    def trace_count(self) -> int:
        return self.backend.trace_count()

    @property
    def metrics(self) -> dict:
        return self.backend.metrics

    def metrics_text(self) -> str:
        return self.backend.metrics_text()

    def reset_metrics(self) -> None:
        self.backend.reset_metrics()

    def warmup(self) -> None:
        """Trace the jitted path(s) before taking traffic, then reset the
        metrics window — so the first real request doesn't pay compile
        latency and the served-traffic metrics exclude any warm batch."""
        native = getattr(self.backend, "warmup", None)
        if native is not None:
            # classification: trace at the fixed batch shape directly,
            # no synthetic request through the scheduler
            native()
        else:
            # generation: prefill traces are per prompt length, so drive
            # one tiny request through the real admit/decode path
            req = Request(prompt=np.ones(4, np.int32), max_new_tokens=2)
            self.backend.submit(req)
            while self.backend.has_work():
                self.backend.step()
        self.backend.reset_metrics()
        if hasattr(self.backend, "reset_stats"):
            self.backend.reset_stats()


def classify_session(program, **kwargs) -> ServeSession:
    """A :class:`ServeSession` serving classification over a compiled
    program (kwargs forward to ``engine.service.InferenceService``)."""
    from repro.engine.service import InferenceService

    return ServeSession(InferenceService(program, **kwargs))


def generate_session(cfg, statics, params, scfg, **kwargs) -> ServeSession:
    """A :class:`ServeSession` serving token generation with mid-decode
    admission (kwargs forward to ``runtime.serve.DecodeService``)."""
    from repro.runtime.serve import DecodeService

    return ServeSession(DecodeService(cfg, statics, params, scfg, **kwargs))
