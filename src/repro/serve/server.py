"""Async HTTP serving front end (stdlib only: ``asyncio`` + hand-rolled
HTTP/1.1).

One process, three moving parts:

  * the **event loop** accepts connections, parses requests, and admits
    work through the :class:`~repro.serve.session.ServeSession` facade —
    admission is just ``scheduler.try_submit`` under the scheduler lock,
    so it is safe from the loop thread while the worker steps;
  * one **worker thread** owns every jitted call: it waits for work,
    optionally lingers ``admit_wait_s`` so a fresh burst fills the whole
    batch (occupancy), then runs ``backend.step()`` — refill + one
    fixed-shape forward/decode — and resolves the finished requests'
    futures back onto the event loop with ``call_soon_threadsafe``;
  * **load shedding**: when the bounded queue is full, ``POST`` returns
    ``429`` with a ``Retry-After`` header computed from live
    backpressure (queue depth x smoothed step time).  Work the scheduler
    has admitted is never dropped — shedding applies only at the front
    door.

Endpoints::

  POST /v1/run      one request  {"image": [[[...]]]} or
                    {"prompt": [...], "max_new_tokens": n} -> JSON result
  POST /v1/stream   {"requests": [...]} -> chunked NDJSON, one line per
                    request *in completion order* (line carries "index")
  GET  /metrics     Prometheus text exposition (scheduler + SLO hists)
  GET  /healthz     liveness + queue/slot occupancy snapshot

The server boots with a warmup request (trace before traffic), so
``trace_count() == 1`` holds under arbitrary socket-driven concurrency.
"""

from __future__ import annotations

import asyncio
import json
import math
import threading
import time

import numpy as np

from repro.obs.metrics import Meter
from repro.serve.api import Overloaded, Request, Response
from repro.serve.session import ServeSession

__all__ = ["ServingServer"]

_MAX_BODY = 64 * 1024 * 1024


class _HttpError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def _parse_request(obj) -> Request:
    if not isinstance(obj, dict):
        raise _HttpError(400, "request must be a JSON object")
    if "image" in obj:
        try:
            image = np.asarray(obj["image"], np.float32)
        except (TypeError, ValueError) as e:
            raise _HttpError(400, f"bad image payload: {e}") from e
        return Request(image=image)
    if "prompt" in obj:
        try:
            prompt = np.asarray(obj["prompt"], np.int32).reshape(-1)
        except (TypeError, ValueError) as e:
            raise _HttpError(400, f"bad prompt payload: {e}") from e
        return Request(
            prompt=prompt,
            max_new_tokens=int(obj.get("max_new_tokens", 32)),
        )
    raise _HttpError(400, "request needs 'image' or 'prompt'")


class ServingServer:
    """Streaming asyncio HTTP server over a :class:`ServeSession`.

    Args:
      session: the serving session (``classify_session`` /
        ``generate_session``); a bare backend is wrapped automatically.
      host/port: bind address; port 0 picks a free port (see
        ``server.address`` after start).
      admit_wait_s: how long the worker lingers for more arrivals when
        the batch is idle and not yet full — trades a few ms of first
        -request latency for near-full occupancy under bursts.
      warmup: run one warmup request at boot (trace before traffic).
    """

    def __init__(
        self,
        session: ServeSession,
        host: str = "127.0.0.1",
        port: int = 0,
        admit_wait_s: float = 0.004,
        warmup: bool = True,
    ):
        if not isinstance(session, ServeSession):
            session = ServeSession(session)
        self.session = session
        self.host, self.port = host, port
        self.admit_wait_s = admit_wait_s
        self.do_warmup = warmup
        self.address: tuple[str, int] | None = None
        self.completed = 0  # requests finished over HTTP (any endpoint)
        self.meter = Meter()  # sustained completion rate (req/s, windowed)
        self._stop = threading.Event()
        self._work = threading.Condition()
        self._futures: dict[int, tuple[asyncio.Future, asyncio.AbstractEventLoop]] = {}
        self._server: asyncio.AbstractServer | None = None
        self._worker: threading.Thread | None = None
        self._thread: threading.Thread | None = None
        self._thread_loop: asyncio.AbstractEventLoop | None = None

    # ------------------------------------------------------------ lifecycle

    async def start(self) -> tuple[str, int]:
        """Warm the backend, bind the socket, start the worker thread."""
        if self.do_warmup:
            self.session.warmup()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port
        )
        self.address = self._server.sockets[0].getsockname()[:2]
        self._worker = threading.Thread(
            target=self._worker_loop, name="serve-worker", daemon=True
        )
        self._worker.start()
        return self.address

    async def stop(self) -> None:
        self._stop.set()
        with self._work:
            self._work.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=10)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    def start_in_thread(self) -> tuple[str, int]:
        """Boot the server on its own event-loop thread; returns the
        bound ``(host, port)``.  Pair with :meth:`shutdown`."""
        ready = threading.Event()
        boot_err: list[BaseException] = []

        def runner():
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)
            self._thread_loop = loop
            try:
                loop.run_until_complete(self.start())
            except BaseException as e:  # surface boot failures to caller
                boot_err.append(e)
                ready.set()
                return
            ready.set()
            try:
                loop.run_forever()
            finally:
                loop.close()

        self._thread = threading.Thread(
            target=runner, name="serve-http", daemon=True
        )
        self._thread.start()
        ready.wait(timeout=300)
        if boot_err:
            raise boot_err[0]
        if self.address is None:
            raise RuntimeError("server failed to start within timeout")
        return self.address

    def shutdown(self) -> None:
        """Stop a :meth:`start_in_thread` server from any thread."""
        if self._thread_loop is None:
            return
        fut = asyncio.run_coroutine_threadsafe(self.stop(), self._thread_loop)
        fut.result(timeout=30)
        self._thread_loop.call_soon_threadsafe(self._thread_loop.stop)
        if self._thread is not None:
            self._thread.join(timeout=30)

    # --------------------------------------------------------------- worker

    def _worker_loop(self) -> None:
        backend = self.session.backend
        sched = backend.scheduler
        while not self._stop.is_set():
            with self._work:
                while not self._stop.is_set() and not backend.has_work():
                    self._work.wait(timeout=0.05)
            if self._stop.is_set():
                return
            # admission batching: if nothing is mid-flight, linger briefly
            # so a burst fills the whole batch before the first step —
            # occupancy over the burst approaches 1 instead of serving the
            # first arrival alone.  Never delays live decode work.
            if self.admit_wait_s > 0 and not sched.live():
                deadline = time.monotonic() + self.admit_wait_s
                while (
                    sched.queued() < sched.batch_slots
                    and time.monotonic() < deadline
                    and not self._stop.is_set()
                ):
                    time.sleep(self.admit_wait_s / 8)
            for req in backend.step():
                self.completed += 1
                self.meter.mark()
                entry = self._futures.pop(id(req), None)
                if entry is not None:
                    fut, loop = entry
                    loop.call_soon_threadsafe(self._resolve, fut, req)

    @staticmethod
    def _resolve(fut: asyncio.Future, req: Request) -> None:
        if not fut.done():
            fut.set_result(req)

    async def _submit(self, req: Request) -> asyncio.Future:
        """Register a completion future, then admit (order matters: the
        worker may finish the request before ``submit`` returns)."""
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        self._futures[id(req)] = (fut, loop)
        try:
            self.session.submit(req)
        except BaseException:
            self._futures.pop(id(req), None)
            raise
        with self._work:
            self._work.notify()
        return fut

    # ----------------------------------------------------------------- http

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            while not self._stop.is_set():
                line = await reader.readline()
                if not line:
                    break
                try:
                    method, path, _version = line.decode("ascii").split()
                except ValueError:
                    await self._plain(writer, 400, "bad request line")
                    break
                headers = {}
                while True:
                    h = await reader.readline()
                    if h in (b"\r\n", b"\n", b""):
                        break
                    k, _, v = h.decode("latin-1").partition(":")
                    headers[k.strip().lower()] = v.strip()
                length = int(headers.get("content-length", 0) or 0)
                if length > _MAX_BODY:
                    await self._plain(writer, 413, "body too large")
                    break
                body = await reader.readexactly(length) if length else b""
                keep = await self._route(method, path, body, writer)
                if not keep or headers.get("connection", "").lower() == "close":
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _route(self, method: str, path: str, body: bytes,
                     writer: asyncio.StreamWriter) -> bool:
        """Dispatch one request; returns whether to keep the connection."""
        if method == "GET" and path == "/healthz":
            sched = self.session.scheduler
            payload = {
                "ok": True,
                "live": len(sched.live()),
                "queued": sched.queued(),
                "batch_slots": sched.batch_slots,
            }
            await self._json(writer, 200, payload)
            return True
        if method == "GET" and path == "/metrics":
            text = (
                self.session.metrics_text().rstrip("\n") + "\n"
                + "\n".join(self.meter.prom_lines("serve_http_requests"))
                + "\n"
            ).encode()
            await self._raw(
                writer, 200, text, "text/plain; version=0.0.4"
            )
            return True
        if method == "POST" and path == "/v1/run":
            return await self._run_one(body, writer)
        if method == "POST" and path == "/v1/stream":
            return await self._run_stream(body, writer)
        await self._plain(writer, 404, f"no route {method} {path}")
        return True

    async def _run_one(self, body: bytes, writer) -> bool:
        try:
            req = _parse_request(self._load_json(body))
            fut = await self._submit(req)
        except _HttpError as e:
            await self._plain(writer, e.status, e.message)
            return True
        except Overloaded as e:
            await self._shed(writer, e)
            return True
        except ValueError as e:
            await self._plain(writer, 400, str(e))
            return True
        req = await fut
        await self._json(writer, 200, req.response().to_json())
        return True

    async def _run_stream(self, body: bytes, writer) -> bool:
        try:
            obj = self._load_json(body)
            items = obj.get("requests") if isinstance(obj, dict) else None
            if not isinstance(items, list) or not items:
                raise _HttpError(400, "body needs a 'requests' list")
            parsed = [_parse_request(o) for o in items]
        except _HttpError as e:
            await self._plain(writer, e.status, e.message)
            return True
        # chunked NDJSON: one line per request, in completion order
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: application/x-ndjson\r\n"
            b"Transfer-Encoding: chunked\r\n\r\n"
        )
        index_of = {id(r): i for i, r in enumerate(parsed)}
        waits = []
        for req in parsed:
            try:
                fut = await self._submit(req)
            except Overloaded as e:
                # shed this one; everything already admitted still runs
                line = Response.shed(e.retry_after_s).to_json()
                line["index"] = index_of[id(req)]
                await self._chunk(writer, line)
                continue
            except ValueError as e:
                line = {"ok": False, "error": str(e),
                        "index": index_of[id(req)]}
                await self._chunk(writer, line)
                continue
            waits.append(fut)
        for fut in asyncio.as_completed(waits):
            req = await fut
            line = req.response().to_json()
            line["index"] = index_of[id(req)]
            await self._chunk(writer, line)
        writer.write(b"0\r\n\r\n")
        await writer.drain()
        return True

    # -------------------------------------------------------------- replies

    @staticmethod
    def _load_json(body: bytes):
        try:
            return json.loads(body.decode() or "null")
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise _HttpError(400, f"bad JSON body: {e}") from e

    async def _shed(self, writer, e: Overloaded) -> None:
        body = json.dumps(Response.shed(e.retry_after_s).to_json()).encode()
        retry = max(1, math.ceil(e.retry_after_s))
        writer.write(
            b"HTTP/1.1 429 Too Many Requests\r\n"
            b"Content-Type: application/json\r\n"
            + f"Retry-After: {retry}\r\n".encode()
            + f"Content-Length: {len(body)}\r\n\r\n".encode()
            + body
        )
        await writer.drain()

    @staticmethod
    async def _raw(writer, status: int, body: bytes, ctype: str) -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  413: "Payload Too Large"}.get(status, "Error")
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n\r\n".encode() + body
        )
        await writer.drain()

    async def _json(self, writer, status: int, payload: dict) -> None:
        await self._raw(
            writer, status, json.dumps(payload).encode(), "application/json"
        )

    async def _plain(self, writer, status: int, message: str) -> None:
        await self._raw(writer, status, message.encode(), "text/plain")

    @staticmethod
    async def _chunk(writer, payload: dict) -> None:
        data = json.dumps(payload).encode() + b"\n"
        writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
        await writer.drain()
