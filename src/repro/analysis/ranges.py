"""Static value-range & bit-width certification for compiled programs.

An execution-free abstract interpreter over
:class:`~repro.engine.program.CompiledNetwork`: starting from a declared
input interval it pushes interval bounds through every op of the
compiled schedule — conv-as-spmm + bias, ``channel_norm``, ReLU, 2x2
maxpool, global average pool, the FC head — and, for quantized
programs, derives activation-independent worst-case extrema of the int8
spmm's accumulators straight from the stored bricks and scales.  Where
``analysis/verify.py`` proves the program is *structurally* sound (the
arrays mean what the executor assumes), this pass proves *semantic*
facts about the values the program can produce.

Interval semantics (all arithmetic in float64 over the stored payloads;
quantized operands are interpreted through their dequantized effective
weights ``w_comp * w_scales``, and activation quantization widens the
interval by the half-step round-off ``amax / (2 * QMAX)``):

* spmm + bias: per output column ``j``,
  ``hi_j = b_j + hi * sum(pos w_j) + lo * sum(neg w_j)`` (and dually for
  ``lo_j``) — exact for a matmul over a scalar input interval.
* ``channel_norm``: the divisor ``std + eps`` lies in ``[eps, inf)``, so
  the sound image of ``[lo, hi]`` is ``[min(lo, 0)/eps, max(hi, 0)/eps]``
  (the ``hi/eps`` endpoint is *attained* by a constant feature map, so no
  tighter activation-independent bound exists).  This grows bounds by up
  to ``1/eps`` per layer: deep stacks certifiably exceed the fp32 range
  under adversarial inputs, which the certificate records as
  ``fp32_safe`` and a V504 warning rather than an error — only
  non-finite (genuinely divergent) bounds are an error.
* ReLU / maxpool / global average pool map ``[lo, hi]`` to
  ``[max(lo, 0), max(hi, 0)]`` / identity / identity.

Accumulator model (int8 path, mirrors
``core/sparse.pattern_spmm_xla_quant``): each scan step contracts one
brick's ``block`` rows in int32 (``|qx| <= QMAX``), so the int32 partial
is bounded by ``QMAX * max column abs-sum per brick``; the fp32
accumulator folds per-brick scales, so its pre-epilogue bound is
``max_j sum_k s_k * QMAX * colsum_k(j)`` — both are activation
independent and V501 proves them inside their types.

Rules (same :class:`~repro.analysis.diagnostics.Report` currency as the
verifier; V5xx extends its V1xx-V4xx families):

=====  =================================================================
rule   semantic guarantee
=====  =================================================================
V501   accumulator-overflow proof: the worst-case int32 spmm partial
       stays below 2**31 and the scale-folded fp32 accumulator stays
       finite (error when not provable)
V502   scale saturation (``s * QMAX`` overflows fp32) or denormal
       (``0 < s <`` the smallest normal fp32) — silent precision loss
V503   dead-scale group: an active brick with scale 0 over nonzero
       stored weights dequantizes a whole OU row-group to zero (warning;
       the structural twin of verify's V112 error)
V504   activation-range divergence: non-finite certified bounds are an
       error; bounds that certifiably exceed the fp32 range under
       worst-case normalisation are a warning (``fp32_safe=False``)
V505   unreachable cell slices: the certified per-layer cell count is
       below the stored ``n_cell_slices`` — the top slice(s) are
       provably zero operand-wide (warning)
V506   a stored certificate disagrees with recomputation from the
       payloads (stale or corrupted manifest entry)
=====  =================================================================

The :class:`RangeCertificate` payload carries, per layer, the certified
activation interval, the accumulator extrema, and a per-OU-row-group
**certified minimum cells-per-weight** table: each brick's magnitude is
re-expressed on the layer's operand-uniform reference grid (the step of
the largest per-brick scale) and mapped through
:func:`~repro.core.quantize.cells_for_magnitude` — exactly the input the
MSR-style variable-cell lowering (ROADMAP "Sub-4-bit cells") needs, and
what ``hardware_report()`` prices as its ``certified_potential``
section.  The certificate is pure numpy over the stored arrays, hence
bit-deterministic across processes, and rides in manifest v4
(``engine/serialize.py``).

Entry points mirror the verifier's: :func:`analyze_network` (in-memory,
wired into ``compile_network(verify=...)`` as the ``ranges`` compile
span) and :func:`analyze_saved` (serialized directories; the ``python
-m repro.analysis ranges <dir>`` CLI wraps it).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis.diagnostics import (
    ERROR,
    WARNING,
    ProgramFormatError,
    Report,
)
from repro.core.quantize import QMAX, cells_for_magnitude, n_cell_slices

__all__ = [
    "DEFAULT_INPUT_RANGE",
    "NORM_EPS",
    "LayerRanges",
    "RangeCertificate",
    "analyze_network",
    "analyze_saved",
]

# declared activation range of the network input when the caller does not
# say otherwise: normalized image data (zero mean, unit-ish scale) stays
# well inside +-3 sigma
DEFAULT_INPUT_RANGE = (-3.0, 3.0)

# must match models.cnn.channel_norm's eps default (pinned by a test so a
# drift there breaks loudly instead of silently decertifying programs)
NORM_EPS = 1e-5

_F32_MAX = float(np.finfo(np.float32).max)
_F32_TINY = float(np.finfo(np.float32).tiny)
_INT32_LIMIT = 2**31


@dataclasses.dataclass(frozen=True)
class LayerRanges:
    """Certified per-layer facts: bounds, extrema, minimum cell table.

    ``pre_lo``/``pre_hi`` bound the raw spmm + bias output (the logits,
    for the FC head); ``act_lo``/``act_hi`` bound the layer's *output*
    activations after norm/ReLU/pool.  The quantized-path fields are
    ``None`` on fp32 operands.  ``min_cells`` is the ``[T, k_max]``
    certified cells-per-weight table (0 for groups that vanish on the
    layer's uniform reference grid); ``certified_cells`` is its max —
    the cell count the whole layer provably fits in.
    """

    name: str
    pre_lo: float
    pre_hi: float
    act_lo: float
    act_hi: float
    acc_int32_max: int | None = None
    acc_fp32_max: float | None = None
    min_cells: tuple[tuple[int, ...], ...] | None = None
    certified_cells: int | None = None
    stored_cells: int | None = None

    def to_manifest(self) -> dict:
        return {
            "name": self.name,
            "pre_lo": self.pre_lo,
            "pre_hi": self.pre_hi,
            "act_lo": self.act_lo,
            "act_hi": self.act_hi,
            "acc_int32_max": self.acc_int32_max,
            "acc_fp32_max": self.acc_fp32_max,
            "min_cells": (
                None if self.min_cells is None
                else [list(row) for row in self.min_cells]
            ),
            "certified_cells": self.certified_cells,
            "stored_cells": self.stored_cells,
        }

    @classmethod
    def from_manifest(cls, entry: dict) -> "LayerRanges":
        mc = entry.get("min_cells")
        return cls(
            name=str(entry["name"]),
            pre_lo=float(entry["pre_lo"]),
            pre_hi=float(entry["pre_hi"]),
            act_lo=float(entry["act_lo"]),
            act_hi=float(entry["act_hi"]),
            acc_int32_max=(
                None if entry.get("acc_int32_max") is None
                else int(entry["acc_int32_max"])
            ),
            acc_fp32_max=(
                None if entry.get("acc_fp32_max") is None
                else float(entry["acc_fp32_max"])
            ),
            min_cells=(
                None if mc is None
                else tuple(tuple(int(c) for c in row) for row in mc)
            ),
            certified_cells=(
                None if entry.get("certified_cells") is None
                else int(entry["certified_cells"])
            ),
            stored_cells=(
                None if entry.get("stored_cells") is None
                else int(entry["stored_cells"])
            ),
        )


@dataclasses.dataclass(frozen=True)
class RangeCertificate:
    """The certification pass's output: one entry per spmm layer
    (convs in schedule order, then ``fc``), plus the declared input
    range it was derived from and whether every certified bound stays
    inside the fp32 range (``fp32_safe``)."""

    input_lo: float
    input_hi: float
    precision: str
    cell_bits: int
    fp32_safe: bool
    layers: tuple[LayerRanges, ...]

    def layer(self, name: str) -> LayerRanges | None:
        for entry in self.layers:
            if entry.name == name:
                return entry
        return None

    def certified_cells(self) -> dict[str, int]:
        """Per-layer certified cell counts (quantized layers only)."""
        return {
            entry.name: entry.certified_cells
            for entry in self.layers
            if entry.certified_cells is not None
        }

    def to_manifest(self) -> dict:
        return {
            "input_lo": self.input_lo,
            "input_hi": self.input_hi,
            "precision": self.precision,
            "cell_bits": self.cell_bits,
            "fp32_safe": self.fp32_safe,
            "layers": [entry.to_manifest() for entry in self.layers],
        }

    @classmethod
    def from_manifest(cls, entry: dict) -> "RangeCertificate":
        return cls(
            input_lo=float(entry["input_lo"]),
            input_hi=float(entry["input_hi"]),
            precision=str(entry["precision"]),
            cell_bits=int(entry["cell_bits"]),
            fp32_safe=bool(entry["fp32_safe"]),
            layers=tuple(
                LayerRanges.from_manifest(e) for e in entry["layers"]
            ),
        )


def _effective_columns(bp) -> tuple[np.ndarray, np.ndarray]:
    """Per-output-column positive/negative sums of the effective weights.

    Returns ``(pos, neg)`` of length ``n_out`` in *original* column
    order: ``pos_j = sum(max(w_kj, 0))`` over all stored rows feeding
    column ``j`` (padded bricks are all-zero and contribute nothing;
    duplicate block ids sum exactly as the executor's scan does).
    """
    wc = np.asarray(bp.w_comp, np.float64)
    s = None
    if bp.w_scales is not None:
        s = np.asarray(bp.w_scales, np.float64)
        if s.size and s.min() < 0.0:
            # negative scales flip brick signs: clip after scaling.  The
            # factored fast path below is only sound for s >= 0, where
            # clip and the per-brick scale multiply commute.
            wc = wc * s[:, :, None, None]
            s = None
    pos = np.clip(wc, 0.0, None).sum(axis=2)  # [T, k_max, tile]
    neg = np.clip(wc, None, 0.0).sum(axis=2)
    if s is not None:
        pos *= s[:, :, None]
        neg *= s[:, :, None]
    pos = pos.sum(axis=1).reshape(-1)[: bp.n_out]
    neg = neg.sum(axis=1).reshape(-1)[: bp.n_out]
    new_order = np.asarray(bp.new_order)
    pos_orig = np.empty(bp.n_out)
    neg_orig = np.empty(bp.n_out)
    pos_orig[new_order] = pos
    neg_orig[new_order] = neg
    return pos_orig, neg_orig


def _spmm_bounds(
    bp, bias, n_valid: int, lo: float, hi: float
) -> tuple[float, float]:
    """Exact interval image of ``x @ W + b`` for ``x`` entries in
    ``[lo, hi]``, over the first ``n_valid`` (unpadded) columns."""
    pos, neg = _effective_columns(bp)
    pos, neg = pos[:n_valid], neg[:n_valid]
    b = np.asarray(bias, np.float64)
    out_hi = b + hi * pos + lo * neg
    out_lo = b + lo * pos + hi * neg
    if out_hi.size == 0:
        return 0.0, 0.0
    return float(out_lo.min()), float(out_hi.max())


def _quantized_interval(lo: float, hi: float) -> tuple[float, float]:
    """Widen an activation interval by the per-row int8 round-off: the
    executor's dynamic quantization introduces at most half a step,
    ``amax / (2 * QMAX)``, of error per element."""
    amax = max(abs(lo), abs(hi))
    pad = amax / (2.0 * QMAX)
    return lo - pad, hi + pad


def _analyze_operand(
    bp,
    name: str,
    cell_bits: int,
    r: Report,
) -> dict:
    """Quantized-operand facts: accumulator extrema, scale health
    (V501/V502/V503), the certified min-cells table, V505."""
    if bp.w_scales is None:
        return {}
    q = np.asarray(bp.w_comp, np.int64)
    s = np.asarray(bp.w_scales, np.float64)
    n_tiles, k_max = q.shape[0], q.shape[1]
    slot = np.arange(k_max)[None, :]
    active = slot < np.clip(np.asarray(bp.nnz), 0, k_max)[:, None]

    # V502 first: scale pathologies poison everything derived below
    s_act = s[active]
    finite = bool(np.isfinite(s).all())
    n_sat = int(np.count_nonzero(s_act * QMAX > _F32_MAX)) if finite else 0
    if not finite or n_sat:
        detail = (
            "non-finite scales" if not finite
            else f"{n_sat} scale(s) saturate fp32 (s * {QMAX} overflows)"
        )
        r.add(
            "V502",
            f"scale saturation: {detail} — dequantized weights are not "
            "representable",
            layer=name, location="w_scales",
        )
    n_den = int(np.count_nonzero((s_act > 0) & (s_act < _F32_TINY)))
    if n_den:
        r.add(
            "V502",
            f"{n_den} denormal scale(s) below the smallest normal fp32 "
            f"({_F32_TINY:.3e}): dequantization silently flushes the "
            "whole row-group toward zero",
            layer=name, location="w_scales",
        )

    # V503: a zero scale over a nonzero brick kills the row-group
    dead = active & (s == 0) & np.any(q != 0, axis=(2, 3))
    if np.any(dead):
        t, k = np.argwhere(dead)[0]
        r.add(
            "V503",
            f"{int(np.count_nonzero(dead))} dead-scale group(s): active "
            f"brick(s) with scale 0 over nonzero weights dequantize to "
            f"zero (first at tile {t}, slot {k})",
            severity=WARNING, layer=name, location=f"w_scales[{t},{k}]",
        )

    # accumulator extrema, activation independent (|qx| <= QMAX always):
    # int32 partial contracts one brick's block rows; the fp32
    # accumulator folds per-brick scales across a tile's slots
    aq = np.abs(q)
    colsum = aq.sum(axis=2)  # [T, k_max, tile]
    acc32 = int(QMAX * colsum.max()) if colsum.size else 0
    if acc32 >= _INT32_LIMIT:
        r.add(
            "V501",
            f"int32 accumulator overflow not provably absent: worst-case "
            f"partial magnitude {acc32} >= 2**31",
            layer=name, location="w_comp",
        )
    if finite:
        accf = (s[:, :, None] * (QMAX * colsum.astype(np.float64)))
        accf = float(accf.sum(axis=1).max()) if accf.size else 0.0
    else:
        accf = float("nan")
    if not np.isfinite(accf) or accf > _F32_MAX:
        r.add(
            "V501",
            f"fp32 accumulator overflow not provably absent: worst-case "
            f"scale-folded magnitude {accf!r} exceeds the fp32 range",
            layer=name, location="w_scales",
        )

    # certified min-cells table on the operand-uniform reference grid
    stored = n_cell_slices(cell_bits)
    qmax_brick = aq.max(axis=(2, 3)) if q.size else np.zeros(
        (n_tiles, k_max), np.int64
    )
    s_ref = float(s_act.max()) if s_act.size and finite else 0.0
    if s_ref > 0:
        m = np.clip(
            np.rint(qmax_brick * (s / s_ref)).astype(np.int64), 0, QMAX
        )
        cells = cells_for_magnitude(m, cell_bits)
    else:
        cells = np.zeros((n_tiles, k_max), np.int64)
    certified = int(cells.max()) if cells.size else 0
    if 0 < certified < stored:
        r.add(
            "V505",
            f"top {stored - certified} of {stored} cell slice(s) are "
            f"provably zero operand-wide: every row-group fits "
            f"{certified} cell(s) on the layer's reference grid",
            severity=WARNING, layer=name, location="w_comp",
        )
    return {
        "acc_int32_max": acc32,
        "acc_fp32_max": accf,
        "min_cells": tuple(tuple(int(c) for c in row) for row in cells),
        "certified_cells": certified,
        "stored_cells": stored,
    }


def analyze_network(
    program,
    input_range: tuple[float, float] = DEFAULT_INPUT_RANGE,
    report: Report | None = None,
) -> tuple[Report, RangeCertificate]:
    """Run the range certification pass over a compiled program.

    Returns ``(report, certificate)``: V5xx diagnostics accumulated into
    ``report`` (created when ``None``) and the
    :class:`RangeCertificate`.  Pure and execution free — only numpy
    reductions over the stored payloads, so the certificate is
    bit-deterministic across processes.
    """
    r = report if report is not None else Report()
    lo, hi = float(input_range[0]), float(input_range[1])
    if not (np.isfinite(lo) and np.isfinite(hi)) or lo > hi:
        raise ValueError(f"input_range must be a finite [lo, hi], got "
                         f"{input_range!r}")

    quantized = program.precision == "int8"
    layers: list[LayerRanges] = []
    fp32_safe = True
    diverged = False
    fp32_edge: str | None = None

    for conv in program.convs:
        # 'same' conv padding inserts zeros into the patches, so the
        # spmm input interval always contains 0
        in_lo, in_hi = min(lo, 0.0), max(hi, 0.0)
        if conv.bp.w_scales is not None:
            in_lo, in_hi = _quantized_interval(in_lo, in_hi)
        pre_lo, pre_hi = _spmm_bounds(
            conv.bp, conv.bias, conv.c_out, in_lo, in_hi
        )
        # channel_norm (divisor in [eps, inf)) then ReLU; maxpool is the
        # identity on intervals
        act_lo = max(min(pre_lo, 0.0) / NORM_EPS, 0.0)
        act_hi = max(max(pre_hi, 0.0) / NORM_EPS, 0.0)
        facts = _analyze_operand(conv.bp, conv.name, program.cell_bits, r) \
            if quantized else {}
        layers.append(LayerRanges(
            name=conv.name, pre_lo=pre_lo, pre_hi=pre_hi,
            act_lo=act_lo, act_hi=act_hi, **facts,
        ))
        bounds = (pre_lo, pre_hi, act_lo, act_hi)
        if not all(np.isfinite(b) for b in bounds):
            if not diverged:
                r.add(
                    "V504",
                    "activation-range divergence: certified bounds are "
                    "non-finite from this layer on",
                    layer=conv.name, location="bounds",
                )
            diverged = True
            fp32_safe = False
        elif fp32_safe and max(abs(b) for b in bounds) > _F32_MAX:
            fp32_safe = False
            fp32_edge = conv.name
        lo, hi = act_lo, act_hi

    # global average pool preserves the interval; the FC head is a plain
    # spmm + bias (its pre and act bounds coincide — the logits)
    fc_lo, fc_hi = (lo, hi)
    if program.fc.bp.w_scales is not None:
        fc_lo, fc_hi = _quantized_interval(fc_lo, fc_hi)
    pre_lo, pre_hi = _spmm_bounds(
        program.fc.bp, program.fc.bias, program.fc.d_out, fc_lo, fc_hi
    )
    facts = _analyze_operand(program.fc.bp, "fc", program.cell_bits, r) \
        if quantized else {}
    layers.append(LayerRanges(
        name="fc", pre_lo=pre_lo, pre_hi=pre_hi,
        act_lo=pre_lo, act_hi=pre_hi, **facts,
    ))
    if not (np.isfinite(pre_lo) and np.isfinite(pre_hi)):
        if not diverged:
            r.add(
                "V504",
                "activation-range divergence: certified logit bounds are "
                "non-finite",
                layer="fc", location="bounds",
            )
        diverged = True
        fp32_safe = False
    elif fp32_safe and max(abs(pre_lo), abs(pre_hi)) > _F32_MAX:
        fp32_safe = False
        fp32_edge = "fc"

    if fp32_edge is not None and not diverged:
        r.add(
            "V504",
            f"certified activation bounds exceed the fp32 range from "
            f"layer {fp32_edge} on under worst-case normalisation "
            f"(fp32_safe=False); bounds stay finite in the certificate's "
            "float64 domain",
            severity=WARNING, layer=fp32_edge, location="bounds",
        )

    cert = RangeCertificate(
        input_lo=float(input_range[0]),
        input_hi=float(input_range[1]),
        precision=program.precision,
        cell_bits=program.cell_bits,
        fp32_safe=fp32_safe,
        layers=tuple(layers),
    )
    return r, cert


def analyze_saved(
    directory: str,
    input_range: tuple[float, float] | None = None,
) -> tuple[Report, RangeCertificate | None]:
    """Certify a serialized program directory.

    Manifest statics (M0xx) and the full structural verifier run first —
    range analysis of a structurally broken program proves nothing — and
    the interpreter only runs when they pass.  With ``input_range=None``
    the stored certificate's declared range (manifest v4) is reused, so
    re-certification answers "does the artifact still support its own
    claim"; a stored certificate that disagrees with recomputation is
    V506.  Returns ``(report, certificate)`` (``None`` certificate when
    analysis could not run).
    """
    from repro.analysis.verify import verify_manifest, verify_network
    from repro.engine import serialize

    r = verify_manifest(directory)
    if not r.ok:
        return r, None
    try:
        program = serialize.load_program(directory, verify=False)
    except ProgramFormatError as e:
        r.add(getattr(e, "rule", "M005"), str(e), location=directory)
        return r, None
    verify_network(program, report=r)
    if not r.ok:
        return r, None

    stored = getattr(program, "certificate", None)
    rng = input_range
    if rng is None:
        rng = (
            (stored.input_lo, stored.input_hi)
            if stored is not None else DEFAULT_INPUT_RANGE
        )
    r, cert = analyze_network(program, input_range=rng, report=r)

    if stored is not None:
        stored_range = (stored.input_lo, stored.input_hi)
        if stored_range == (cert.input_lo, cert.input_hi):
            recomputed = cert
        else:
            _, recomputed = analyze_network(
                program, input_range=stored_range, report=Report()
            )
        if stored.to_manifest() != recomputed.to_manifest():
            r.add(
                "V506",
                "stored range certificate disagrees with recomputation "
                "from the payloads (stale or corrupted manifest entry)",
                location="certificate", severity=ERROR,
            )
    return r, cert
