"""AST-based trace-safety lint for ``src/repro/``.

Repo-specific rules ruff cannot express, keeping the PR-6 contracts —
"zero overhead when tracing is off" and jit-purity of the forward path —
honest as the codebase grows:

=====  =================================================================
rule   contract
=====  =================================================================
L001   no wall-clock (``time.time``/``perf_counter``/``monotonic``,
       ``datetime.now``) or ``np.random`` *calls* inside functions
       reachable from a jitted/``shard_map``/``pallas_call`` entry point
       — impure host calls run once at trace time and silently freeze
L002   every public API taking ``tracer=`` must default to ``None`` or
       ``NULL_TRACER`` (tracing is strictly opt-in); ``repro/obs/``
       itself is exempt — its plumbing takes tracers positionally
L003   no mutable default arguments (literals, ``list``/``dict``/``set``
       constructors, or repo dataclasses not declared ``frozen=True``)
L004   timing code must synchronize before reading the clock: a function
       that reads the clock twice and launches jax work in between must
       call ``block_until_ready``/``device_get``, else it times dispatch
       instead of execution
L005   no new internal imports of the deprecated serving request types
       (``repro.engine.service.ClassifyRequest``,
       ``repro.runtime.serve.Request``) — internal code uses the unified
       ``repro.serve.Request``; the shims exist only for external
       callers during the deprecation window
L006   lock discipline: in a class that holds a ``threading.Lock`` /
       ``RLock`` attribute, every method that mutates shared instance
       state (attributes assigned in ``__init__``) must do so inside a
       ``with self.<lock>`` block — an unlocked write to state the lock
       exists to protect is a data race by construction.  Assignments in
       ``__init__`` (pre-publication) and in nested ``def``s (unknown
       calling context) are exempt
=====  =================================================================

Reachability for L001 is a best-effort static call graph: functions
passed (by name, factory call, or decorator) to ``jax.jit``,
``shard_map``, or ``pl.pallas_call`` seed a BFS over same-module calls,
``from``-imports, module-attribute calls, ``self.`` method-name matches,
and nested ``def``s of reachable functions (traced closures).

Suppress a finding with an inline ``# lint: allow(L004)`` comment on the
offending line or on the enclosing ``def`` line; use sparingly and only
with a neighbouring justification.

Run as ``python -m repro.analysis lint [paths]``; CI enforces exit 0.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re

from repro.analysis.diagnostics import Report

__all__ = ["lint_paths", "lint_file"]

_CLOCK_CHAINS = {
    ("time", "time"),
    ("time", "perf_counter"),
    ("time", "monotonic"),
    ("time", "process_time"),
    ("datetime", "now"),
    ("datetime", "utcnow"),
    ("datetime", "datetime", "now"),
    ("datetime", "datetime", "utcnow"),
}
_JIT_SEEDS = {"jit", "pallas_call", "shard_map"}
_SYNC_NAMES = {"block_until_ready", "device_get"}
_MUTABLE_CTORS = {"list", "dict", "set", "bytearray", "defaultdict", "deque"}
_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([A-Z0-9,\s]+)\)")


def _dotted(node: ast.AST) -> tuple[str, ...] | None:
    """``a.b.c`` -> ("a", "b", "c"); None for non-name chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


@dataclasses.dataclass
class _Func:
    key: str  # "module::qualname"
    module: str
    qualname: str
    node: ast.FunctionDef | ast.AsyncFunctionDef
    path: str
    callees: set[str] = dataclasses.field(default_factory=set)
    children: set[str] = dataclasses.field(default_factory=set)
    clock_calls: list[tuple[int, str]] = dataclasses.field(default_factory=list)
    nprandom_calls: list[tuple[int, str]] = dataclasses.field(default_factory=list)
    jax_linenos: list[int] = dataclasses.field(default_factory=list)
    synchronizes: bool = False

    @property
    def jax_rooted(self) -> bool:
        return bool(self.jax_linenos)


@dataclasses.dataclass
class _Module:
    name: str
    path: str
    tree: ast.Module
    lines: list[str]
    # local alias -> dotted module it names ("np" -> "numpy")
    mod_aliases: dict = dataclasses.field(default_factory=dict)
    # from-imported name -> (source module, original name)
    from_imports: dict = dataclasses.field(default_factory=dict)
    funcs: dict = dataclasses.field(default_factory=dict)  # qualname -> _Func
    by_bare: dict = dataclasses.field(default_factory=dict)  # name -> [qualname]
    frozen_classes: set = dataclasses.field(default_factory=set)
    nonfrozen_dataclasses: set = dataclasses.field(default_factory=set)


def _module_name(path: str) -> str:
    """Dotted module name, anchored at the ``repro`` package when present."""
    parts = os.path.normpath(os.path.abspath(path))[:-3].split(os.sep)
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    return ".".join(p for p in parts if p)


def _dataclass_frozen(dec: ast.AST) -> bool | None:
    """True/False when *dec* is a dataclass decorator, None otherwise."""
    chain = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
    if not chain or chain[-1] != "dataclass":
        return None
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
    return False


class _Collector(ast.NodeVisitor):
    """One pass per module: functions, imports, classes, call metadata."""

    def __init__(self, mod: _Module):
        self.mod = mod
        self.stack: list[str] = []  # enclosing class/function names
        self.fstack: list[_Func] = []  # enclosing _Func entries only

    # -- imports ----------------------------------------------------
    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.mod.mod_aliases[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0]
            )
            if a.asname:
                self.mod.mod_aliases[a.asname] = a.name

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module:
            for a in node.names:
                self.mod.from_imports[a.asname or a.name] = (
                    node.module, a.name
                )

    # -- classes ----------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef):
        frozen = None
        for dec in node.decorator_list:
            got = _dataclass_frozen(dec)
            if got is not None:
                frozen = got
        if frozen is True:
            self.mod.frozen_classes.add(node.name)
        elif frozen is False:
            self.mod.nonfrozen_dataclasses.add(node.name)
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    # -- functions --------------------------------------------------
    def _visit_func(self, node):
        qualname = ".".join(self.stack + [node.name])
        f = _Func(
            key=f"{self.mod.name}::{qualname}",
            module=self.mod.name,
            qualname=qualname,
            node=node,
            path=self.mod.path,
        )
        if self.fstack:
            self.fstack[-1].children.add(f.key)
        self.mod.funcs[qualname] = f
        self.mod.by_bare.setdefault(node.name, []).append(qualname)
        self.stack.append(node.name)
        self.fstack.append(f)
        self.generic_visit(node)
        self.fstack.pop()
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    # -- calls ------------------------------------------------------
    def visit_Call(self, node: ast.Call):
        if self.fstack:
            f = self.fstack[-1]
            chain = _dotted(node.func)
            if chain:
                self._classify(f, node, chain)
        self.generic_visit(node)

    def _classify(self, f: _Func, node: ast.Call, chain: tuple[str, ...]):
        root = chain[0]
        rooted = self.mod.mod_aliases.get(root, root)
        dotted = ".".join(chain)
        if chain in _CLOCK_CHAINS and (
            rooted.split(".")[0] in ("time", "datetime")
            or self.mod.from_imports.get(root, ("", ""))[1] == "datetime"
        ):
            f.clock_calls.append((node.lineno, dotted))
        if (
            len(chain) >= 2
            and rooted.split(".")[0] == "numpy"
            and chain[1] == "random"
        ) or rooted == "numpy.random":
            f.nprandom_calls.append((node.lineno, dotted))
        if rooted.split(".")[0] == "jax":
            f.jax_linenos.append(node.lineno)
        if chain[-1] in _SYNC_NAMES:
            f.synchronizes = True


def _parse(paths: list[str]) -> list[_Module]:
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                files.extend(
                    os.path.join(root, n) for n in sorted(names)
                    if n.endswith(".py")
                )
        elif p.endswith(".py"):
            files.append(p)
    mods = []
    for path in sorted(set(files)):
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue  # ruff owns syntax errors
        mod = _Module(
            name=_module_name(path), path=path, tree=tree,
            lines=src.splitlines(),
        )
        _Collector(mod).visit(tree)
        mods.append(mod)
    return mods


# ---------------------------------------------------------------------------
# call-graph resolution + jit reachability
# ---------------------------------------------------------------------------


def _resolve_name(mods_by_name, mod: _Module, name: str) -> str | None:
    if name in mod.by_bare:
        return f"{mod.name}::{mod.by_bare[name][-1]}"
    if name in mod.from_imports:
        src_mod, orig = mod.from_imports[name]
        target = mods_by_name.get(src_mod) or mods_by_name.get(
            "repro." + src_mod.lstrip(".")
        )
        if target and orig in target.by_bare:
            return f"{target.name}::{target.by_bare[orig][-1]}"
    return None


def _resolve_call(mods_by_name, mod: _Module, chain: tuple[str, ...]):
    if len(chain) == 1:
        return _resolve_name(mods_by_name, mod, chain[0])
    if chain[0] == "self" and len(chain) == 2:
        if chain[1] in mod.by_bare:
            return f"{mod.name}::{mod.by_bare[chain[1]][-1]}"
        return None
    target_mod = mods_by_name.get(mod.mod_aliases.get(chain[0], ""))
    if target_mod and chain[-1] in target_mod.by_bare:
        return f"{target_mod.name}::{target_mod.by_bare[chain[-1]][-1]}"
    return None


def _seed_arg(mods_by_name, mod: _Module, arg: ast.AST, seeds: set[str]):
    """Mark the function a jit/shard_map/pallas_call argument names."""
    if isinstance(arg, ast.Name):
        key = _resolve_name(mods_by_name, mod, arg.id)
        if key:
            seeds.add(key)
    elif isinstance(arg, ast.Call):
        chain = _dotted(arg.func)
        if chain:  # factory: jax.jit(make_step(...)) traces the closure
            key = _resolve_call(mods_by_name, mod, chain)
            if key:
                seeds.add(key)
    elif isinstance(arg, ast.Lambda):
        for sub in ast.walk(arg.body):
            if isinstance(sub, ast.Call):
                chain = _dotted(sub.func)
                if chain:
                    key = _resolve_call(mods_by_name, mod, chain)
                    if key:
                        seeds.add(key)


def _collect_seeds(mods: list[_Module], mods_by_name) -> set[str]:
    seeds: set[str] = set()
    for mod in mods:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                chain = _dotted(node.func) or ()
                name = chain[-1] if chain else ""
                if name in _JIT_SEEDS and node.args:
                    _seed_arg(mods_by_name, mod, node.args[0], seeds)
                elif name == "partial" and node.args:
                    inner = _dotted(node.args[0]) or ()
                    if inner and inner[-1] in _JIT_SEEDS and len(node.args) > 1:
                        _seed_arg(mods_by_name, mod, node.args[1], seeds)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    chain = _dotted(
                        dec.func if isinstance(dec, ast.Call) else dec
                    ) or ()
                    inner = ()
                    if (
                        isinstance(dec, ast.Call)
                        and chain
                        and chain[-1] == "partial"
                        and dec.args
                    ):
                        inner = _dotted(dec.args[0]) or ()
                    if (chain and chain[-1] in _JIT_SEEDS) or (
                        inner and inner[-1] in _JIT_SEEDS
                    ):
                        for q, f in mod.funcs.items():
                            if f.node is node:
                                seeds.add(f.key)
    return seeds


def _reachable(mods: list[_Module], mods_by_name, seeds: set[str]) -> set[str]:
    funcs = {f.key: (mod, f) for mod in mods for f in mod.funcs.values()}
    for mod, f in funcs.values():
        for node in ast.walk(f.node):
            if isinstance(node, ast.Call):
                chain = _dotted(node.func)
                if chain:
                    key = _resolve_call(mods_by_name, mod, chain)
                    if key:
                        f.callees.add(key)
    seen = set()
    frontier = [k for k in seeds if k in funcs]
    while frontier:
        key = frontier.pop()
        if key in seen:
            continue
        seen.add(key)
        _mod, f = funcs[key]
        # traced closures: nested defs of a reachable factory are traced
        frontier.extend(f.children - seen)
        frontier.extend(f.callees - seen)
    return seen


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


def _allowed(mod: _Module, rule: str, *linenos: int) -> bool:
    for ln in linenos:
        if 1 <= ln <= len(mod.lines):
            m = _ALLOW_RE.search(mod.lines[ln - 1])
            if m and rule in {s.strip() for s in m.group(1).split(",")}:
                return True
    return False


def _loc(mod: _Module, lineno: int) -> str:
    return f"{os.path.relpath(mod.path)}:{lineno}"


def _rule_l001(r: Report, mod: _Module, f: _Func):
    for lineno, what in f.clock_calls + f.nprandom_calls:
        if _allowed(mod, "L001", lineno, f.node.lineno):
            continue
        r.add(
            "L001",
            f"impure host call {what}() inside jit-reachable "
            f"{f.qualname}(): it runs once at trace time and freezes",
            layer=f.module, location=_loc(mod, lineno),
        )


def _rule_l002(r: Report, mod: _Module, f: _Func, in_obs: bool):
    if in_obs or f.node.name.startswith("_"):
        return
    args = f.node.args
    named = args.posonlyargs + args.args + args.kwonlyargs
    defaults = dict(
        zip([a.arg for a in reversed(args.posonlyargs + args.args)],
            list(reversed(args.defaults)))
    )
    defaults.update(
        (a.arg, d)
        for a, d in zip(args.kwonlyargs, args.kw_defaults)
        if d is not None
    )
    for a in named:
        if a.arg != "tracer":
            continue
        d = defaults.get(a.arg)
        ok = (
            isinstance(d, ast.Constant) and d.value is None
        ) or (isinstance(d, ast.Name) and d.id == "NULL_TRACER") or (
            isinstance(d, ast.Attribute) and d.attr == "NULL_TRACER"
        )
        if not ok and not _allowed(mod, "L002", f.node.lineno):
            r.add(
                "L002",
                f"public API {f.qualname}() takes tracer= without a "
                "None/NULL_TRACER default — tracing must be opt-in",
                layer=f.module, location=_loc(mod, f.node.lineno),
            )


def _mutable_default(d: ast.AST, nonfrozen: set[str], frozen: set[str]):
    if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                      ast.DictComp, ast.SetComp)):
        return "mutable literal"
    if isinstance(d, ast.Call):
        chain = _dotted(d.func) or ()
        name = chain[-1] if chain else ""
        if name in _MUTABLE_CTORS:
            return f"{name}() constructor"
        if name in nonfrozen and name not in frozen:
            return f"non-frozen dataclass {name}()"
    return None


def _rule_l003(r: Report, mod: _Module, f: _Func, nonfrozen, frozen):
    args = f.node.args
    for d in list(args.defaults) + [d for d in args.kw_defaults if d]:
        why = _mutable_default(d, nonfrozen, frozen)
        if why and not _allowed(mod, "L003", d.lineno, f.node.lineno):
            r.add(
                "L003",
                f"mutable default argument ({why}) in {f.qualname}(): "
                "shared across calls",
                layer=f.module, location=_loc(mod, d.lineno),
            )


def _rule_l004(r: Report, mod: _Module, f: _Func):
    if len(f.clock_calls) < 2 or f.synchronizes:
        return
    lo = min(ln for ln, _ in f.clock_calls)
    hi = max(ln for ln, _ in f.clock_calls)
    # only jax work *between* the clock reads is being (mis)timed
    timed = [ln for ln in f.jax_linenos if lo < ln < hi]
    if not timed or _allowed(mod, "L004", lo, f.node.lineno):
        return
    r.add(
        "L004",
        f"{f.qualname}() launches jax work (line {timed[0]}) between clock "
        "reads without block_until_ready/device_get — it times async "
        "dispatch, not execution",
        layer=f.module, location=_loc(mod, lo),
    )


# deprecated name -> the modules it must no longer be imported from
_DEPRECATED_IMPORTS = {
    ("repro.engine.service", "ClassifyRequest"),
    ("engine.service", "ClassifyRequest"),
    ("repro.engine", "ClassifyRequest"),
    ("repro.runtime.serve", "Request"),
    ("runtime.serve", "Request"),
}


def _rule_l005(r: Report, mod: _Module):
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ImportFrom) or not node.module:
            continue
        for a in node.names:
            if (node.module, a.name) not in _DEPRECATED_IMPORTS:
                continue
            if _allowed(mod, "L005", node.lineno):
                continue
            r.add(
                "L005",
                f"import of deprecated {node.module}.{a.name} — use "
                "repro.serve.Request (the shim is for external callers "
                "only)",
                layer=mod.name, location=_loc(mod, node.lineno),
            )


_LOCK_CTORS = {"Lock", "RLock"}


def _self_attr(node: ast.AST) -> str | None:
    """``self.X`` or ``self.X[...]`` -> ``"X"``; anything else -> None."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _is_lock_ctor(mod: _Module, value: ast.AST) -> bool:
    if not isinstance(value, ast.Call):
        return False
    chain = _dotted(value.func) or ()
    if not chain or chain[-1] not in _LOCK_CTORS:
        return False
    root = mod.mod_aliases.get(chain[0], chain[0])
    if root.split(".")[0] == "threading":
        return True
    # `from threading import Lock` / `RLock`
    src, orig = mod.from_imports.get(chain[0], ("", ""))
    return len(chain) == 1 and src.split(".")[-1] == "threading" and (
        orig in _LOCK_CTORS
    )


class _LockScan(ast.NodeVisitor):
    """Record ``self.<shared>`` mutations made outside ``with self.<lock>``.

    Nested ``def``/``lambda`` bodies are skipped entirely: a closure
    defined under a lock may run after it is released (and vice versa),
    so neither flagging nor excusing it is sound.
    """

    def __init__(self, lock_attrs: set, shared: set):
        self.lock_attrs = lock_attrs
        self.shared = shared
        self.depth = 0  # nesting level of with-self.<lock> blocks
        self.offences: list[tuple[int, str]] = []

    def _record(self, target: ast.AST, lineno: int):
        attr = _self_attr(target)
        if attr in self.shared and self.depth == 0:
            self.offences.append((lineno, attr))

    def _visit_with(self, node):
        locked = any(
            _self_attr(item.context_expr) in self.lock_attrs
            for item in node.items
        )
        self.depth += locked
        self.generic_visit(node)
        self.depth -= locked

    visit_With = _visit_with
    visit_AsyncWith = _visit_with

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            self._record(t, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._record(node.target, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            self._record(node.target, node.lineno)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete):
        for t in node.targets:
            self._record(t, node.lineno)
        self.generic_visit(node)

    def visit_FunctionDef(self, node):  # skip nested defs
        pass

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef


def _rule_l006(r: Report, mod: _Module):
    for cls in ast.walk(mod.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = [
            n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        lock_attrs = {
            attr
            for m in methods
            for node in ast.walk(m)
            if isinstance(node, ast.Assign) and _is_lock_ctor(mod, node.value)
            for attr in map(_self_attr, node.targets)
            if attr
        }
        if not lock_attrs:
            continue
        shared: set[str] = set()
        for m in methods:
            if m.name != "__init__":
                continue
            for node in ast.walk(m):
                targets = (
                    node.targets if isinstance(node, ast.Assign)
                    else [node.target] if isinstance(node, ast.AnnAssign)
                    else []
                )
                for t in targets:
                    # plain `self.x = ...` only — subscripts in __init__
                    # are construction detail, not attribute declaration
                    if isinstance(t, ast.Attribute):
                        attr = _self_attr(t)
                        if attr:
                            shared.add(attr)
        shared -= lock_attrs
        if not shared:
            continue
        locks = "/".join(f"self.{a}" for a in sorted(lock_attrs))
        for m in methods:
            if m.name == "__init__":
                continue
            scan = _LockScan(lock_attrs, shared)
            for stmt in m.body:
                scan.visit(stmt)
            for lineno, attr in scan.offences:
                if _allowed(mod, "L006", lineno, m.lineno):
                    continue
                r.add(
                    "L006",
                    f"{cls.name}.{m.name}() mutates self.{attr} outside a "
                    f"`with {locks}` block — shared state in a "
                    "lock-holding class must be mutated under the lock",
                    layer=mod.name, location=_loc(mod, lineno),
                )


def lint_paths(paths: list[str]) -> Report:
    """Lint *paths* (files or directories) and return a Report."""
    mods = _parse(paths)
    mods_by_name = {m.name: m for m in mods}
    # short-name aliases so `from repro.engine import lowering`-style and
    # relative imports both resolve
    for m in mods:
        for k in (m.name.removeprefix("repro."), m.name.split(".")[-1]):
            mods_by_name.setdefault(k, m)
    frozen = {c for m in mods for c in m.frozen_classes}
    nonfrozen = {c for m in mods for c in m.nonfrozen_dataclasses}
    reachable = _reachable(mods, mods_by_name, _collect_seeds(mods, mods_by_name))

    r = Report()
    for mod in mods:
        in_obs = f"{os.sep}obs{os.sep}" in mod.path or mod.name.startswith(
            "repro.obs"
        )
        _rule_l005(r, mod)
        _rule_l006(r, mod)
        for f in mod.funcs.values():
            if f.key in reachable:
                _rule_l001(r, mod, f)
            _rule_l002(r, mod, f, in_obs)
            _rule_l003(r, mod, f, nonfrozen, frozen)
            _rule_l004(r, mod, f)
    return r


def lint_file(path: str) -> Report:
    return lint_paths([path])
