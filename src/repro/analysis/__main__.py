"""CLI for the static verifier and trace-safety lint.

``python -m repro.analysis verify <dir>`` exits 0 when the saved program
has no error diagnostics (warnings print but do not fail); ``--json``
emits the machine-readable report instead of text.

``python -m repro.analysis lint [paths...]`` (default ``src/repro``)
exits 0 only when the tree is completely clean — CI treats lint
warnings as failures too, since every rule here guards a correctness
contract.
"""

from __future__ import annotations

import argparse
import sys


def _emit(report, as_json: bool) -> None:
    print(report.dumps() if as_json else report.format())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)

    v = sub.add_parser("verify", help="verify a saved program directory")
    v.add_argument("directory")
    v.add_argument("--json", action="store_true")

    li = sub.add_parser("lint", help="trace-safety lint over source trees")
    li.add_argument("paths", nargs="*", default=["src/repro"])
    li.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    if args.cmd == "verify":
        from repro.analysis.verify import verify_saved

        report = verify_saved(args.directory)
        _emit(report, args.json)
        return 0 if report.ok else 1

    from repro.analysis.lint import lint_paths

    report = lint_paths(args.paths or ["src/repro"])
    _emit(report, args.json)
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
