"""CLI for the static verifier, range certification, and lint.

``python -m repro.analysis verify <dir>`` exits 0 when the saved program
has no error diagnostics (warnings print but do not fail); ``--json``
emits the machine-readable report instead of text.

``python -m repro.analysis ranges <dir>`` runs the range certification
pass (``repro.analysis.ranges``) over a saved program:  structural
verification first, then the abstract interpreter; exits 0 when no
error diagnostics exist.  ``--json`` emits ``{"report": ...,
"certificate": ...}``; ``--input-lo``/``--input-hi`` override the
declared input range (default: the stored certificate's own range, or
``DEFAULT_INPUT_RANGE``).

``python -m repro.analysis lint [paths...]`` (default ``src/repro``)
exits 0 only when the tree is completely clean — CI treats lint
warnings as failures too, since every rule here guards a correctness
contract.

``python -m repro.analysis all <dir> [--paths ...]`` runs verify + lint
+ ranges and emits one merged JSON report (always JSON; ``--json`` is
accepted for symmetry).

Exit codes:

=========  ============================================================
command    exit code
=========  ============================================================
verify     0 clean-of-errors; 1 error diagnostics
ranges     0 clean-of-errors; 1 error diagnostics
lint       0 completely clean; 1 any finding
all        bitmask of failure classes — 0 clean, ``+1`` verify errors,
           ``+2`` lint findings, ``+4`` ranges errors (so e.g. 5 means
           verify and ranges failed but lint was clean)
=========  ============================================================
"""

from __future__ import annotations

import argparse
import json
import sys

EXIT_VERIFY = 1
EXIT_LINT = 2
EXIT_RANGES = 4


def _emit(report, as_json: bool) -> None:
    print(report.dumps() if as_json else report.format())


def _ranges_json(report, cert) -> dict:
    return {
        "report": report.to_json(),
        "certificate": None if cert is None else cert.to_manifest(),
    }


def _parse_range(args):
    if (args.input_lo is None) != (args.input_hi is None):
        raise SystemExit("--input-lo and --input-hi must be given together")
    if args.input_lo is None:
        return None
    return (float(args.input_lo), float(args.input_hi))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)

    v = sub.add_parser("verify", help="verify a saved program directory")
    v.add_argument("directory")
    v.add_argument("--json", action="store_true")

    rg = sub.add_parser(
        "ranges", help="range-certify a saved program directory"
    )
    rg.add_argument("directory")
    rg.add_argument("--json", action="store_true")
    rg.add_argument("--input-lo", type=float, default=None)
    rg.add_argument("--input-hi", type=float, default=None)

    li = sub.add_parser("lint", help="trace-safety lint over source trees")
    li.add_argument("paths", nargs="*", default=["src/repro"])
    li.add_argument("--json", action="store_true")

    al = sub.add_parser(
        "all", help="verify + lint + ranges with one merged JSON report"
    )
    al.add_argument("directory")
    al.add_argument("--paths", nargs="*", default=["src/repro"])
    al.add_argument("--json", action="store_true")
    al.add_argument("--input-lo", type=float, default=None)
    al.add_argument("--input-hi", type=float, default=None)
    args = ap.parse_args(argv)

    if args.cmd == "verify":
        from repro.analysis.verify import verify_saved

        report = verify_saved(args.directory)
        _emit(report, args.json)
        return 0 if report.ok else EXIT_VERIFY

    if args.cmd == "ranges":
        from repro.analysis.ranges import analyze_saved

        report, cert = analyze_saved(
            args.directory, input_range=_parse_range(args)
        )
        if args.json:
            print(json.dumps(_ranges_json(report, cert), indent=2))
        else:
            print(report.format())
            if cert is not None:
                for entry in cert.layers:
                    cells = (
                        "" if entry.certified_cells is None
                        else f"  cells={entry.certified_cells}"
                        f"/{entry.stored_cells}"
                    )
                    print(
                        f"{entry.name}: act in "
                        f"[{entry.act_lo:.6g}, {entry.act_hi:.6g}]{cells}"
                    )
                print(f"fp32_safe={cert.fp32_safe}")
        return 0 if report.ok else 1

    if args.cmd == "lint":
        from repro.analysis.lint import lint_paths

        report = lint_paths(args.paths or ["src/repro"])
        _emit(report, args.json)
        return 0 if report.clean else 1

    # all: the three passes, one merged JSON document, a bitmask exit
    from repro.analysis.lint import lint_paths
    from repro.analysis.ranges import analyze_saved
    from repro.analysis.verify import verify_saved

    verify_report = verify_saved(args.directory)
    lint_report = lint_paths(args.paths or ["src/repro"])
    ranges_report, cert = analyze_saved(
        args.directory, input_range=_parse_range(args)
    )
    code = 0
    if not verify_report.ok:
        code |= EXIT_VERIFY
    if not lint_report.clean:
        code |= EXIT_LINT
    if not ranges_report.ok:
        code |= EXIT_RANGES
    print(json.dumps({
        "ok": code == 0,
        "exit_code": code,
        "verify": verify_report.to_json(),
        "lint": lint_report.to_json(),
        "ranges": _ranges_json(ranges_report, cert),
    }, indent=2))
    return code


if __name__ == "__main__":
    sys.exit(main())
