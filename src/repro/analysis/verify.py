"""Static program verifier for compiled crossbar programs.

A pure, execution-free pass over :class:`~repro.core.sparse.BlockPatternWeight`
operands, :class:`~repro.engine.program.CompiledNetwork` artifacts,
:class:`~repro.engine.partition.NetworkPartition` declarations, and
serialized program directories.  It enforces the structural invariants the
engine otherwise only establishes dynamically (by executing and comparing
against dense):

=====  ========================================================francke
rule   invariant
=====  =================================================================
V101   ``new_order``/``inv_order`` are bijections over ``[0, n_out)``
V102   the two permutations are mutual inverses
V103   geometry divisibility: ``k_in % block == 0``, ``n_out % tile == 0``,
       enough tiles to cover ``n_out``
V104   operand shapes: ``w_comp [T, k_max, block, tile]``,
       ``block_ids [T, k_max]``, ``nnz [T]``, integer index dtypes
V105   ``block_ids`` within ``[0, k_in // block)``
V106   pack density: ``0 <= nnz <= min(k_max, n_blocks)``; over-allocated
       brick slots (``k_max > max(nnz)``) are a warning
V107   padded brick slots and padded tiles are inert: zero bricks,
       ``block_ids == 0``, zero scales
V108   active ``block_ids`` strictly increasing per tile (canonical pack
       order; violations warn — execution is order-insensitive)
V109   ``dict_masks`` is ``[P, k_in // block]`` boolean
V110   ``w_scales`` shaped ``[T, k_max]`` float (quantized programs)
V111   scales finite and non-negative
V112   a zero scale must not silently drop a nonzero brick
V113   quantized payloads are int8 within ``[-QMAX, QMAX]``
V114   ``cell_slices`` recompose bit-exactly to the stored ``w_comp``
V115   fp32 payloads are finite
V201   ``pattern_bits`` shaped ``[c_out, c_in]``, integer
V202   pattern bitmasks lie within the ``kernel x kernel`` window
V203   layer-vs-operand geometry: ``bp.k_in``/``bp.n_out`` are exactly the
       padded matmul dims of the layer
V204   bias shape/finiteness
V205   mapping strategy tags are known (``block_order`` in
       ``BLOCK_ORDERS``, conv/fc ``reorder`` in ``REORDERS``) and the
       candidate's geometry fields are positive
V206   mapping geometry is consistent with the packed operands: the OU
       fits the crossbar, a weight's cell slices fit one row, the OU can
       hold the layer's tallest pattern block, and an int8 program's
       mapping stores the cell-slice count its payload actually occupies
V301   inter-layer shape chaining (channels, spatial dims, fc head)
V302   precision contract: ``precision``/``cell_bits`` agree with the
       stored payloads
V303   program block/tile geometry agrees with every operand
V401   partition shards are positive
V402   partition tiles disjointly cover the padded tile axis of every layer
V403   partition axes are distinct, non-empty names
M001   manifest present and parseable
M002   format version supported
M003   manifest keys/types complete
M004   referenced payload files exist
M005   payload arrays load and match the declared geometry
=====  =================================================================

Entry points:

* :func:`verify_bp` / :func:`verify_conv` / :func:`verify_network` — pure
  in-memory checks returning a :class:`~repro.analysis.diagnostics.Report`.
* :func:`verify_partition` — partition-vs-program tile cover.
* :func:`verify_manifest` / :func:`verify_saved` — serialized directories
  (static manifest checks first, payload checks only if those pass).

Trust-boundary wiring: ``compile_network(..., verify='strict')`` runs
:func:`verify_network` as a post-condition, ``load_program(verify=True)``
(the default) verifies untrusted files after loading, and
``partition_network`` validates its partition cover.  The ``python -m
repro.analysis verify <dir>`` CLI wraps :func:`verify_saved`.
"""

from __future__ import annotations

import numpy as np

from repro.analysis.diagnostics import (
    ERROR,
    WARNING,
    ProgramFormatError,
    Report,
)
from repro.core.mapping import BLOCK_ORDERS
from repro.core.patterns import ALL_ZERO, pattern_sizes
from repro.core.quantize import QMAX, cell_slices, compose_cell_slices
from repro.core.sparse import REORDERS, BlockPatternWeight

__all__ = [
    "verify_bp",
    "verify_conv",
    "verify_fc",
    "verify_network",
    "verify_partition",
    "verify_manifest",
    "verify_saved",
]


def _pad_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _is_permutation(order: np.ndarray, n: int) -> bool:
    return (
        order.ndim == 1
        and order.shape[0] == n
        and np.array_equal(np.sort(order), np.arange(n))
    )


def verify_bp(
    bp: BlockPatternWeight,
    layer: str | None = None,
    cell_bits: int = 4,
    report: Report | None = None,
) -> Report:
    """Verify one compressed operand's structural invariants (V1xx)."""
    r = report if report is not None else Report()
    w = np.asarray(bp.w_comp)
    ids = np.asarray(bp.block_ids)
    nnz = np.asarray(bp.nnz)
    new_order = np.asarray(bp.new_order)
    inv_order = np.asarray(bp.inv_order)

    # V104 first: the shape contract everything else indexes through
    shape_ok = True
    if w.ndim != 4:
        r.add("V104", f"w_comp must be rank 4, got shape {w.shape}",
              layer=layer, location="w_comp")
        return r  # nothing downstream is well-defined
    n_tiles, k_max, blk, tl = w.shape
    if (blk, tl) != (bp.block, bp.tile):
        shape_ok = False
        r.add(
            "V104",
            f"w_comp bricks are {blk}x{tl}, declared block/tile is "
            f"{bp.block}x{bp.tile}",
            layer=layer, location="w_comp",
        )
    if ids.shape != (n_tiles, k_max):
        shape_ok = False
        r.add(
            "V104",
            f"block_ids shape {ids.shape} != (n_tiles, k_max) = "
            f"{(n_tiles, k_max)}",
            layer=layer, location="block_ids",
        )
    if nnz.shape != (n_tiles,):
        shape_ok = False
        r.add(
            "V104",
            f"nnz shape {nnz.shape} != (n_tiles,) = {(n_tiles,)}",
            layer=layer, location="nnz",
        )
    for name, arr in (("block_ids", ids), ("nnz", nnz),
                      ("new_order", new_order), ("inv_order", inv_order)):
        if not np.issubdtype(arr.dtype, np.integer):
            r.add("V104", f"{name} must be an integer array, got {arr.dtype}",
                  layer=layer, location=name)
            shape_ok = False

    # V103 geometry divisibility
    if bp.block < 1 or bp.tile < 1 or bp.k_in < 1 or bp.n_out < 1:
        r.add("V103", f"non-positive geometry: k_in={bp.k_in} "
              f"n_out={bp.n_out} block={bp.block} tile={bp.tile}",
              layer=layer, location="geometry")
        return r
    if bp.k_in % bp.block:
        r.add("V103", f"k_in={bp.k_in} not divisible by block={bp.block}",
              layer=layer, location="k_in")
    if bp.n_out % bp.tile:
        r.add("V103", f"n_out={bp.n_out} not divisible by tile={bp.tile}",
              layer=layer, location="n_out")
    base_tiles = bp.n_out // bp.tile
    if n_tiles < base_tiles:
        r.add(
            "V103",
            f"{n_tiles} stored tiles cover only {n_tiles * bp.tile} of "
            f"{bp.n_out} output columns",
            layer=layer, location="n_tiles",
        )

    # V101/V102 permutations
    perm_ok = True
    for name, order in (("new_order", new_order), ("inv_order", inv_order)):
        if not _is_permutation(order, bp.n_out):
            perm_ok = False
            r.add(
                "V101",
                f"{name} is not a bijection over [0, {bp.n_out})",
                layer=layer, location=name,
            )
    if perm_ok and not np.array_equal(
        inv_order[new_order], np.arange(bp.n_out)
    ):
        r.add(
            "V102",
            "inv_order is not the inverse of new_order "
            "(inv_order[new_order] != identity)",
            layer=layer, location="inv_order",
        )

    if not shape_ok or bp.k_in % bp.block:
        return r  # index checks below assume the shape contract

    n_blocks = bp.k_in // bp.block

    # V105 block-id bounds
    if ids.size and (ids.min() < 0 or ids.max() >= n_blocks):
        r.add(
            "V105",
            f"block_ids outside [0, {n_blocks}): "
            f"min={int(ids.min())} max={int(ids.max())}",
            layer=layer, location="block_ids",
        )

    # V106 pack density (mirrors the _Packer/_build invariants)
    if nnz.size and (nnz.min() < 0 or nnz.max() > min(k_max, n_blocks)):
        r.add(
            "V106",
            f"nnz outside [0, min(k_max={k_max}, n_blocks={n_blocks})]: "
            f"min={int(nnz.min())} max={int(nnz.max())}",
            layer=layer, location="nnz",
        )
    elif k_max > max(int(nnz.max()) if nnz.size else 0, 1):
        r.add(
            "V106",
            f"k_max={k_max} over-allocates brick slots "
            f"(max nnz is {int(nnz.max()) if nnz.size else 0})",
            severity=WARNING, layer=layer, location="k_max",
        )

    # V107 padded slots (and padded tiles) are inert; V108 pack order.
    # Pristine programs have few padded slots (k_max == max nnz), so
    # gathering just those bricks beats a full payload scan.
    nnz_c = np.clip(nnz, 0, k_max)
    slot = np.arange(k_max)[None, :]
    padded = slot >= nnz_c[:, None]  # [T, k_max]
    if np.any(ids[padded] != 0):
        r.add(
            "V107",
            "padded brick slots must point at block 0",
            layer=layer, location="block_ids",
        )
    if np.any(w[padded] != 0):
        r.add(
            "V107",
            "padded brick slots must hold all-zero bricks",
            layer=layer, location="w_comp",
        )
    active = ~padded
    # strictly increasing active ids per tile: diff > 0 where both active
    if k_max > 1:
        both = active[:, 1:] & active[:, :-1]
        if np.any((np.diff(ids, axis=1) <= 0) & both):
            r.add(
                "V108",
                "active block_ids are not strictly increasing per tile "
                "(non-canonical pack order; duplicates split one block's "
                "weights over two bricks)",
                severity=WARNING, layer=layer, location="block_ids",
            )

    # V109 dictionary shape
    dm = np.asarray(bp.dict_masks)
    if dm.ndim != 2 or dm.shape[1] != n_blocks:
        r.add(
            "V109",
            f"dict_masks shape {dm.shape} != (P, n_blocks={n_blocks})",
            layer=layer, location="dict_masks",
        )

    # quantized-path contracts
    if bp.w_scales is not None:
        s = np.asarray(bp.w_scales)
        if s.shape != (n_tiles, k_max):
            r.add(
                "V110",
                f"w_scales shape {s.shape} != (n_tiles, k_max) = "
                f"{(n_tiles, k_max)}",
                layer=layer, location="w_scales",
            )
            return r
        if not np.issubdtype(s.dtype, np.floating):
            r.add("V110", f"w_scales must be float, got {s.dtype}",
                  layer=layer, location="w_scales")
        if not np.all(np.isfinite(s)) or (s.size and s.min() < 0):
            r.add(
                "V111",
                "w_scales must be finite and non-negative",
                layer=layer, location="w_scales",
            )
        # active slots with a zero scale (padded slots are V107's job);
        # pristine programs have none, so the brick gather is empty
        zero_active = (s == 0) & ~padded
        if np.any(zero_active):
            nonzero = np.any(w[zero_active] != 0, axis=(1, 2))
            if np.any(nonzero):
                t, k = np.argwhere(zero_active)[int(np.argmax(nonzero))]
                r.add(
                    "V112",
                    f"zero scale silently drops a nonzero brick "
                    f"(first at tile {t}, slot {k})",
                    layer=layer, location=f"w_scales[{t},{k}]",
                )
        if w.dtype != np.int8:
            r.add(
                "V113",
                f"quantized w_comp must be int8, got {w.dtype}",
                layer=layer, location="w_comp",
            )
        wmin = int(w.min()) if w.size else 0
        wmax = int(w.max()) if w.size else 0
        if w.dtype == np.int8 and (wmin < -QMAX or wmax > QMAX):
            r.add(
                "V113",
                f"quantized weights outside [-{QMAX}, {QMAX}]: "
                f"min={wmin} max={wmax}",
                layer=layer, location="w_comp",
            )
        if w.dtype == np.int8:
            # cell slicing is elementwise, so the bit-exact round trip
            # w == compose(slices(w)) holds for the whole payload iff it
            # holds for every distinct int8 value present — slice the 256
            # possible values once, then count offenders with one bincount
            # pass instead of re-slicing every brick
            domain = np.arange(-128, 128, dtype=np.int8)
            recomposed = compose_cell_slices(
                cell_slices(domain, cell_bits), cell_bits
            )
            bad = domain[np.asarray(recomposed, np.int64) != domain]
            # a bad value can only occur inside the payload's [min, max],
            # so pristine programs skip the counting pass entirely
            bad = bad[(bad >= wmin) & (bad <= wmax)]
            if bad.size:
                counts = np.bincount(
                    w.reshape(-1).view(np.uint8), minlength=256
                )
                n_bad = int(counts[bad.astype(np.int16) % 256].sum())
                if n_bad:
                    present = [
                        int(v) for v in bad
                        if counts[int(v) % 256]
                    ][:8]
                    r.add(
                        "V114",
                        f"{n_bad} stored weights (values {present}) do not "
                        f"survive the {cell_bits}-bit cell-slice round trip",
                        layer=layer, location="w_comp",
                    )
        if np.any(s[padded] != 0):
            r.add(
                "V107",
                "padded brick slots must carry zero scales",
                layer=layer, location="w_scales",
            )
    else:
        if not np.issubdtype(w.dtype, np.floating):
            r.add(
                "V113",
                f"unquantized w_comp must be float, got {w.dtype} "
                "(int payload without w_scales)",
                layer=layer, location="w_comp",
            )
        # NaN/Inf propagate through the sum, so this is a single
        # allocation-free reduce; the exact count is only computed on the
        # (already broken) error path
        elif not np.isfinite(w.sum()):
            r.add(
                "V115",
                f"{int((~np.isfinite(w)).sum())} non-finite stored weights",
                layer=layer, location="w_comp",
            )
    return r


def _verify_bias(r: Report, bias, n: int, layer: str) -> None:
    b = np.asarray(bias)
    if b.shape != (n,):
        r.add("V204", f"bias shape {b.shape} != ({n},)",
              layer=layer, location="bias")
    elif not np.all(np.isfinite(b)):
        r.add("V204", "bias has non-finite entries",
              layer=layer, location="bias")


def _verify_mapping(r: Report, conv) -> None:
    """V205/V206: a searched per-layer mapping candidate, if present.

    ``MappingCandidate`` is deliberately unvalidated at construction so a
    corrupted save surfaces here as a diagnostic rather than a load-time
    construction error."""
    m = getattr(conv, "mapping", None)
    if m is None:
        return
    name = conv.name
    if m.block_order not in BLOCK_ORDERS:
        r.add(
            "V205",
            f"unknown mapping block_order {m.block_order!r} "
            f"(known: {BLOCK_ORDERS})",
            layer=name, location="mapping.block_order",
        )
    if m.reorder not in REORDERS:
        r.add(
            "V205",
            f"unknown mapping reorder {m.reorder!r} (known: {REORDERS})",
            layer=name, location="mapping.reorder",
        )
    dims = {
        "rows": m.rows,
        "cols": m.cols,
        "cells_per_weight": m.cells_per_weight,
        "ou_rows": m.ou_rows,
        "ou_cols": m.ou_cols,
    }
    bad = {k: v for k, v in dims.items() if v < 1}
    if bad:
        r.add(
            "V205",
            f"non-positive mapping geometry: {bad}",
            layer=name, location="mapping",
        )
        return  # consistency checks below assume positive dims
    if m.ou_rows > m.rows:
        r.add(
            "V206",
            f"mapping ou_rows={m.ou_rows} exceeds crossbar rows={m.rows}",
            layer=name, location="mapping.ou_rows",
        )
    if m.ou_cols > m.cols:
        r.add(
            "V206",
            f"mapping ou_cols={m.ou_cols} exceeds crossbar cols={m.cols}",
            layer=name, location="mapping.ou_cols",
        )
    if m.cells_per_weight > m.cols:
        r.add(
            "V206",
            f"mapping cells_per_weight={m.cells_per_weight} exceeds "
            f"crossbar cols={m.cols} (one weight must fit one row)",
            layer=name, location="mapping.cells_per_weight",
        )
    bits = np.asarray(conv.pattern_bits)
    if (
        bits.ndim == 2
        and bits.size
        and np.issubdtype(bits.dtype, np.integer)
        and bits.min() >= 0
    ):
        nz = bits != ALL_ZERO
        if np.any(nz):
            max_h = int(pattern_sizes(bits)[nz].max())
            if m.ou_rows < max_h:
                r.add(
                    "V206",
                    f"mapping ou_rows={m.ou_rows} cannot hold the layer's "
                    f"tallest pattern block (height {max_h}): "
                    "pattern_ou_schedule never splits a block across OU "
                    "row groups",
                    layer=name, location="mapping.ou_rows",
                )


def verify_conv(conv, cell_bits: int = 4, report: Report | None = None) -> Report:
    """Verify one compiled conv layer (V2xx + its operand's V1xx)."""
    r = report if report is not None else Report()
    name = conv.name
    verify_bp(conv.bp, layer=name, cell_bits=cell_bits, report=r)

    k = conv.kernel
    if k < 1:
        r.add("V203", f"kernel size {k} < 1", layer=name, location="kernel")
        return r
    if k % 2 == 0:
        r.add(
            "V203",
            f"even kernel {k}x{k}: the executor's 'same' padding assumes "
            "an odd kernel",
            severity=WARNING, layer=name, location="kernel",
        )
    if conv.out_hw < 1 or conv.c_in < 1 or conv.c_out < 1:
        r.add(
            "V203",
            f"non-positive layer dims: c_in={conv.c_in} c_out={conv.c_out} "
            f"out_hw={conv.out_hw}",
            layer=name, location="dims",
        )
        return r

    bits = np.asarray(conv.pattern_bits)
    if bits.shape != (conv.c_out, conv.c_in) or not np.issubdtype(
        bits.dtype, np.integer
    ):
        r.add(
            "V201",
            f"pattern_bits shape {bits.shape} (dtype {bits.dtype}) != "
            f"integer [c_out={conv.c_out}, c_in={conv.c_in}]",
            layer=name, location="pattern_bits",
        )
    elif bits.size and (
        bits.min() < 0 or bits.max() >= (1 << (k * k))
    ):
        r.add(
            "V202",
            f"pattern bitmask outside the {k}x{k} kernel window "
            f"[0, 2^{k * k}): min={int(bits.min())} max={int(bits.max())}",
            layer=name, location="pattern_bits",
        )

    bp = conv.bp
    want_k = _pad_up(conv.c_in * k * k, bp.block)
    want_n = _pad_up(conv.c_out, bp.tile)
    if bp.k_in != want_k:
        r.add(
            "V203",
            f"bp.k_in={bp.k_in} != padded c_in*k*k = {want_k}",
            layer=name, location="bp.k_in",
        )
    if bp.n_out != want_n:
        r.add(
            "V203",
            f"bp.n_out={bp.n_out} != padded c_out = {want_n}",
            layer=name, location="bp.n_out",
        )
    _verify_bias(r, conv.bias, conv.c_out, name)
    _verify_mapping(r, conv)
    return r


def verify_fc(fc, cell_bits: int = 4, report: Report | None = None) -> Report:
    """Verify the compiled FC head (V2xx + operand V1xx)."""
    r = report if report is not None else Report()
    verify_bp(fc.bp, layer="fc", cell_bits=cell_bits, report=r)
    reorder = getattr(fc, "reorder", "pattern")
    if reorder not in REORDERS:
        r.add(
            "V205",
            f"unknown fc reorder {reorder!r} (known: {REORDERS})",
            layer="fc", location="reorder",
        )
    bp = fc.bp
    if fc.d_in < 1 or fc.d_out < 1:
        r.add("V203", f"non-positive fc dims: d_in={fc.d_in} d_out={fc.d_out}",
              layer="fc", location="dims")
        return r
    want_k = _pad_up(fc.d_in, bp.block)
    want_n = _pad_up(fc.d_out, bp.tile)
    if bp.k_in != want_k:
        r.add("V203", f"bp.k_in={bp.k_in} != padded d_in = {want_k}",
              layer="fc", location="bp.k_in")
    if bp.n_out != want_n:
        r.add("V203", f"bp.n_out={bp.n_out} != padded d_out = {want_n}",
              layer="fc", location="bp.n_out")
    _verify_bias(r, fc.bias, fc.d_out, "fc")
    return r


def verify_partition(program, partition=None, report: Report | None = None) -> Report:
    """Verify a partition's tile disjoint-cover over a program (V4xx)."""
    from repro.engine.partition import padded_tiles, tile_assignment

    r = report if report is not None else Report()
    part = partition if partition is not None else program.partition
    if part is None:
        return r
    if part.data < 1 or part.model < 1:
        r.add("V401", f"non-positive partition {part.data}x{part.model}",
              location="partition")
        return r
    if not part.data_axis or not part.model_axis:
        r.add("V403", "partition axis names must be non-empty",
              location="partition")
    elif part.data_axis == part.model_axis:
        r.add(
            "V403",
            f"data_axis and model_axis are both {part.data_axis!r}",
            location="partition",
        )
    bps = [(c.name, c.bp) for c in program.convs] + [("fc", program.fc.bp)]
    for name, bp in bps:
        padded = padded_tiles(bp.n_tiles, part.model)
        asg = tile_assignment(bp.n_tiles, part.model)
        per = padded // part.model
        cover = (
            asg.shape == (part.model, per)
            and np.array_equal(np.sort(asg.ravel()), np.arange(padded))
        )
        if padded % part.model or not cover:
            r.add(
                "V402",
                f"tile assignment does not disjointly cover the "
                f"{padded}-tile padded axis over {part.model} shard(s)",
                layer=name, location="partition",
            )
    return r


def verify_network(program, report: Report | None = None) -> Report:
    """Verify a full compiled program: every operand, every layer, the
    inter-layer chain, the precision contract, and any partition."""
    r = report if report is not None else Report()
    cfg = program.config

    # V303 / V302 program-level contracts
    quantized = []
    for name, bp in [(c.name, c.bp) for c in program.convs] + [
        ("fc", program.fc.bp)
    ]:
        if (bp.block, bp.tile) != (program.block, program.tile):
            r.add(
                "V303",
                f"operand block/tile {bp.block}x{bp.tile} != program "
                f"{program.block}x{program.tile}",
                layer=name, location="bp",
            )
        quantized.append(bp.w_scales is not None)
    if program.precision not in ("fp32", "int8"):
        r.add("V302", f"unknown precision {program.precision!r}",
              location="precision")
    elif program.precision == "int8" and not all(quantized):
        r.add(
            "V302",
            "precision='int8' but some operands carry no w_scales",
            location="precision",
        )
    elif program.precision == "fp32" and any(quantized):
        r.add(
            "V302",
            "precision='fp32' but some operands carry w_scales",
            location="precision",
        )
    if program.cell_bits < 1:
        r.add("V302", f"cell_bits={program.cell_bits} < 1",
              location="cell_bits")
        return r

    # per-layer checks
    for conv in program.convs:
        verify_conv(conv, cell_bits=program.cell_bits, report=r)
    verify_fc(program.fc, cell_bits=program.cell_bits, report=r)

    # V206 storage consistency: an int8 program's searched mappings must
    # price the cell-slice count its payload actually occupies (the same
    # derivation hardware_report uses)
    stored = program.cells_per_weight
    if stored is not None:
        for conv in program.convs:
            m = getattr(conv, "mapping", None)
            if m is not None and m.cells_per_weight != stored:
                r.add(
                    "V206",
                    f"mapping cells_per_weight={m.cells_per_weight} != "
                    f"the stored cell-slice count {stored} "
                    f"(int8 over {program.cell_bits}-bit cells)",
                    layer=conv.name, location="mapping.cells_per_weight",
                )

    # V301 inter-layer chain
    if len(program.convs) != cfg.num_convs:
        r.add(
            "V301",
            f"{len(program.convs)} compiled convs != config's "
            f"{cfg.num_convs}",
            location="convs",
        )
    hw = cfg.input_hw
    prev_out = cfg.conv_channels[0][0] if cfg.conv_channels else None
    for i, conv in enumerate(program.convs, start=1):
        if conv.c_in != prev_out:
            r.add(
                "V301",
                f"c_in={conv.c_in} does not chain from previous layer's "
                f"c_out={prev_out}",
                layer=conv.name, location="c_in",
            )
        if i <= cfg.num_convs and (conv.c_in, conv.c_out) != tuple(
            cfg.conv_channels[i - 1]
        ):
            r.add(
                "V301",
                f"(c_in, c_out)=({conv.c_in}, {conv.c_out}) != config "
                f"channels {tuple(cfg.conv_channels[i - 1])}",
                layer=conv.name, location="channels",
            )
        if conv.out_hw != hw:
            r.add(
                "V301",
                f"out_hw={conv.out_hw} != chained spatial size {hw}",
                layer=conv.name, location="out_hw",
            )
        if conv.pool_after != (i in cfg.pool_after):
            r.add(
                "V301",
                f"pool_after={conv.pool_after} disagrees with config "
                f"pool_after={sorted(cfg.pool_after)}",
                layer=conv.name, location="pool_after",
            )
        if conv.pool_after:
            hw //= 2
        prev_out = conv.c_out
    if program.convs and program.fc.d_in != program.convs[-1].c_out:
        r.add(
            "V301",
            f"fc.d_in={program.fc.d_in} != last conv c_out="
            f"{program.convs[-1].c_out} (global average pool preserves "
            "channels)",
            layer="fc", location="d_in",
        )
    if program.fc.d_out != cfg.num_classes:
        r.add(
            "V301",
            f"fc.d_out={program.fc.d_out} != num_classes={cfg.num_classes}",
            layer="fc", location="d_out",
        )

    verify_partition(program, report=r)
    return r


def verify_manifest(directory: str, report: Report | None = None) -> Report:
    """Static checks of a serialized program directory (M0xx).

    Validates the manifest's version, keys, and referenced payload files
    *without* constructing any array — the same pre-load validation
    ``load_program`` performs, expressed as diagnostics instead of a
    raised :class:`ProgramFormatError`.
    """
    from repro.engine import serialize

    r = report if report is not None else Report()
    try:
        manifest = serialize.read_manifest(directory)
    except ProgramFormatError as e:
        r.add(getattr(e, "rule", "M001"), str(e), location=directory)
        return r
    try:
        serialize.validate_manifest(manifest, directory)
    except ProgramFormatError as e:
        r.add(getattr(e, "rule", "M003"), str(e), location=directory)
    return r


def verify_saved(directory: str) -> Report:
    """Full verification of a saved program: manifest statics, payload
    load, then the in-memory network verifier."""
    from repro.engine import serialize

    r = verify_manifest(directory)
    if not r.ok:
        return r
    try:
        program = serialize.load_program(directory, verify=False)
    except ProgramFormatError as e:
        r.add(getattr(e, "rule", "M005"), str(e), location=directory)
        return r
    return verify_network(program, report=r)
