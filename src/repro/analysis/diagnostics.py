"""Structured diagnostics for the static program verifier and repo lint.

One :class:`Diagnostic` is one violated rule at one location: a stable
rule id (``V1xx`` operand rules, ``V2xx`` layer rules, ``V3xx`` network
rules, ``V4xx`` partition rules, ``M0xx`` manifest rules, ``L0xx`` lint
rules), a severity (``error`` means the program must not run / the code
must not merge; ``warning`` means suspicious but executable), and enough
location context (layer, field path, file:line) to act on it without
re-running the verifier.

:class:`Report` collects diagnostics and is the single currency between
the rule passes (``analysis/verify.py``, ``analysis/lint.py``), their
call sites at the trust boundaries (``compile_network(verify=...)``,
``serialize.load_program(verify=...)``, ``partition_network``), and the
``python -m repro.analysis`` CLI (which renders it as text or JSON).

This module is dependency-free on purpose: ``engine/serialize.py`` pulls
:class:`ProgramFormatError` from here without dragging the verifier (and
its ``engine`` imports) into its own import graph.
"""

from __future__ import annotations

import dataclasses
import json

__all__ = [
    "Diagnostic",
    "Report",
    "ProgramFormatError",
    "VerificationError",
    "ERROR",
    "WARNING",
]

ERROR = "error"
WARNING = "warning"


class ProgramFormatError(ValueError):
    """A serialized program's manifest or payload is malformed.

    Raised by ``engine/serialize.load_program`` *before* any array is
    constructed, so a corrupt or truncated file surfaces as one clear
    error naming the offending manifest field instead of an opaque
    ``KeyError``/``ValueError`` from the middle of the load.  Carries
    the manifest rule id (``M001`` unreadable, ``M002`` bad version,
    ``M003`` missing/ill-typed keys, ``M004`` missing payload files,
    ``M005`` payload load failure) so :func:`repro.analysis.verify.
    verify_manifest` can report it as a diagnostic.
    """

    def __init__(self, message: str, rule: str = "M003"):
        super().__init__(message)
        self.rule = rule


class VerificationError(ValueError):
    """A program failed static verification; carries the full report."""

    def __init__(self, message: str, report: "Report"):
        super().__init__(message + "\n" + report.format())
        self.report = report


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One rule violation at one location."""

    rule: str  # stable id, e.g. "V101"
    severity: str  # ERROR | WARNING
    message: str
    layer: str | None = None  # "conv1", "fc", or None for network scope
    location: str | None = None  # field path or file:line

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "message": self.message,
            "layer": self.layer,
            "location": self.location,
        }

    def format(self) -> str:
        where = ":".join(p for p in (self.layer, self.location) if p)
        prefix = f"{self.severity.upper()} {self.rule}"
        return f"{prefix} [{where}] {self.message}" if where else \
            f"{prefix} {self.message}"


class Report:
    """An ordered collection of diagnostics with an error/warning split."""

    def __init__(self, diagnostics: list[Diagnostic] | None = None):
        self.diagnostics: list[Diagnostic] = list(diagnostics or [])

    def add(
        self,
        rule: str,
        message: str,
        severity: str = ERROR,
        layer: str | None = None,
        location: str | None = None,
    ) -> Diagnostic:
        d = Diagnostic(rule, severity, message, layer, location)
        self.diagnostics.append(d)
        return d

    def extend(self, other: "Report") -> "Report":
        self.diagnostics.extend(other.diagnostics)
        return self

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        """No errors (warnings are allowed)."""
        return not self.errors

    @property
    def clean(self) -> bool:
        """No diagnostics at all."""
        return not self.diagnostics

    def rules(self, severity: str | None = None) -> set[str]:
        """The distinct rule ids present, optionally filtered by severity."""
        return {
            d.rule
            for d in self.diagnostics
            if severity is None or d.severity == severity
        }

    def __len__(self) -> int:
        return len(self.diagnostics)

    def __iter__(self):
        return iter(self.diagnostics)

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "diagnostics": [d.to_json() for d in self.diagnostics],
        }

    def format(self) -> str:
        if not self.diagnostics:
            return "clean: no diagnostics"
        lines = [d.format() for d in self.diagnostics]
        lines.append(
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s)"
        )
        return "\n".join(lines)

    def raise_if_errors(self, context: str) -> "Report":
        """Raise :class:`VerificationError` when any error diagnostic
        exists; returns ``self`` otherwise (chainable)."""
        if self.errors:
            raise VerificationError(
                f"{context}: {len(self.errors)} verification error(s)", self
            )
        return self

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2)
