"""Static analysis for compiled crossbar programs and the repo itself.

Three passes, one diagnostics currency:

* :mod:`repro.analysis.verify` — an execution-free program verifier over
  ``BlockPatternWeight`` / ``CompiledNetwork`` / ``NetworkPartition`` /
  serialized manifests (rules ``V1xx``–``V4xx``, ``M0xx``).  Runs at the
  trust boundaries: ``compile_network(verify=...)``,
  ``load_program(verify=True)``, ``partition_network``.
* :mod:`repro.analysis.ranges` — the range & bit-width certification
  pass (rules ``V5xx``): an abstract interpreter that propagates
  interval bounds through the compiled schedule and proves accumulator
  and cell-budget facts about the quantized path, emitting a
  :class:`~repro.analysis.ranges.RangeCertificate` that
  ``hardware_report()`` prices and manifest v4 persists.
* :mod:`repro.analysis.lint` — an AST lint over ``src/repro/`` (rules
  ``L0xx``) enforcing jit-purity, tracer discipline, and lock
  discipline in CI.

CLI::

    python -m repro.analysis verify <saved-program-dir> [--json]
    python -m repro.analysis ranges <saved-program-dir> [--json]
    python -m repro.analysis lint [paths...] [--json]
    python -m repro.analysis all <saved-program-dir> [--paths ...]

(exit codes documented in :mod:`repro.analysis.__main__`).
"""

from repro.analysis.diagnostics import (
    ERROR,
    WARNING,
    Diagnostic,
    ProgramFormatError,
    Report,
    VerificationError,
)

__all__ = [
    "Diagnostic",
    "Report",
    "ProgramFormatError",
    "VerificationError",
    "ERROR",
    "WARNING",
]
