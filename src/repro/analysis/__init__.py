"""Static analysis for compiled crossbar programs and the repo itself.

Two halves, one diagnostics currency:

* :mod:`repro.analysis.verify` — an execution-free program verifier over
  ``BlockPatternWeight`` / ``CompiledNetwork`` / ``NetworkPartition`` /
  serialized manifests (rules ``V1xx``–``V4xx``, ``M0xx``).  Runs at the
  trust boundaries: ``compile_network(verify=...)``,
  ``load_program(verify=True)``, ``partition_network``.
* :mod:`repro.analysis.lint` — an AST trace-safety lint over
  ``src/repro/`` (rules ``L0xx``) enforcing jit-purity and tracer
  discipline in CI.

CLI::

    python -m repro.analysis verify <saved-program-dir> [--json]
    python -m repro.analysis lint [paths...] [--json]
"""

from repro.analysis.diagnostics import (
    ERROR,
    WARNING,
    Diagnostic,
    ProgramFormatError,
    Report,
    VerificationError,
)

__all__ = [
    "Diagnostic",
    "Report",
    "ProgramFormatError",
    "VerificationError",
    "ERROR",
    "WARNING",
]
