"""Inference engine: compile pattern-pruned CNNs into executable programs.

The paper's deployment story made real: ``lowering`` turns pruned dense
weights into compressed spmm operands (reorder -> compress -> index),
``program`` is the compiled artifact (ops + geometry + crossbar pricing),
``executor`` runs it through the Pallas/XLA kernels (single-device or
sharded over a mesh via ``partition`` — tile-parallel spmm with psum
combine, batch-parallel service slots), ``serialize`` persists it,
``scheduler`` is the continuous-batching control plane (bounded queue,
slot refill, validity mask, latency/occupancy metrics), ``service``
serves traffic over it, and ``stats`` measures activation-skip
statistics on the served traffic so the crossbar energy pricing uses
observed (not assumed) skip probabilities.

Note: the model's BN stand-in (``channel_norm``) is per-sample, so a
request's logits never depend on which other requests share its batch.
``InferenceService`` exploits that to run every batch at the fixed
``batch_slots`` shape — dead slots zero-padded and masked out of the
statistics — so the forward traces exactly once for any traffic pattern.

Observability
-------------
The whole stack is instrumented through ``repro.obs`` — pure stdlib,
opt-in, and free when off (``tracer=None`` resolves to a shared no-op
tracer; the jitted forward is byte-identical either way).

* **Tracing.** Pass one ``obs.Tracer`` through the layers you care
  about: ``compile_network(..., tracer=tr)`` records the lowering
  phases (``prune -> reorder -> pack -> quantize`` under per-layer
  ``lower:<name>`` spans), ``make_forward(..., tracer=tr)`` switches to
  an eager per-layer instrumented forward (``layer:*`` spans with real
  wall-times — profile with it, serve without it), and
  ``InferenceService(..., tracer=tr)`` emits per-request async
  lifecycles (enqueue ``b`` -> admit ``n`` -> done ``e``) plus
  queue-depth/slot-occupancy counter tracks.  ``tr.write("trace.json")``
  produces Chrome trace-event JSON — load it in Perfetto or
  chrome://tracing to see compile, execute, and serve on one timeline.
* **Predicted-vs-measured drift.** The instrumented forward's
  ``fn.observed_times()`` (layer -> mean seconds) feeds
  ``CompiledNetwork.hardware_report(observed=...)``, which then carries
  a ``drift`` section comparing each layer's *share* of measured wall
  time against its share of predicted crossbar cycles — the simulator's
  cost model audited against the executing engine.
* **Metrics.** ``SchedulerMetrics.snapshot()`` includes
  histogram-backed ``latency_p50_s``/``latency_p99_s`` and the
  queue-wait vs in-flight latency breakdown;
  ``InferenceService.metrics_text()`` renders the same registry in
  Prometheus text exposition for scraping.  Process-global metrics live
  in ``repro.obs.get_registry()`` (resettable for test isolation).

Mapping optimization
--------------------
``compile_network(optimize='auto')`` (or ``optimize=MappingSearchConfig(
...)``) runs the per-layer mapping design-space search
(``core/mapsearch.py``) before lowering each conv: a seeded greedy
descent with restarts over crossbar dims x packing order
(``block_order``) x column-reorder strategy, priced by the simulator's
own cost chain (``core/simulator.mapping_cost``) so the predicted
area/energy/cycles equal ``hardware_report`` numbers exactly.  Selection
is Pareto-guarded — the chosen candidate is never worse than the fixed
paper scheme on *both* crossbar area-cells and energy, falling back to
the fixed scheme on ties — and fully deterministic for a given seed.
How it composes:

* **precision=** — the search prices the cell-slice count the program
  actually stores (int8 -> ``ceil(8 / cell_bits)`` cells/weight, fp32 ->
  the crossbar model default), so a quantized program's searched area is
  the quantized area.  Note int8 logits are only tolerance-equal across
  reorder strategies: per-brick quantization scales depend on column
  grouping.  fp32 logits are bit-identical — reordering changes layout,
  never semantics.
* **verify=** — searched programs pass the same static verifier;
  the candidate itself is checked by rules V205 (strategy tags) and
  V206 (geometry consistent with the packed operands).
* **partitioning / sharded execution** — the searched reorder produces
  the same ``BlockPatternWeight`` contract, so ``partition_network``
  and the mesh executor apply unchanged.
* **serialization** — the chosen ``MappingCandidate`` per conv and the
  FC reorder tag ride in the manifest (format v3; v1/v2 programs load
  as the fixed scheme) and ``hardware_report`` prices each layer at its
  stored candidate after reload.
* **tracing** — each layer's search lands as a ``search:<name>``
  compile span carrying evaluations / chosen candidate / area-vs-fixed,
  next to the ``lower:<name>`` spans.

Serving
-------
The serving front door lives in ``repro.serve`` — one
:class:`~repro.serve.Request`/:class:`~repro.serve.Response` contract and
one ``submit``/``stream``/``run`` verb set over both backends:

* **Classification** — ``repro.serve.classify_session(program)`` wraps
  :class:`InferenceService` (this package): fixed-shape continuous
  batching over the jitted engine forward, traced exactly once.
* **Generation** — ``repro.serve.generate_session(cfg, statics, params,
  scfg)`` wraps ``runtime.serve.DecodeService``: per-slot decode
  positions, so freed slots are refilled *mid-decode* while other
  requests keep decoding — and every request's tokens are bit-identical
  to running it alone.
* **HTTP** — ``repro.serve.ServingServer(session)`` is a stdlib-asyncio
  HTTP/1.1 front end: ``POST /v1/run`` (one request/response),
  ``POST /v1/stream`` (chunked NDJSON in completion order),
  ``GET /healthz``, ``GET /metrics`` (Prometheus text).  All jitted
  calls run on one worker thread; the event loop only parses, enqueues,
  and resolves futures.  Over capacity it *sheds*: HTTP 429 with a
  backpressure-derived ``Retry-After``, while already-admitted work is
  never dropped (``SchedulerFull`` never escapes the public path —
  sessions translate it to ``repro.serve.Overloaded``).

``examples/serve_http.py`` boots the full stack and reports req/s,
first-result p50/p99, and slot occupancy; ``benchmarks/bench_engine.py
http_service`` gates the same numbers in CI.  The old entry points
(``engine.service.ClassifyRequest``, ``runtime.serve.Request``) remain
as deprecated shims that construct ``repro.serve.Request`` and warn.

Compile options
---------------
:class:`CompileOptions` is the one frozen object carrying everything
``compile_network`` accepts beyond the network itself — lowering
geometry (``block``/``tile``/``precision``/``cell_bits``, mirroring
:class:`EngineConfig`) plus the compile-pass switches
(``verify``/``optimize``/``tracer``).  Prefer
``compile_network(cfg, params, bits, options=CompileOptions(...))``;
the loose kwargs remain as deprecated aliases that compile bit-identical
programs while emitting ``DeprecationWarning``.

Verification
------------
``repro.analysis`` statically checks compiled programs — pure numpy
over the operands, no kernel execution — and is wired in at every
trust boundary:

* ``compile_network(..., verify='strict')`` verifies the freshly built
  program and raises ``analysis.VerificationError`` listing every
  violated invariant; ``verify='warn'`` emits a single warning instead;
  the default ``None`` skips it (compile output is trusted by
  construction — turn it on when changing the lowering itself).
* ``load_program(directory)`` verifies by default: the manifest is
  validated *before* any array is constructed (a malformed or
  version-skewed save raises ``analysis.ProgramFormatError``, rule
  ``M001``–``M005``), then the loaded program is semantically verified
  (``V1xx``/``V2xx``/``V3xx`` rules).  Pass ``verify=False`` on hot
  paths that reload a program the same process just saved.
* ``partition_network`` always validates the partition geometry
  (``V4xx``: shard counts, tile disjoint-cover, distinct axes) — it is
  cheap and a bad partition fails far from its cause otherwise.
* ``CompiledNetwork.verify()`` returns the full diagnostic ``Report``
  for ad-hoc inspection; ``python -m repro.analysis verify <dir>``
  does the same for a saved program from the command line.

Each ``Diagnostic`` carries a stable rule id, severity, layer, and
location string; ``Report.format()`` renders them one per line.
Warnings (e.g. over-allocated ``k_max``, non-canonical pack order)
never raise — only errors do.  The companion trace-safety lint
(``python -m repro.analysis lint src/repro``) runs in CI and keeps
wall-clock reads, host RNG, unsynchronized timing, and unlocked
shared-state mutation out of the source tree.

Certification
-------------
Verification proves the program is *well-formed*; the range
certification pass (``repro.analysis.ranges``) proves facts about what
it can *compute*.  It is an abstract interpreter over the compiled
schedule: from a declared input interval it propagates sound activation
bounds through every layer (spmm -> channel-norm -> relu -> pool ->
head) and derives activation-independent worst-case accumulator extrema
for the quantized path.  Structural rules are ``V1xx``–``V4xx``/
``M0xx``; semantic rules are ``V5xx`` (accumulator overflow, scale
saturation/denormal, dead scale groups, range divergence, unreachable
cell slices, stale stored certificates).

When ``compile_network(..., verify=...)`` is on, the pass runs right
after verification and attaches a ``RangeCertificate`` to the program:
per-layer activation bounds plus a certified minimum cells-per-weight
table on the layer's reference scale grid.  The certificate rides in
manifest v4 (v1–v3 saves still load, without one),
``hardware_report()`` prices it as a ``certified_potential`` section
(certified-vs-stored crossbar area/energy, exactly on the simulator's
own cost chain), and ``python -m repro.analysis ranges <dir>`` recomputes
and cross-checks it for a saved program (rule ``V506`` fires if the
stored certificate disagrees).  ``python -m repro.analysis all <dir>``
runs verify + lint + ranges with one merged JSON report.
"""

from repro.engine.executor import (
    execute,
    extract_patches,
    make_forward,
    warmup_forward,
)
from repro.engine.scheduler import (
    SchedulerFull,
    SchedulerMetrics,
    SlotScheduler,
)
from repro.engine.partition import (
    NetworkPartition,
    pad_bp_tiles,
    partition_from_mesh,
    partition_network,
    tile_assignment,
)
from repro.core.mapping import MappingCandidate
from repro.core.mapsearch import (
    MappingSearchConfig,
    MappingSearchResult,
    search_layer_mapping,
)
from repro.engine.lowering import (
    PRECISIONS,
    CompileOptions,
    EngineConfig,
    compile_network,
    conv_mapping_search,
    lower_conv,
    lower_fc,
    lower_matrix,
)
from repro.engine.program import CompiledConv, CompiledFC, CompiledNetwork
from repro.engine.serialize import load_program, save_program
# back-compat re-export for the deprecation window
from repro.engine.service import ClassifyRequest  # lint: allow(L005)
from repro.engine.service import InferenceService
from repro.engine.stats import (
    ActivationStats,
    LayerSkipStats,
    skip_patterns_and_masks,
    stats_from_counts,
)

__all__ = [
    "PRECISIONS",
    "CompileOptions",
    "EngineConfig",
    "compile_network",
    "conv_mapping_search",
    "lower_conv",
    "lower_fc",
    "lower_matrix",
    "MappingCandidate",
    "MappingSearchConfig",
    "MappingSearchResult",
    "search_layer_mapping",
    "CompiledConv",
    "CompiledFC",
    "CompiledNetwork",
    "make_forward",
    "warmup_forward",
    "execute",
    "extract_patches",
    "save_program",
    "load_program",
    "ClassifyRequest",
    "InferenceService",
    "SchedulerFull",
    "SchedulerMetrics",
    "SlotScheduler",
    "NetworkPartition",
    "pad_bp_tiles",
    "partition_from_mesh",
    "partition_network",
    "tile_assignment",
    "ActivationStats",
    "LayerSkipStats",
    "skip_patterns_and_masks",
    "stats_from_counts",
]
