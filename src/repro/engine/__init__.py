"""Inference engine: compile pattern-pruned CNNs into executable programs.

The paper's deployment story made real: ``lowering`` turns pruned dense
weights into compressed spmm operands (reorder -> compress -> index),
``program`` is the compiled artifact (ops + geometry + crossbar pricing),
``executor`` runs it through the Pallas/XLA kernels (single-device or
sharded over a mesh via ``partition`` — tile-parallel spmm with psum
combine, batch-parallel service slots), ``serialize`` persists it,
``scheduler`` is the continuous-batching control plane (bounded queue,
slot refill, validity mask, latency/occupancy metrics), ``service``
serves traffic over it, and ``stats`` measures activation-skip
statistics on the served traffic so the crossbar energy pricing uses
observed (not assumed) skip probabilities.

Note: the model's BN stand-in (``channel_norm``) is per-sample, so a
request's logits never depend on which other requests share its batch.
``InferenceService`` exploits that to run every batch at the fixed
``batch_slots`` shape — dead slots zero-padded and masked out of the
statistics — so the forward traces exactly once for any traffic pattern.
"""

from repro.engine.executor import execute, extract_patches, make_forward
from repro.engine.scheduler import (
    SchedulerFull,
    SchedulerMetrics,
    SlotScheduler,
)
from repro.engine.partition import (
    NetworkPartition,
    pad_bp_tiles,
    partition_from_mesh,
    partition_network,
    tile_assignment,
)
from repro.engine.lowering import (
    PRECISIONS,
    EngineConfig,
    compile_network,
    lower_conv,
    lower_fc,
    lower_matrix,
)
from repro.engine.program import CompiledConv, CompiledFC, CompiledNetwork
from repro.engine.serialize import load_program, save_program
from repro.engine.service import ClassifyRequest, InferenceService
from repro.engine.stats import (
    ActivationStats,
    LayerSkipStats,
    skip_patterns_and_masks,
    stats_from_counts,
)

__all__ = [
    "PRECISIONS",
    "EngineConfig",
    "compile_network",
    "lower_conv",
    "lower_fc",
    "lower_matrix",
    "CompiledConv",
    "CompiledFC",
    "CompiledNetwork",
    "make_forward",
    "execute",
    "extract_patches",
    "save_program",
    "load_program",
    "ClassifyRequest",
    "InferenceService",
    "SchedulerFull",
    "SchedulerMetrics",
    "SlotScheduler",
    "NetworkPartition",
    "pad_bp_tiles",
    "partition_from_mesh",
    "partition_network",
    "tile_assignment",
    "ActivationStats",
    "LayerSkipStats",
    "skip_patterns_and_masks",
    "stats_from_counts",
]
