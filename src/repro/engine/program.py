"""The ``CompiledNetwork`` artifact: what the engine compiler emits.

A compiled program is an ordered op list — one ``CompiledConv`` per conv
layer (im2col conv-as-spmm + norm/ReLU + optional 2x2 maxpool), a global
average pool, and a ``CompiledFC`` head — each carrying real kernel
operands (a :class:`~repro.core.sparse.BlockPatternWeight` with
``w_comp`` / ``block_ids`` / ``inv_order``) rather than placement
statistics.  ``executor.py`` runs it, ``serialize.py`` persists it, and
:meth:`CompiledNetwork.hardware_report` prices it on the paper's RRAM
crossbar model by reusing ``core/mapping.map_layer`` +
``core/simulator.simulate_layer``, so every compiled program also knows
its crossbar area / energy / cycle budget.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.crossbar import EnergyModel
from repro.core.mapping import CrossbarConfig, MappingCandidate
from repro.core.quantize import WEIGHT_BITS, n_cell_slices
from repro.core.patterns import PatternDict
from repro.core.simulator import drift_table, mapping_cost, simulate_layer_multi
from repro.core.sparse import BlockPatternWeight, block_density
from repro.core.synthetic import LayerSpec, SyntheticLayer
from repro.engine.partition import NetworkPartition, tile_assignment
from repro.models.cnn import CNNConfig

__all__ = ["CompiledConv", "CompiledFC", "CompiledNetwork"]


@dataclasses.dataclass
class CompiledConv:
    """One conv layer lowered to an im2col spmm.

    ``bp`` operates on the *padded* matmul view: patches padded from
    ``c_in * kernel**2`` to ``bp.k_in`` rows, outputs padded from ``c_out``
    to ``bp.n_out`` columns (the executor slices the first ``c_out`` back
    out after the inverse permutation).

    ``mapping`` (optional) is the searched per-layer crossbar mapping
    (``compile_network(optimize=...)``, ``core/mapsearch.py``):
    ``hardware_report`` prices the layer at that candidate's geometry and
    packing order instead of the report-wide defaults.  ``None`` (the
    fixed scheme, and every v1/v2-loaded program) keeps the historical
    pricing.
    """

    name: str
    c_in: int
    c_out: int
    kernel: int  # spatial kernel side (3 for 3x3)
    out_hw: int  # output feature-map side at compile-time input_hw
    pool_after: bool
    bp: BlockPatternWeight
    bias: np.ndarray  # [c_out]
    pattern_bits: np.ndarray  # [c_out, c_in] packed kernel patterns
    mapping: MappingCandidate | None = None

    @property
    def k_unpadded(self) -> int:
        return self.c_in * self.kernel * self.kernel


@dataclasses.dataclass
class CompiledFC:
    """The FC head lowered onto the same compressed-spmm path.

    ``reorder`` records the column-reorder strategy the head was lowered
    with (``core/sparse.REORDERS``) — the FC has no crossbar mapping, so
    its searchable space is the reorder alone.
    """

    d_in: int
    d_out: int
    bp: BlockPatternWeight
    bias: np.ndarray  # [d_out]
    reorder: str = "pattern"


@dataclasses.dataclass
class CompiledNetwork:
    """Executable artifact: ordered ops + geometry + hardware pricing.

    ``partition`` (optional) declares how the program is meant to spread
    over a device mesh — tile-parallel ``model`` shards x batch-parallel
    ``data`` shards (``engine/partition.py``).  The executor realizes it
    when given a mesh; ``hardware_report`` derives its per-chip view from
    it; ``serialize.py`` persists it.

    ``precision`` records the stored weight representation ('fp32', or
    'int8' for per-row-group quantized bricks + scales) and ``cell_bits``
    the RRAM cell width those weights are sliced over;
    ``hardware_report`` prices crossbar area from the *stored* cell-slice
    count instead of the assumed-width default whenever the program is
    quantized.

    ``certificate`` (optional) is the
    :class:`~repro.analysis.ranges.RangeCertificate` the certification
    pass attaches (``compile_network(verify=...)``): certified activation
    bounds, accumulator extrema, and the per-OU-row-group minimum
    cells-per-weight table.  ``hardware_report`` prices it as the
    ``certified_potential`` section; ``serialize.py`` persists it
    (manifest v4).
    """

    config: CNNConfig
    convs: list[CompiledConv]
    fc: CompiledFC
    block: int
    tile: int
    partition: NetworkPartition | None = None
    precision: str = "fp32"
    cell_bits: int = 4
    certificate: object | None = None

    @property
    def cells_per_weight(self) -> int | None:
        """Cell slices each stored weight occupies, from actual storage.

        int8 programs: ``ceil(8 / cell_bits)`` (2 for 4-bit cells).  fp32
        programs store no cell slices — returns None and pricing keeps
        the crossbar model's assumed width.
        """
        if self.precision == "int8":
            return n_cell_slices(self.cell_bits)
        return None

    @property
    def num_ops(self) -> int:
        # convs + global-avg-pool + fc
        return len(self.convs) + 2

    def verify(self, strict: bool = False):
        """Run the static program verifier (``repro.analysis.verify``).

        Returns the diagnostic :class:`~repro.analysis.diagnostics.Report`;
        with ``strict=True`` raises
        :class:`~repro.analysis.diagnostics.VerificationError` when any
        error diagnostic is present.
        """
        from repro.analysis.verify import verify_network

        report = verify_network(self)
        if strict:
            report.raise_if_errors("CompiledNetwork.verify")
        return report

    def op_list(self) -> list[tuple[str, str]]:
        """Human-readable (op, detail) schedule, in execution order."""
        ops = []
        for c in self.convs:
            d = (f"spmm[{c.bp.k_in}x{c.bp.n_out}] "
                 f"density={block_density(c.bp):.2f} + norm/relu")
            if c.pool_after:
                d += " + maxpool2x2"
            ops.append((c.name, d))
        ops.append(("gap", "global average pool"))
        ops.append(("fc", f"spmm[{self.fc.bp.k_in}x{self.fc.bp.n_out}]"))
        return ops

    def weight_bytes(self) -> tuple[int, int]:
        """(compressed, dense-fp32) weight bytes across all spmm ops.

        Compressed bytes use the *stored* element width (1 byte per int8
        weight plus its fp32 row-group scales; 4 bytes per fp32 weight),
        so the quantized storage win is visible next to the dense size.
        """
        comp = dense = 0
        for c in self.convs:
            comp += self._bp_bytes(c.bp)
            dense += c.k_unpadded * c.c_out * 4
        comp += self._bp_bytes(self.fc.bp)
        dense += self.fc.d_in * self.fc.d_out * 4
        return comp, dense

    @staticmethod
    def _bp_bytes(bp) -> int:
        itemsize = np.dtype(np.asarray(bp.w_comp).dtype).itemsize
        n = int(np.sum(bp.nnz)) * bp.block * bp.tile * itemsize
        if bp.w_scales is not None:
            n += int(np.sum(bp.nnz)) * 4  # one fp32 scale per stored brick
        return n

    def _synthetic_layers(self) -> list[SyntheticLayer]:
        """The convs as ``SyntheticLayer``s for crossbar-model pricing."""
        layers = []
        for c in self.convs:
            spec = LayerSpec(
                name=c.name,
                c_in=c.c_in,
                c_out=c.c_out,
                out_hw=c.out_hw,
                kernel_size=c.kernel * c.kernel,
            )
            pdict = PatternDict(
                k=spec.kernel_size,
                patterns=tuple(int(b) for b in np.unique(c.pattern_bits)),
            )
            weights = np.zeros(
                (c.c_out, c.c_in, spec.kernel_size), np.float32
            )
            layers.append(SyntheticLayer(
                spec=spec, pdict=pdict,
                pattern_bits=np.asarray(c.pattern_bits, np.int64),
                weights=weights,
            ))
        return layers

    def _chips_view(self, layer_results, model: int, data: int) -> dict:
        """Split per-layer crossbar area/energy/cycles over ``model``
        tile-parallel chips (x ``data`` batch-parallel replicas).

        Each chip's share of a layer is the fraction of that layer's real
        (unpadded) spmm tiles the contiguous assignment hands it
        (``engine/partition.tile_assignment``) — a proportional split of
        the crossbar-model totals, so uneven tile counts show up as chip
        imbalance rather than being averaged away.  ``cycles_parallel``
        is the bottleneck chip; data replicas multiply area, not latency.
        """
        shares = np.zeros((model, len(self.convs)))
        for li, c in enumerate(self.convs):
            t = c.bp.n_tiles
            asg = tile_assignment(t, model)
            shares[:, li] = (asg < t).sum(axis=1) / t

        def split(attr):
            vals = np.array([getattr(r, attr) for r in layer_results])
            return shares @ vals  # [model]

        cb, en, cy = split("ours_crossbars"), split("ours_energy_pj"), \
            split("ours_cycles")
        total_cycles = float(sum(r.ours_cycles for r in layer_results))
        cycles_parallel = float(cy.max()) if model else 0.0
        return {
            "n_chips": model * data,
            "model_shards": model,
            "data_replicas": data,
            "per_chip": [
                {
                    "chip": m,
                    "tile_share": float(shares[m].mean()),
                    "crossbars": float(cb[m]),
                    "energy_pj": float(en[m]),
                    "cycles": float(cy[m]),
                }
                for m in range(model)
            ],
            "crossbars_per_chip_max": float(cb.max()),
            "total_crossbars_all_chips": float(cb.sum()) * data,
            "cycles_parallel": cycles_parallel,
            "parallel_speedup": total_cycles / max(cycles_parallel, 1e-9),
        }

    def _certified_potential(
        self, config: CrossbarConfig, energy: EnergyModel
    ) -> dict:
        """Price what the certificate's min-cell table would unlock.

        Each conv is re-priced via ``core/simulator.mapping_cost`` — the
        exact chain ``hardware_report``'s own rows come from — twice: at
        its effective candidate (the searched mapping, or the reference
        ``config`` as a candidate) and at the same candidate with
        ``cells_per_weight`` replaced by the layer's *certified* cell
        count.  The "current" numbers therefore match the report's layer
        rows bit for bit (zero drift, property-tested), and the deltas
        are the area/energy a variable-cell (MSR-style) lowering of the
        ROADMAP's sub-4-bit item would provably unlock.
        """
        cert = self.certificate
        if self.precision != "int8":
            return {
                "available": False,
                "reason": "range certificates price cell storage; this "
                          "program stores fp32 weights",
            }
        rows = []
        for c in self.convs:
            entry = cert.layer(c.name)
            if entry is None or entry.certified_cells is None:
                continue
            cand = c.mapping if c.mapping is not None else MappingCandidate(
                rows=config.rows,
                cols=config.cols,
                cells_per_weight=config.cells_per_weight,
                ou_rows=config.ou_rows,
                ou_cols=config.ou_cols,
            )
            # an all-zero layer certifies 0 cells; it still occupies one
            # cell per weight in any real lowering
            certified = max(int(entry.certified_cells), 1)
            bits = np.asarray(c.pattern_bits, np.int64)
            windows = c.out_hw * c.out_hw
            ksize = c.kernel * c.kernel
            cur = mapping_cost(bits, cand, windows, ksize, energy)
            new = mapping_cost(
                bits,
                dataclasses.replace(cand, cells_per_weight=certified),
                windows, ksize, energy,
            )
            rows.append({
                "name": c.name,
                "stored_cells": cand.cells_per_weight,
                "certified_cells": certified,
                "area_cells": cur.area_cells,
                "certified_area_cells": new.area_cells,
                "energy_pj": cur.energy_pj,
                "certified_energy_pj": new.energy_pj,
                "cycles": cur.cycles,
                "certified_cycles": new.cycles,
            })
        area = float(sum(r["area_cells"] for r in rows))
        c_area = float(sum(r["certified_area_cells"] for r in rows))
        e_cur = float(sum(r["energy_pj"] for r in rows))
        c_e = float(sum(r["certified_energy_pj"] for r in rows))
        return {
            "available": True,
            "fp32_safe": bool(getattr(cert, "fp32_safe", True)),
            "input_range": [
                float(getattr(cert, "input_lo", 0.0)),
                float(getattr(cert, "input_hi", 0.0)),
            ],
            "layers": rows,
            "area_cells": int(area),
            "certified_area_cells": int(c_area),
            "energy_pj": e_cur,
            "certified_energy_pj": c_e,
            "area_win": area / max(c_area, 1e-9),
            "energy_win": e_cur / max(c_e, 1e-9),
        }

    def hardware_report(
        self,
        config: CrossbarConfig = CrossbarConfig(),
        energy: EnergyModel = EnergyModel(),
        skip_stats=None,
        assumed_skip: float | None = None,
        n_chips: int | None = None,
        observed: dict[str, float] | None = None,
    ) -> dict:
        """Price the compiled convs on the paper's crossbar model.

        Reuses ``core/mapping.map_layer`` (via ``simulate_layer``) on each
        layer's 3x3 pattern bits, so crossbar counts agree exactly with
        ``core/simulator.simulate_dataset`` for the same bits.

        Energy/cycle pricing comes in up to three flavours:

          * the no-skip upper bound (always; the historical ``energy_pj`` /
            ``cycles`` keys are unchanged);
          * *assumed*: a uniform scalar skip probability ``assumed_skip``
            applied to every OU row-group — the fallback when no
            activations have been observed;
          * *measured*: per-(channel, pattern) probabilities counted on
            real activations — pass an
            :class:`~repro.engine.stats.ActivationStats` (from
            ``make_forward(..., collect_stats=True)`` or
            ``InferenceService``) or a mapping of layer name to
            :class:`~repro.core.simulator.SkipDistribution`.

        When both are given, the ``skip`` section reports the
        measured-vs-assumed delta explicitly, so the gap between the
        statistical assumption and the realized zero pattern is a
        first-class output.  Layers without measured statistics fall back
        to the no-skip bound inside the measured totals; the ``skip``
        section's ``measured_layers`` lists which layers were actually
        observed, and per-layer rows only carry ``energy_pj_measured``
        when that layer was.

        ``observed`` maps layer names to *measured* per-layer seconds —
        the ``fn.observed_times()`` of a tracer-instrumented
        ``make_forward`` — and adds a ``drift`` section
        (``core/simulator.drift_table``): each layer's share of total
        predicted cycles vs its share of measured wall time, the
        per-layer drift between the two, and the implied
        seconds-per-cycle spread.  Predicted cycles use the
        measured-skip pricing when ``skip_stats`` is also given (so both
        sides of the comparison describe the same served traffic), else
        the no-skip bound.

        ``n_chips`` adds a ``chips`` section splitting crossbar area /
        energy / cycles over that many tile-parallel devices; with
        ``n_chips=None`` the view is derived from ``self.partition`` when
        the program carries one (model shards x data replicas).

        Mapping: a searched program (``compile_network(optimize=...)``)
        carries a per-layer :class:`~repro.core.mapping.MappingCandidate`
        — those layers are priced at their candidate's crossbar geometry
        and packing order (exactly the ``core/simulator.mapping_cost``
        numbers the search minimized) while the naive baseline stays at
        the reference ``config``.  The ``mapping`` section lists the
        per-layer candidates and the FC reorder; ``area_cells`` /
        ``naive_area_cells`` total crossbar area in *cells*, the unit
        that stays comparable when layers sit on different crossbar dims.

        Cell precision: for an int8 program the crossbar model's
        ``cells_per_weight`` is overridden with the cell-slice count the
        stored weights actually occupy (``ceil(8 / cell_bits)``) — the
        area/energy numbers price what the executor runs, not an assumed
        16-bit width; the ``precision`` section reports which happened.

        Certification: a program carrying a
        :class:`~repro.analysis.ranges.RangeCertificate` additionally
        gets a ``certified_potential`` section — each int8 conv re-priced
        at the *certified* minimum cells-per-weight its row-groups
        provably fit (``core/simulator.mapping_cost``, the same chain as
        the layer rows, so "current" numbers match them exactly) — the
        area/energy win an MSR-style variable-cell lowering would unlock.
        """
        stored_cells = self.cells_per_weight
        if stored_cells is not None and stored_cells != config.cells_per_weight:
            config = dataclasses.replace(
                config, cells_per_weight=stored_cells
            )
        syn = self._synthetic_layers()

        dists = {}
        if skip_stats is not None:
            # ActivationStats (engine/stats.py) or {name: SkipDistribution}
            per_layer = getattr(skip_stats, "layers", skip_stats)
            for c in self.convs:
                entry = per_layer.get(c.name)
                if entry is None:
                    continue
                to_dist = getattr(entry, "to_distribution", None)
                dists[c.name] = to_dist() if to_dist is not None else entry
        measured_windows = max(
            (int(getattr(d, "windows", 0)) for d in dists.values()),
            default=0,
        )

        # one mapping pass per layer, priced under every requested source;
        # a searched layer is priced at its own candidate geometry and
        # packing order, while the naive baseline stays at the reference
        # ``config`` so area ratios compare against the same yardstick
        layers, assumed, measured = [], [], []
        for c, layer in zip(self.convs, syn):
            sources = {"noskip": None}
            if assumed_skip is not None:
                sources["assumed"] = float(assumed_skip)
            if c.name in dists:
                sources["measured"] = dists[c.name]
            if c.mapping is not None:
                priced = simulate_layer_multi(
                    layer, sources, c.mapping.crossbar_config(), energy,
                    block_order=c.mapping.block_order, naive_config=config,
                )
            else:
                priced = simulate_layer_multi(layer, sources, config, energy)
            layers.append(priced["noskip"])
            assumed.append(priced.get("assumed"))
            measured.append(priced.get("measured", priced["noskip"])
                            if skip_stats is not None else None)
        has_assumed = assumed_skip is not None
        has_measured = skip_stats is not None

        def tot(results, attr):
            return float(sum(getattr(r, attr) for r in results))

        layer_rows = []
        for i, r in enumerate(layers):
            row = {
                "name": r.name,
                "crossbars": r.ours_crossbars,
                "naive_crossbars": r.naive_crossbars,
                "area_cells": r.ours_area_cells,
                "naive_area_cells": r.naive_area_cells,
                "energy_pj": r.ours_energy_pj,
                "cycles": r.ours_cycles,
                "utilization": r.utilization,
                "index_bits": r.index_bits,
                "stored_kernels": r.stored_kernels,
                "total_kernels": r.total_kernels,
            }
            if has_assumed:
                row["energy_pj_assumed"] = assumed[i].ours_energy_pj
                row["cycles_assumed"] = assumed[i].ours_cycles
            if self.convs[i].name in dists:
                row["energy_pj_measured"] = measured[i].ours_energy_pj
                row["cycles_measured"] = measured[i].ours_cycles
            layer_rows.append(row)

        rep = {
            "layers": layer_rows,
            "crossbars": int(tot(layers, "ours_crossbars")),
            "naive_crossbars": int(tot(layers, "naive_crossbars")),
            # area in *cells*: the comparable total once searched layers
            # sit on per-layer crossbar dims (a 128x128 crossbar is not a
            # 512x512, so raw crossbar counts stop being commensurable)
            "area_cells": int(tot(layers, "ours_area_cells")),
            "naive_area_cells": int(tot(layers, "naive_area_cells")),
            "area_efficiency": tot(layers, "naive_crossbars")
            / max(tot(layers, "ours_crossbars"), 1.0),
            "energy_pj": tot(layers, "ours_energy_pj"),
            "naive_energy_pj": tot(layers, "naive_energy_pj"),
            "cycles": tot(layers, "ours_cycles"),
            "index_kb": tot(layers, "index_bits") / 8.0 / 1024.0,
        }
        rep["mapping"] = {
            "optimized": any(c.mapping is not None for c in self.convs),
            "per_layer": {
                c.name: (None if c.mapping is None
                         else c.mapping.to_manifest())
                for c in self.convs
            },
            "fc_reorder": self.fc.reorder,
        }
        rep["precision"] = {
            "weights": self.precision,
            "weight_bits": WEIGHT_BITS if self.precision == "int8" else 32,
            "cell_bits": self.cell_bits,
            "cells_per_weight": config.cells_per_weight,
            "derived_from_storage": stored_cells is not None,
        }
        if self.certificate is not None:
            rep["certified_potential"] = self._certified_potential(
                config, energy
            )

        e_noskip = rep["energy_pj"]
        e_assumed = tot(assumed, "ours_energy_pj") if has_assumed else None
        e_measured = tot(measured, "ours_energy_pj") if has_measured else None
        if has_assumed:
            rep["energy_pj_assumed"] = e_assumed
            rep["cycles_assumed"] = tot(assumed, "ours_cycles")
        if has_measured:
            rep["energy_pj_measured"] = e_measured
            rep["cycles_measured"] = tot(measured, "ours_cycles")
        rep["skip"] = {
            "assumed_probability": assumed_skip,
            "measured_windows": measured_windows,
            "measured_layers": sorted(dists),
            "energy_pj_noskip": e_noskip,
            "energy_pj_assumed": e_assumed,
            "energy_pj_measured": e_measured,
            "measured_discount": (
                None if e_measured is None
                else 1.0 - e_measured / max(e_noskip, 1e-9)
            ),
            "measured_vs_assumed_delta_pj": (
                None if e_measured is None or e_assumed is None
                else e_measured - e_assumed
            ),
            "measured_vs_assumed_delta_frac": (
                None if e_measured is None or e_assumed is None
                else (e_measured - e_assumed) / max(e_assumed, 1e-9)
            ),
        }
        if observed:
            # predicted cycles per layer: measured-skip priced when skip
            # statistics exist for the layer, else the no-skip bound
            predicted = {}
            for i, r in enumerate(layers):
                src = measured[i] if self.convs[i].name in dists else r
                predicted[r.name] = src.ours_cycles
            rep["drift"] = drift_table(
                predicted, {k: float(v) for k, v in observed.items()}
            )
        if n_chips is not None:
            rep["chips"] = self._chips_view(layers, int(n_chips), 1)
        elif self.partition is not None:
            rep["chips"] = self._chips_view(
                layers, self.partition.model, self.partition.data
            )
        return rep
