"""Continuous-batching slot scheduler: the serving control plane.

Extracted from the control-plane skeleton of ``runtime/serve.py``'s
``ServeLoop`` so both serving front ends — token generation there,
classification in ``engine/service.py`` — share one scheduler instead of
each reimplementing (and subtly breaking) queue/slot bookkeeping:

  * a FIFO **request queue** with optional backpressure (``max_queue``;
    :meth:`SlotScheduler.submit` raises :class:`SchedulerFull`,
    :meth:`SlotScheduler.try_submit` returns ``False``),
  * a fixed number of **batch slots**: the executing batch always has the
    same shape, so the jitted forward is traced exactly once; free slots
    are *dead* and carried as ``False`` entries of :meth:`valid_mask`,
  * **continuous refill**: :meth:`refill` admits queued requests into
    free slots the moment they free up — mid-flight for workloads whose
    requests finish at different times, per batch for one-shot workloads,
  * **metrics**: per-request enqueue->done latency and per-step slot
    occupancy (:class:`SchedulerMetrics`), measured against an injectable
    monotonic ``clock`` so tests can pin time.

The scheduler is deliberately execution-agnostic: it never touches
arrays.  The caller owns the batch buffer, writes admitted payloads into
the slots :meth:`refill` hands out, runs its jitted step, and reports
completions back via :meth:`complete`.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import numpy as np

__all__ = ["SchedulerFull", "SchedulerMetrics", "SlotScheduler"]


class SchedulerFull(RuntimeError):
    """Raised by :meth:`SlotScheduler.submit` when the bounded queue is
    full — the backpressure signal a front end turns into HTTP 429/503."""


@dataclasses.dataclass
class SchedulerMetrics:
    """Counters the scheduler accumulates while serving.

    ``occupancy_sum`` adds the live-slot count once per recorded step, so
    ``occupancy_mean`` is the average fraction of the fixed batch shape
    doing useful work; latencies are enqueue->done wall-clock seconds.
    """

    batch_slots: int
    enqueued: int = 0
    completed: int = 0
    rejected: int = 0
    steps: int = 0
    occupancy_sum: int = 0
    latency_sum: float = 0.0
    latency_max: float = 0.0

    @property
    def occupancy_mean(self) -> float:
        """Mean live fraction of the batch over recorded steps, in [0, 1]."""
        if self.steps == 0:
            return 0.0
        return self.occupancy_sum / (self.steps * self.batch_slots)

    @property
    def latency_mean(self) -> float:
        if self.completed == 0:
            return 0.0
        return self.latency_sum / self.completed

    def snapshot(self) -> dict:
        return {
            "batch_slots": self.batch_slots,
            "enqueued": self.enqueued,
            "completed": self.completed,
            "rejected": self.rejected,
            "steps": self.steps,
            "occupancy_mean": self.occupancy_mean,
            "latency_mean_s": self.latency_mean,
            "latency_max_s": self.latency_max,
        }


class SlotScheduler:
    """Fixed-slot continuous-batching scheduler (queue + slots + metrics).

    Args:
      batch_slots: number of slots in the fixed batch shape.
      max_queue: queued-request bound; 0 means unbounded.  Requests beyond
        the bound are rejected (``submit`` raises, ``try_submit`` returns
        ``False``) — requests already admitted to slots don't count.
      clock: monotonic time source for latency metrics (injectable so
        tests are deterministic).
    """

    def __init__(
        self,
        batch_slots: int,
        max_queue: int = 0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.batch_slots = batch_slots
        self.max_queue = max_queue
        self._clock = clock
        self._queue: deque[tuple[Any, float]] = deque()
        self._slots: list[Any | None] = [None] * batch_slots
        self._enq_time: list[float] = [0.0] * batch_slots
        self.metrics = SchedulerMetrics(batch_slots=batch_slots)

    # ------------------------------------------------------------- admission

    def has_capacity(self) -> bool:
        """Whether the queue can accept a request right now — a probe
        that, unlike :meth:`try_submit`, does not count a rejection."""
        return not self.max_queue or len(self._queue) < self.max_queue

    def try_submit(self, item: Any) -> bool:
        """Enqueue ``item``; ``False`` (and a rejected tick) when full."""
        if not self.has_capacity():
            self.metrics.rejected += 1
            return False
        self._queue.append((item, self._clock()))
        self.metrics.enqueued += 1
        return True

    def submit(self, item: Any) -> None:
        """Enqueue ``item``; raise :class:`SchedulerFull` when full."""
        if not self.try_submit(item):
            raise SchedulerFull(
                f"request queue full ({len(self._queue)}/{self.max_queue})"
            )

    def refill(self) -> list[tuple[int, Any]]:
        """Admit queued requests into free slots, lowest slot first.

        Returns the ``(slot, item)`` pairs admitted *now*; the caller
        writes their payloads into exactly those batch rows.
        """
        admitted = []
        for i in range(self.batch_slots):
            if self._slots[i] is None and self._queue:
                item, t_enq = self._queue.popleft()
                self._slots[i] = item
                self._enq_time[i] = t_enq
                admitted.append((i, item))
        return admitted

    # ------------------------------------------------------------- occupancy

    def live(self) -> list[tuple[int, Any]]:
        """The currently occupied ``(slot, item)`` pairs."""
        return [(i, it) for i, it in enumerate(self._slots) if it is not None]

    def valid_mask(self) -> np.ndarray:
        """Bool [batch_slots]: which rows of the fixed batch are live."""
        return np.array([s is not None for s in self._slots], bool)

    def queued(self) -> int:
        return len(self._queue)

    def reset_metrics(self) -> None:
        """Start a fresh metrics window (e.g. after a warm-up batch).

        In-flight requests keep their original enqueue times, so their
        latencies land in the new window when they complete.
        """
        self.metrics = SchedulerMetrics(batch_slots=self.batch_slots)

    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    # ------------------------------------------------------------ completion

    def record_step(self) -> None:
        """Account one executed batch step at the current occupancy."""
        self.metrics.steps += 1
        self.metrics.occupancy_sum += sum(
            1 for s in self._slots if s is not None
        )

    def complete(self, slot: int) -> Any:
        """Free ``slot``, record its request's latency, return the item."""
        item = self._slots[slot]
        if item is None:
            raise ValueError(f"slot {slot} is not occupied")
        self._slots[slot] = None
        latency = max(self._clock() - self._enq_time[slot], 0.0)
        self.metrics.completed += 1
        self.metrics.latency_sum += latency
        self.metrics.latency_max = max(self.metrics.latency_max, latency)
        return item
