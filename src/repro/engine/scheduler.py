"""Continuous-batching slot scheduler: the serving control plane.

Extracted from the control-plane skeleton of ``runtime/serve.py``'s
``ServeLoop`` so both serving front ends — token generation there,
classification in ``engine/service.py`` — share one scheduler instead of
each reimplementing (and subtly breaking) queue/slot bookkeeping:

  * a FIFO **request queue** with optional backpressure (``max_queue``;
    :meth:`SlotScheduler.submit` raises :class:`SchedulerFull`,
    :meth:`SlotScheduler.try_submit` returns ``False``),
  * a fixed number of **batch slots**: the executing batch always has the
    same shape, so the jitted forward is traced exactly once; free slots
    are *dead* and carried as ``False`` entries of :meth:`valid_mask`,
  * **continuous refill**: :meth:`refill` admits queued requests into
    free slots the moment they free up — mid-flight for workloads whose
    requests finish at different times, per batch for one-shot workloads,
  * **metrics**: per-request enqueue->done latency — histogram-backed, so
    :meth:`SchedulerMetrics.snapshot` carries exact p50/p99 next to the
    mean, split into queue wait (enqueue->admit) vs in-flight
    (admit->done) — and per-step slot occupancy, measured against an
    injectable monotonic ``clock`` so tests can pin time,
  * **tracing**: given a :class:`~repro.obs.trace.Tracer`, every request
    becomes an async span (enqueue -> admit -> done) and queue depth /
    live slots become counter tracks, landing request lifecycles on the
    same Perfetto timeline as compile phases and layer execution.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Any, Callable

import numpy as np

from repro.obs.metrics import LATENCY_BUCKETS_S, Histogram
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = ["SchedulerFull", "SchedulerMetrics", "SlotScheduler"]


class SchedulerFull(RuntimeError):
    """Raised by :meth:`SlotScheduler.submit` when the bounded queue is
    full — the backpressure signal a front end turns into HTTP 429/503."""


def _latency_hist() -> Histogram:
    return Histogram(buckets=LATENCY_BUCKETS_S)


@dataclasses.dataclass
class SchedulerMetrics:
    """Counters the scheduler accumulates while serving.

    ``occupancy_sum`` adds the live-slot count once per recorded step, so
    ``occupancy_mean`` is the average fraction of the fixed batch shape
    doing useful work.  Latencies are enqueue->done wall-clock seconds,
    recorded into an exact-percentile histogram
    (``obs/metrics.Histogram``) and broken down into queue wait
    (enqueue->admit, recorded at admission over ``admitted`` requests)
    vs in-flight time (admit->done, recorded at completion).
    """

    batch_slots: int
    enqueued: int = 0
    admitted: int = 0
    completed: int = 0
    rejected: int = 0
    steps: int = 0
    occupancy_sum: int = 0
    latency_sum: float = 0.0
    latency_max: float = 0.0
    queue_wait_sum: float = 0.0
    in_flight_sum: float = 0.0
    first_results: int = 0
    first_result_sum: float = 0.0
    latency_hist: Histogram = dataclasses.field(
        default_factory=_latency_hist, repr=False, compare=False
    )
    queue_wait_hist: Histogram = dataclasses.field(
        default_factory=_latency_hist, repr=False, compare=False
    )
    first_result_hist: Histogram = dataclasses.field(
        default_factory=_latency_hist, repr=False, compare=False
    )

    @property
    def occupancy_mean(self) -> float:
        """Mean live fraction of the batch over recorded steps, in [0, 1]."""
        if self.steps == 0:
            return 0.0
        return self.occupancy_sum / (self.steps * self.batch_slots)

    @property
    def latency_mean(self) -> float:
        if self.completed == 0:
            return 0.0
        return self.latency_sum / self.completed

    @property
    def latency_p50(self) -> float:
        return self.latency_hist.percentile(50)

    @property
    def latency_p99(self) -> float:
        return self.latency_hist.percentile(99)

    @property
    def queue_wait_mean(self) -> float:
        if self.admitted == 0:
            return 0.0
        return self.queue_wait_sum / self.admitted

    @property
    def in_flight_mean(self) -> float:
        if self.completed == 0:
            return 0.0
        return self.in_flight_sum / self.completed

    @property
    def first_result_mean(self) -> float:
        if self.first_results == 0:
            return 0.0
        return self.first_result_sum / self.first_results

    def record_admit(self, queue_wait: float) -> None:
        self.admitted += 1
        self.queue_wait_sum += queue_wait
        self.queue_wait_hist.observe(queue_wait)

    def record_first_result(self, latency: float) -> None:
        """Enqueue->first-result SLO latency: time to the first usable
        output (first decode token for generation; the completed logits
        for single-step classification)."""
        self.first_results += 1
        self.first_result_sum += latency
        self.first_result_hist.observe(latency)

    def record_complete(self, latency: float, in_flight: float) -> None:
        self.completed += 1
        self.latency_sum += latency
        self.latency_max = max(self.latency_max, latency)
        self.latency_hist.observe(latency)
        self.in_flight_sum += in_flight

    def snapshot(self) -> dict:
        return {
            "batch_slots": self.batch_slots,
            "enqueued": self.enqueued,
            "admitted": self.admitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "steps": self.steps,
            "occupancy_mean": self.occupancy_mean,
            "latency_mean_s": self.latency_mean,
            "latency_max_s": self.latency_max,
            "latency_p50_s": self.latency_p50,
            "latency_p99_s": self.latency_p99,
            "queue_wait_mean_s": self.queue_wait_mean,
            "queue_wait_p99_s": self.queue_wait_hist.percentile(99),
            "in_flight_mean_s": self.in_flight_mean,
            "first_result_mean_s": self.first_result_mean,
            "first_result_p50_s": self.first_result_hist.percentile(50),
            "first_result_p99_s": self.first_result_hist.percentile(99),
        }

    def to_prometheus(self, prefix: str = "scheduler") -> str:
        """Prometheus text exposition of the current window — what an RPC
        front end returns from its ``/metrics`` endpoint."""
        lines = []
        scalars = {
            "batch_slots": ("gauge", self.batch_slots),
            "enqueued_total": ("counter", self.enqueued),
            "admitted_total": ("counter", self.admitted),
            "completed_total": ("counter", self.completed),
            "rejected_total": ("counter", self.rejected),
            "steps_total": ("counter", self.steps),
            "occupancy_mean": ("gauge", self.occupancy_mean),
        }
        for name, (kind, value) in scalars.items():
            full = f"{prefix}_{name}"
            lines.append(f"# TYPE {full} {kind}")
            lines.append(f"{full} {value}")
        lines.extend(self.latency_hist.prom_lines(f"{prefix}_latency_seconds"))
        lines.extend(
            self.queue_wait_hist.prom_lines(f"{prefix}_queue_wait_seconds")
        )
        lines.extend(
            self.first_result_hist.prom_lines(
                f"{prefix}_first_result_seconds"
            )
        )
        return "\n".join(lines) + "\n"


class SlotScheduler:
    """Fixed-slot continuous-batching scheduler (queue + slots + metrics).

    Args:
      batch_slots: number of slots in the fixed batch shape.
      max_queue: queued-request bound; 0 means unbounded.  Requests beyond
        the bound are rejected (``submit`` raises, ``try_submit`` returns
        ``False``) — requests already admitted to slots don't count.
      clock: monotonic time source for latency metrics (injectable so
        tests are deterministic).
      tracer: optional span tracer; each request becomes an async
        "request" span from enqueue to completion with an admission
        instant, and queue depth / live slots are emitted as counter
        tracks.  ``None`` resolves to the shared no-op tracer.

    Thread safety: every public method takes one internal re-entrant
    lock, so an async front end may ``try_submit`` from its event loop
    while a worker thread steps/refills/completes and a scraper calls
    :meth:`snapshot` — counters and slot bookkeeping stay consistent.
    (The histograms carry their own locks; ``reset_metrics`` swapping
    the metrics object is atomic under the same lock.)
    """

    def __init__(
        self,
        batch_slots: int,
        max_queue: int = 0,
        clock: Callable[[], float] = time.monotonic,
        tracer: Tracer | None = None,
    ):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.batch_slots = batch_slots
        self.max_queue = max_queue
        self._clock = clock
        self._tracer = tracer or NULL_TRACER
        self._lock = threading.RLock()
        self._queue: deque[tuple[Any, float, int]] = deque()
        self._slots: list[Any | None] = [None] * batch_slots
        self._enq_time: list[float] = [0.0] * batch_slots
        self._admit_time: list[float] = [0.0] * batch_slots
        self._slot_rid: list[int] = [0] * batch_slots
        self._first_done: list[bool] = [True] * batch_slots
        self._rid_seq = 0  # request-id sequence for the trace's async spans
        self._last_step_t: float | None = None
        self._step_ewma: float = 0.0  # smoothed inter-step wall time
        self.metrics = SchedulerMetrics(batch_slots=batch_slots)

    # ------------------------------------------------------------- admission

    def has_capacity(self) -> bool:
        """Whether the queue can accept a request right now — a probe
        that, unlike :meth:`try_submit`, does not count a rejection."""
        with self._lock:
            return not self.max_queue or len(self._queue) < self.max_queue

    def try_submit(self, item: Any) -> bool:
        """Enqueue ``item``; ``False`` (and a rejected tick) when full."""
        with self._lock:
            if not (not self.max_queue or len(self._queue) < self.max_queue):
                self.metrics.rejected += 1
                self._tracer.instant("request_rejected", cat="request")
                return False
            self._rid_seq += 1
            rid = self._rid_seq
            self._queue.append((item, self._clock(), rid))
            self.metrics.enqueued += 1
            self._tracer.async_begin("request", rid, cat="request")
            self._emit_counters()
            return True

    def resubmit(self, item: Any) -> None:
        """Re-enqueue already-admitted work at the *front* of the queue.

        The priority lane for load shedding: work the service already
        accepted (e.g. an in-flight slot replayed after a fault, or a
        request bumped out of a slot) must never compete with — or be
        shed in favour of — brand-new arrivals, so it bypasses
        ``max_queue`` and is admitted before anything behind it.
        """
        with self._lock:
            self._rid_seq += 1
            rid = self._rid_seq
            self._queue.appendleft((item, self._clock(), rid))
            self.metrics.enqueued += 1
            self._tracer.async_begin("request", rid, cat="request")
            self._emit_counters()

    def submit(self, item: Any) -> None:
        """Enqueue ``item``; raise :class:`SchedulerFull` when full."""
        if not self.try_submit(item):
            raise SchedulerFull(
                f"request queue full ({len(self._queue)}/{self.max_queue})"
            )

    def refill(self) -> list[tuple[int, Any]]:
        """Admit queued requests into free slots, lowest slot first.

        Returns the ``(slot, item)`` pairs admitted *now*; the caller
        writes their payloads into exactly those batch rows.
        """
        with self._lock:
            admitted = []
            for i in range(self.batch_slots):
                if self._slots[i] is None and self._queue:
                    item, t_enq, rid = self._queue.popleft()
                    now = self._clock()
                    self._slots[i] = item
                    self._enq_time[i] = t_enq
                    self._admit_time[i] = now
                    self._slot_rid[i] = rid
                    self._first_done[i] = False
                    self.metrics.record_admit(max(now - t_enq, 0.0))
                    self._tracer.async_instant(
                        "request", rid, cat="request", event="admit", slot=i
                    )
                    admitted.append((i, item))
            if admitted:
                self._emit_counters()
            return admitted

    # ------------------------------------------------------------- occupancy

    def live(self) -> list[tuple[int, Any]]:
        """The currently occupied ``(slot, item)`` pairs."""
        with self._lock:
            return [
                (i, it) for i, it in enumerate(self._slots) if it is not None
            ]

    def valid_mask(self) -> np.ndarray:
        """Bool [batch_slots]: which rows of the fixed batch are live."""
        with self._lock:
            return np.array([s is not None for s in self._slots], bool)

    def queued(self) -> int:
        with self._lock:
            return len(self._queue)

    def slot_rid(self, slot: int) -> int:
        """The trace async-span id of the request occupying ``slot``."""
        with self._lock:
            return self._slot_rid[slot]

    def reset_metrics(self) -> None:
        """Start a fresh metrics window (e.g. after a warm-up batch).

        In-flight requests are *re-anchored* to the reset instant: their
        enqueue/admit timestamps become "now", so when they eventually
        complete they contribute only their post-reset time to the fresh
        window instead of dragging pre-reset wait in with them.
        """
        with self._lock:
            now = self._clock()
            for i, s in enumerate(self._slots):
                if s is not None:
                    self._enq_time[i] = now
                    self._admit_time[i] = now
            self._last_step_t = None
            self.metrics = SchedulerMetrics(batch_slots=self.batch_slots)

    def snapshot(self) -> dict:
        """Consistent point-in-time metrics dict (equivalent to
        ``scheduler.metrics.snapshot()`` but taken under the scheduler
        lock, so a concurrent ``reset_metrics`` can't swap the object
        mid-read)."""
        with self._lock:
            return self.metrics.snapshot()

    def has_work(self) -> bool:
        with self._lock:
            return bool(self._queue) or any(
                s is not None for s in self._slots
            )

    def retry_after_hint(self) -> float:
        """Backpressure-derived retry hint in seconds for shed requests.

        Estimates how long until the queue has drained enough to accept
        new work: full-queue depth in units of batch_slots-sized waves,
        times the smoothed inter-step wall time (falling back to 50ms
        before any step has run).  Clamped to [1ms, 60s].
        """
        with self._lock:
            step = self._step_ewma if self._step_ewma > 0 else 0.05
            waves = max(1, math.ceil((len(self._queue) + 1)
                                     / self.batch_slots))
            return float(min(max(waves * step, 1e-3), 60.0))

    # ------------------------------------------------------------ completion

    def record_step(self) -> None:
        """Account one executed batch step at the current occupancy."""
        with self._lock:
            now = self._clock()
            if self._last_step_t is not None:
                dur = max(now - self._last_step_t, 0.0)
                self._step_ewma = (
                    dur if self._step_ewma == 0.0
                    else 0.8 * self._step_ewma + 0.2 * dur
                )
            self._last_step_t = now
            self.metrics.steps += 1
            live = sum(1 for s in self._slots if s is not None)
            self.metrics.occupancy_sum += live
            self._tracer.counter("scheduler/slots_live", live=live)

    def record_first_result(self, slot: int) -> None:
        """Record the enqueue->first-result latency for ``slot`` (e.g.
        the first decode token landing).  Idempotent per occupancy;
        :meth:`complete` falls back to recording it for single-step
        workloads that never call this."""
        with self._lock:
            if self._first_done[slot] or self._slots[slot] is None:
                return
            self._first_done[slot] = True
            now = self._clock()
            self.metrics.record_first_result(
                max(now - self._enq_time[slot], 0.0)
            )
            self._tracer.async_instant(
                "request", self._slot_rid[slot], cat="request",
                event="first_result", slot=slot,
            )

    def complete(self, slot: int) -> Any:
        """Free ``slot``, record its request's latency, return the item."""
        with self._lock:
            item = self._slots[slot]
            if item is None:
                raise ValueError(f"slot {slot} is not occupied")
            if not self._first_done[slot]:
                self.record_first_result(slot)
            self._slots[slot] = None
            self._first_done[slot] = True
            now = self._clock()
            latency = max(now - self._enq_time[slot], 0.0)
            in_flight = max(now - self._admit_time[slot], 0.0)
            self.metrics.record_complete(latency, in_flight)
            self._tracer.async_end(
                "request", self._slot_rid[slot], cat="request"
            )
            self._emit_counters()
            return item

    def _emit_counters(self) -> None:
        t = self._tracer
        if not t.enabled:
            return
        t.counter("scheduler/queue_depth", queued=len(self._queue))
        t.counter(
            "scheduler/slots_live",
            live=sum(1 for s in self._slots if s is not None),
        )
