"""Persist compiled programs so compilation is paid once per model.

Layout mirrors ``checkpoint/checkpointer.py``: one ``.npy`` per array plus
a fsynced ``program.json`` manifest, written into a ``.tmp`` directory and
``os.replace``d only when complete, so a crashed writer never leaves a
half-written program that a loader would pick up.  The round trip is
bit-exact: every array is stored verbatim (float payloads as fp32,
quantized payloads as int8 with their fp32 row-group scales, index
streams as int32/int64).  A ``CompiledNetwork.partition``
(``engine/partition.py``) rides along in the manifest, so a program
partitioned for an N-chip mesh reloads ready to serve from one; the
stored ``precision`` / ``cell_bits`` reload the same way (format v2 —
v1 programs load as fp32).  Format v3 adds the searched mapping
metadata: an optional per-conv ``mapping``
(:meth:`~repro.core.mapping.MappingCandidate.to_manifest`) and the FC
``reorder`` tag — v1/v2 programs load with no mapping and the
'pattern' reorder (the fixed scheme), so old artifacts keep their
historical pricing.  Format v4 adds the optional range
``certificate`` (:class:`~repro.analysis.ranges.RangeCertificate`):
v1-v3 programs load with ``certificate=None``, and only its structure
is checked here (M003) — whether the certificate still matches the
payloads is the certification pass's job (V506).
"""

from __future__ import annotations

import json
import os
import shutil

import jax.numpy as jnp
import numpy as np

from repro.analysis.diagnostics import ProgramFormatError
from repro.core.mapping import MappingCandidate
from repro.core.sparse import BlockPatternWeight
from repro.engine.partition import NetworkPartition
from repro.engine.program import CompiledConv, CompiledFC, CompiledNetwork
from repro.models.cnn import CNNConfig

__all__ = [
    "save_program",
    "load_program",
    "read_manifest",
    "validate_manifest",
    "ProgramFormatError",
]

_MANIFEST = "program.json"
# v2 adds precision/cell_bits + per-bp w_scales; v3 adds per-conv
# mapping candidates + the fc reorder tag; v4 adds the optional range
# certificate
_FORMAT_VERSION = 4
_SUPPORTED_VERSIONS = (1, 2, 3, 4)


def _save_array(directory: str, name: str, arr) -> str:
    fname = f"{name}.npy"
    with open(os.path.join(directory, fname), "wb") as f:
        np.save(f, np.asarray(arr))
        f.flush()
        os.fsync(f.fileno())
    return fname


def _bp_manifest(prefix: str, bp: BlockPatternWeight, directory: str) -> dict:
    fields = ["w_comp", "block_ids", "nnz", "new_order", "inv_order",
              "dict_masks"]
    if bp.w_scales is not None:
        fields.append("w_scales")
    return {
        "k_in": bp.k_in,
        "n_out": bp.n_out,
        "block": bp.block,
        "tile": bp.tile,
        "arrays": {
            field: _save_array(directory, f"{prefix}.{field}", getattr(bp, field))
            for field in fields
        },
    }


def _load_bp(entry: dict, directory: str) -> BlockPatternWeight:
    def arr(field):
        return np.load(os.path.join(directory, entry["arrays"][field]))

    has_scales = "w_scales" in entry["arrays"]
    return BlockPatternWeight(
        w_comp=jnp.asarray(arr("w_comp")),
        block_ids=jnp.asarray(arr("block_ids")),
        nnz=arr("nnz"),
        new_order=arr("new_order"),
        inv_order=arr("inv_order"),
        k_in=int(entry["k_in"]),
        n_out=int(entry["n_out"]),
        block=int(entry["block"]),
        tile=int(entry["tile"]),
        dict_masks=arr("dict_masks"),
        w_scales=jnp.asarray(arr("w_scales")) if has_scales else None,
    )


def save_program(directory: str, program: CompiledNetwork) -> str:
    """Atomically write ``program`` under ``directory``.  Returns the path."""
    parent = os.path.dirname(os.path.abspath(directory))
    os.makedirs(parent, exist_ok=True)
    tmp = directory.rstrip("/") + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    cfg = program.config
    manifest = {
        "format_version": _FORMAT_VERSION,
        "block": program.block,
        "tile": program.tile,
        "precision": program.precision,
        "cell_bits": program.cell_bits,
        "config": {
            "conv_channels": [list(c) for c in cfg.conv_channels],
            "pool_after": sorted(cfg.pool_after),
            "num_classes": cfg.num_classes,
            "input_hw": cfg.input_hw,
            "kernel": cfg.kernel,
        },
        "convs": [],
    }
    if program.partition is not None:
        manifest["partition"] = program.partition.to_manifest()
    if getattr(program, "certificate", None) is not None:
        manifest["certificate"] = program.certificate.to_manifest()
    for c in program.convs:
        manifest["convs"].append(
            {
                "name": c.name,
                "c_in": c.c_in,
                "c_out": c.c_out,
                "kernel": c.kernel,
                "out_hw": c.out_hw,
                "pool_after": c.pool_after,
                "bias": _save_array(tmp, f"{c.name}.bias", c.bias),
                "pattern_bits": _save_array(
                    tmp, f"{c.name}.pattern_bits", c.pattern_bits
                ),
                "bp": _bp_manifest(c.name, c.bp, tmp),
                "mapping": (
                    None if c.mapping is None else c.mapping.to_manifest()
                ),
            }
        )
    manifest["fc"] = {
        "d_in": program.fc.d_in,
        "d_out": program.fc.d_out,
        "bias": _save_array(tmp, "fc.bias", program.fc.bias),
        "bp": _bp_manifest("fc", program.fc.bp, tmp),
        "reorder": program.fc.reorder,
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    # never delete the previous program before the new one is in place:
    # move it aside, swap in the new directory, then drop the old copy
    old = directory.rstrip("/") + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(directory):
        os.replace(directory, old)
    os.replace(tmp, directory)
    if os.path.exists(old):
        shutil.rmtree(old)
    return directory


def _resolve_directory(directory: str) -> str:
    """Fall back to ``<directory>.old`` when the target has no manifest —
    a save interrupted between the two swap renames leaves the previous
    complete program there, so a restarting service still has a model."""
    if not os.path.exists(os.path.join(directory, _MANIFEST)):
        old = directory.rstrip("/") + ".old"
        if os.path.exists(os.path.join(old, _MANIFEST)):
            return old
    return directory


def read_manifest(directory: str) -> dict:
    """Read the manifest JSON, raising :class:`ProgramFormatError` (M001)
    instead of an opaque OSError/JSONDecodeError."""
    path = os.path.join(_resolve_directory(directory), _MANIFEST)
    try:
        with open(path) as f:
            manifest = json.load(f)
    except OSError as e:
        raise ProgramFormatError(
            f"program manifest unreadable: {path}: {e}", rule="M001"
        ) from e
    except ValueError as e:
        raise ProgramFormatError(
            f"program manifest is not valid JSON: {path}: {e}", rule="M001"
        ) from e
    if not isinstance(manifest, dict):
        raise ProgramFormatError(
            f"program manifest is not a JSON object: {path}", rule="M001"
        )
    return manifest


_BP_ARRAY_FIELDS = ("w_comp", "block_ids", "nnz", "new_order", "inv_order",
                    "dict_masks")
_CONFIG_KEYS = ("conv_channels", "pool_after", "num_classes", "input_hw",
                "kernel")
_CONV_KEYS = ("name", "c_in", "c_out", "kernel", "out_hw", "pool_after",
              "bias", "pattern_bits", "bp")
_MAPPING_KEYS = ("rows", "cols", "cells_per_weight", "ou_rows", "ou_cols",
                 "block_order", "reorder")
_CERT_KEYS = ("input_lo", "input_hi", "precision", "cell_bits",
              "fp32_safe", "layers")
_CERT_LAYER_KEYS = ("name", "pre_lo", "pre_hi", "act_lo", "act_hi")


def _require(entry: dict, keys, where: str) -> None:
    missing = [k for k in keys if k not in entry]
    if missing:
        raise ProgramFormatError(
            f"program manifest {where} is missing key(s) "
            f"{', '.join(missing)}", rule="M003"
        )


def _check_mapping_entry(entry, where: str) -> None:
    """Structural (M003) check of a v3 ``mapping`` entry.

    Only types and keys are checked here — *validity* of the tags and
    dims against the packed operands is the static verifier's job
    (V205/V206), so a structurally sound but semantically corrupt save
    surfaces as a diagnostic after load, not a format error."""
    if entry is None:
        return
    if not isinstance(entry, dict):
        raise ProgramFormatError(
            f"program manifest {where} must be an object or null",
            rule="M003",
        )
    _require(entry, _MAPPING_KEYS, where)
    for k in ("rows", "cols", "cells_per_weight", "ou_rows", "ou_cols"):
        if not isinstance(entry[k], int) or isinstance(entry[k], bool):
            raise ProgramFormatError(
                f"program manifest {where}.{k} must be an integer",
                rule="M003",
            )
    for k in ("block_order", "reorder"):
        if not isinstance(entry[k], str):
            raise ProgramFormatError(
                f"program manifest {where}.{k} must be a string",
                rule="M003",
            )


def _check_certificate_entry(entry, where: str) -> None:
    """Structural (M003) check of a v4 range ``certificate`` entry.

    Like :func:`_check_mapping_entry`, only keys and types are enforced
    here — whether the certified bounds and cell table still match the
    payloads is the certification pass's V506, so a structurally sound
    but stale certificate surfaces as a diagnostic after load."""
    if entry is None:
        return
    if not isinstance(entry, dict):
        raise ProgramFormatError(
            f"program manifest {where} must be an object or null",
            rule="M003",
        )
    _require(entry, _CERT_KEYS, where)
    for k in ("input_lo", "input_hi"):
        if not isinstance(entry[k], (int, float)) or isinstance(
            entry[k], bool
        ):
            raise ProgramFormatError(
                f"program manifest {where}.{k} must be a number",
                rule="M003",
            )
    if not isinstance(entry["precision"], str):
        raise ProgramFormatError(
            f"program manifest {where}.precision must be a string",
            rule="M003",
        )
    if not isinstance(entry["cell_bits"], int) or isinstance(
        entry["cell_bits"], bool
    ):
        raise ProgramFormatError(
            f"program manifest {where}.cell_bits must be an integer",
            rule="M003",
        )
    layers = entry["layers"]
    if not isinstance(layers, list):
        raise ProgramFormatError(
            f"program manifest {where}.layers must be a list", rule="M003"
        )
    for i, e in enumerate(layers):
        lwhere = f"{where}.layers[{i}]"
        if not isinstance(e, dict):
            raise ProgramFormatError(
                f"program manifest {lwhere} must be an object", rule="M003"
            )
        _require(e, _CERT_LAYER_KEYS, lwhere)
        mc = e.get("min_cells")
        if mc is not None and not isinstance(mc, list):
            raise ProgramFormatError(
                f"program manifest {lwhere}.min_cells must be a list or "
                "null", rule="M003"
            )


def _check_bp_entry(entry: dict, directory: str, where: str) -> None:
    if not isinstance(entry, dict):
        raise ProgramFormatError(
            f"program manifest {where} must be an object", rule="M003"
        )
    _require(entry, ("k_in", "n_out", "block", "tile", "arrays"), where)
    arrays = entry["arrays"]
    if not isinstance(arrays, dict):
        raise ProgramFormatError(
            f"program manifest {where}.arrays must be an object", rule="M003"
        )
    _require(arrays, _BP_ARRAY_FIELDS, f"{where}.arrays")
    for field, fname in arrays.items():
        if not isinstance(fname, str) or not os.path.exists(
            os.path.join(directory, fname)
        ):
            raise ProgramFormatError(
                f"payload file for {where}.arrays.{field} missing: "
                f"{fname!r}", rule="M004"
            )


def validate_manifest(manifest: dict, directory: str) -> None:
    """Validate manifest version, keys, and payload files *before* any
    array is constructed.  Raises :class:`ProgramFormatError` on the
    first problem; returns None when the manifest is loadable."""
    directory = _resolve_directory(directory)
    version = manifest.get("format_version")
    if version not in _SUPPORTED_VERSIONS:
        raise ProgramFormatError(
            f"unsupported program format version {version!r} "
            f"(supported: {_SUPPORTED_VERSIONS})", rule="M002"
        )
    _require(manifest, ("block", "tile", "config", "convs", "fc"), "root")
    cfg = manifest["config"]
    if not isinstance(cfg, dict):
        raise ProgramFormatError(
            "program manifest config must be an object", rule="M003"
        )
    _require(cfg, _CONFIG_KEYS, "config")
    convs = manifest["convs"]
    if not isinstance(convs, list):
        raise ProgramFormatError(
            "program manifest convs must be a list", rule="M003"
        )
    if manifest.get("precision", "fp32") not in ("fp32", "int8"):
        raise ProgramFormatError(
            f"unknown precision {manifest.get('precision')!r}", rule="M003"
        )
    for i, e in enumerate(convs):
        where = f"convs[{i}]"
        if not isinstance(e, dict):
            raise ProgramFormatError(
                f"program manifest {where} must be an object", rule="M003"
            )
        _require(e, _CONV_KEYS, where)
        for field in ("bias", "pattern_bits"):
            fname = e[field]
            if not isinstance(fname, str) or not os.path.exists(
                os.path.join(directory, fname)
            ):
                raise ProgramFormatError(
                    f"payload file for {where}.{field} missing: "
                    f"{fname!r}", rule="M004"
                )
        _check_bp_entry(e["bp"], directory, f"{where}.bp")
        _check_mapping_entry(e.get("mapping"), f"{where}.mapping")
    fce = manifest["fc"]
    if not isinstance(fce, dict):
        raise ProgramFormatError(
            "program manifest fc must be an object", rule="M003"
        )
    _require(fce, ("d_in", "d_out", "bias", "bp"), "fc")
    if not isinstance(fce.get("reorder", "pattern"), str):
        raise ProgramFormatError(
            "program manifest fc.reorder must be a string", rule="M003"
        )
    fname = fce["bias"]
    if not isinstance(fname, str) or not os.path.exists(
        os.path.join(directory, fname)
    ):
        raise ProgramFormatError(
            f"payload file for fc.bias missing: {fname!r}", rule="M004"
        )
    _check_bp_entry(fce["bp"], directory, "fc.bp")
    part = manifest.get("partition")
    if part is not None:
        _require(part, ("data", "model", "data_axis", "model_axis"),
                 "partition")
    _check_certificate_entry(manifest.get("certificate"), "certificate")


def load_program(directory: str, verify: bool = True) -> CompiledNetwork:
    """Load a program previously written by :func:`save_program`.

    The manifest's version, keys, and payload files are validated
    *before* any array is constructed — a corrupt or truncated program
    raises one clear :class:`ProgramFormatError` instead of an opaque
    ``KeyError`` mid-load.  With ``verify=True`` (the default: saved
    programs are an untrusted input) the loaded network additionally
    runs the full static verifier and a
    :class:`~repro.analysis.diagnostics.VerificationError` carries the
    diagnostic report.  Pass ``verify=False`` on hot paths that reload
    programs this process just saved.
    """
    directory = _resolve_directory(directory)
    manifest = read_manifest(directory)
    validate_manifest(manifest, directory)
    c = manifest["config"]
    cfg = CNNConfig(
        conv_channels=tuple(tuple(x) for x in c["conv_channels"]),
        pool_after=frozenset(c["pool_after"]),
        num_classes=c["num_classes"],
        input_hw=c["input_hw"],
        kernel=c["kernel"],
    )
    try:
        convs = [
            CompiledConv(
                name=e["name"],
                c_in=e["c_in"],
                c_out=e["c_out"],
                kernel=e["kernel"],
                out_hw=e["out_hw"],
                pool_after=e["pool_after"],
                bp=_load_bp(e["bp"], directory),
                bias=np.load(os.path.join(directory, e["bias"])),
                pattern_bits=np.load(
                    os.path.join(directory, e["pattern_bits"])
                ),
                mapping=(
                    MappingCandidate.from_manifest(e["mapping"])
                    if e.get("mapping") is not None
                    else None
                ),
            )
            for e in manifest["convs"]
        ]
        fce = manifest["fc"]
        fc = CompiledFC(
            d_in=fce["d_in"],
            d_out=fce["d_out"],
            bp=_load_bp(fce["bp"], directory),
            bias=np.load(os.path.join(directory, fce["bias"])),
            reorder=str(fce.get("reorder", "pattern")),
        )
    except (OSError, ValueError) as e:
        raise ProgramFormatError(
            f"program payload under {directory} failed to load: {e}",
            rule="M005",
        ) from e
    part = manifest.get("partition")
    cert_entry = manifest.get("certificate")
    certificate = None
    if cert_entry is not None:
        # lazy: diagnostics-only dependency, keeps the load path's
        # import graph free of the analysis interpreter
        from repro.analysis.ranges import RangeCertificate

        try:
            certificate = RangeCertificate.from_manifest(cert_entry)
        except (KeyError, TypeError, ValueError) as e:
            raise ProgramFormatError(
                f"program manifest certificate failed to decode: {e}",
                rule="M003",
            ) from e
    program = CompiledNetwork(
        config=cfg,
        convs=convs,
        fc=fc,
        block=manifest["block"],
        tile=manifest["tile"],
        partition=NetworkPartition.from_manifest(part) if part else None,
        precision=manifest.get("precision", "fp32"),
        cell_bits=int(manifest.get("cell_bits", 4)),
        certificate=certificate,
    )
    if verify:
        from repro.analysis.verify import verify_network

        verify_network(program).raise_if_errors(
            f"load_program({directory!r})"
        )
    return program
