"""Measured activation-skip statistics (the engine side of paper §V-B).

The crossbar simulator prices OU skipping from the probability that an
input *selection* — the activations a pattern's wordlines would drive,
i.e. positions ``bits_to_mask(pattern)`` of one input channel's k*k patch
taps — is entirely zero.  ``core/simulator.forward_zero_stats`` estimates
that probability from a synthetic forward pass over random inputs; the
engine's executor sees the *real* served activations and can measure it.

This module is the aggregation layer between the two: the executor emits a
jit-friendly raw counter per conv layer (``counts[c, p]`` = number of
windows whose channel-``c`` selection under pattern ``p`` was all-zero,
out of ``windows`` total), and the classes here carry those counters
across batches/requests and convert them into the
:class:`~repro.core.simulator.SkipDistribution` that
``CompiledNetwork.hardware_report`` prices energy and cycles from.

Because ``channel_norm`` is per-sample, the counters are batch-composition
independent at *every* layer: statistics accumulated over scheduler
batches (dead slots masked out of counts and windows alike) are exactly
equal to one stats forward over the concatenated live images.

The (channel, pattern) pair is exactly the OU row-group identity: every
OU of a pattern-pruned placement shares its block's channel and pattern
(``core/ou.pattern_ou_schedule``), so one measured fraction per pair
covers every OU row-group in the layer.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.patterns import bits_to_mask
from repro.core.simulator import SkipDistribution

__all__ = [
    "LayerSkipStats",
    "ActivationStats",
    "skip_patterns_and_masks",
    "stats_from_counts",
]


def skip_patterns_and_masks(
    pattern_bits: np.ndarray, kernel_size: int
) -> tuple[tuple[int, ...], np.ndarray]:
    """The distinct patterns of a layer and their boolean position masks.

    Returns (patterns, masks) with masks ``[P, kernel_size]`` bool, row i
    the selected patch positions of ``patterns[i]``.  The ordering matches
    the counter columns the executor emits.
    """
    patterns = tuple(int(p) for p in np.unique(np.asarray(pattern_bits)))
    masks = np.stack([bits_to_mask(p, kernel_size) for p in patterns])
    return patterns, masks


@dataclasses.dataclass
class LayerSkipStats:
    """All-zero-selection counters for one conv layer.

    counts[c, i]: windows whose channel-``c`` input selection under
    ``patterns[i]`` was entirely zero, out of ``windows`` observed windows
    (= batch * H * W input positions, summed over every batch merged in).
    The all-zero pattern (bits == 0) selects nothing and therefore always
    counts as skippable, mirroring ``core/simulator._skip_fractions``.
    """

    name: str
    kernel_size: int
    patterns: tuple[int, ...]
    windows: int
    counts: np.ndarray  # [C_in, P] int64
    # kernels of the layer per (channel, pattern) — how many output
    # channels use pattern p on input channel c.  Weights mean_skip() by
    # how often each pair actually occurs in the OU schedule; None falls
    # back to an unweighted mean over the nonzero patterns.
    occurrences: np.ndarray | None = None  # [C_in, P] int64

    def skip_fractions(self) -> np.ndarray:
        """Measured P(selection all-zero) per (channel, pattern), [C, P]."""
        return self.counts / max(self.windows, 1)

    def mean_skip(self) -> float:
        """Mean measured skip over the layer's real (channel, pattern)
        pairs, occurrence-weighted when known.

        The all-zero pattern is excluded: it stores no kernels, so its
        vacuous always-skip column would inflate the summary relative to
        the probabilities the energy pricing actually consumes.
        """
        frac = self.skip_fractions()
        nonzero = np.array([p != 0 for p in self.patterns])
        if not nonzero.any():
            return 0.0
        if self.occurrences is not None:
            w = self.occurrences * nonzero[None, :]
            total = w.sum()
            return float((frac * w).sum() / total) if total else 0.0
        return float(frac[:, nonzero].mean())

    def merge(self, other: "LayerSkipStats") -> "LayerSkipStats":
        if (other.name, other.patterns, other.kernel_size) != (
            self.name, self.patterns, self.kernel_size
        ) or other.counts.shape != self.counts.shape:
            raise ValueError(
                f"incompatible stats for layer {self.name!r}: "
                f"{other.patterns} vs {self.patterns}"
            )
        return LayerSkipStats(
            name=self.name,
            kernel_size=self.kernel_size,
            patterns=self.patterns,
            windows=self.windows + other.windows,
            counts=self.counts + other.counts,
            occurrences=self.occurrences,
        )

    def to_distribution(self) -> SkipDistribution:
        frac = self.skip_fractions()
        probs = {
            (c, pat): float(frac[c, i])
            for c in range(frac.shape[0])
            for i, pat in enumerate(self.patterns)
        }
        return SkipDistribution(probs=probs, windows=self.windows)


@dataclasses.dataclass
class ActivationStats:
    """Per-layer measured skip statistics for one or more forward passes."""

    layers: dict[str, LayerSkipStats]

    def merge(self, other: "ActivationStats") -> "ActivationStats":
        merged = dict(self.layers)
        for name, st in other.layers.items():
            merged[name] = merged[name].merge(st) if name in merged else st
        return ActivationStats(layers=merged)

    def mean_skip(self) -> float:
        if not self.layers:
            return 0.0
        return float(np.mean([st.mean_skip() for st in self.layers.values()]))

    def to_distributions(self) -> dict[str, SkipDistribution]:
        return {n: st.to_distribution() for n, st in self.layers.items()}


def stats_from_counts(
    convs,
    counts: dict[str, np.ndarray],
    windows: dict[str, int],
) -> ActivationStats:
    """Assemble :class:`ActivationStats` from the executor's raw counters.

    convs: the program's ``CompiledConv`` list (pattern_bits source);
    counts / windows: per layer name, as returned by the jitted forward and
    as computed from the actual input geometry.
    """
    layers = {}
    for op in convs:
        if op.name not in counts:
            continue
        kk = op.kernel * op.kernel
        patterns, _ = skip_patterns_and_masks(op.pattern_bits, kk)
        pb = np.asarray(op.pattern_bits)  # [c_out, c_in]
        occ = np.stack(
            [(pb == p).sum(axis=0) for p in patterns], axis=1
        ).astype(np.int64)  # [c_in, P]
        layers[op.name] = LayerSkipStats(
            name=op.name,
            kernel_size=kk,
            patterns=patterns,
            windows=int(windows[op.name]),
            counts=np.asarray(counts[op.name], np.int64),
            occurrences=occ,
        )
    return ActivationStats(layers=layers)
