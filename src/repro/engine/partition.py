"""Partition a ``CompiledNetwork`` across a device mesh.

The paper's OU-based accelerator scales by spreading a sparse network's
crossbar tiles over many parallel arrays; the engine analogue is to spread
each layer's compressed spmm operands over a mesh of devices:

  * **tile-parallel** (the ``model`` axis): the ``n_tiles`` axis of every
    :class:`~repro.core.sparse.BlockPatternWeight` is zero-padded up to a
    multiple of the shard count (:func:`pad_bp_tiles`) and split
    contiguously (:func:`tile_assignment`).  Each device computes the
    output columns of its own tiles; the executor scatters the partial
    outputs into full width and ``psum``s them back together before the
    inverse output permutation (the Output Indexing Unit stays global).
    Padding tiles carry zero bricks and ``nnz == 0``, so they are
    numerically inert — exactly like the crossbar mapper's grey area.
  * **batch-parallel** (the ``data`` axis): ``InferenceService`` slots /
    forward-batch rows are split across devices; activation-skip counters
    are ``psum``-reduced so measured statistics are identical to the
    single-device run.

:class:`NetworkPartition` is the declarative record of that split.  It
rides on ``CompiledNetwork.partition`` (and through ``serialize.py``), so
one compiled artifact knows how it is meant to serve from multiple chips;
``executor.make_forward(..., mesh=...)`` realizes it on an actual mesh.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.sparse import BlockPatternWeight
from repro.parallel.sharding import pad_to_multiple

__all__ = [
    "NetworkPartition",
    "padded_tiles",
    "tile_assignment",
    "pad_bp_tiles",
    "partition_from_mesh",
    "partition_network",
]


@dataclasses.dataclass(frozen=True)
class NetworkPartition:
    """Declarative split of a compiled program over a device mesh.

    ``model`` tile-parallel shards x ``data`` batch-parallel shards; the
    axis names bind the split to mesh axes at execution time.
    """

    data: int = 1
    model: int = 1
    data_axis: str = "data"
    model_axis: str = "model"

    def __post_init__(self):
        if self.data < 1 or self.model < 1:
            raise ValueError(f"invalid partition {self.data}x{self.model}")

    @property
    def n_chips(self) -> int:
        return self.data * self.model

    def to_manifest(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_manifest(cls, entry: dict) -> "NetworkPartition":
        return cls(
            data=int(entry["data"]),
            model=int(entry["model"]),
            data_axis=entry.get("data_axis", "data"),
            model_axis=entry.get("model_axis", "model"),
        )


def padded_tiles(n_tiles: int, shards: int) -> int:
    """Tile count padded up so ``shards`` devices hold equal tile slabs."""
    return pad_to_multiple(n_tiles, max(shards, 1))


def tile_assignment(n_tiles: int, shards: int) -> np.ndarray:
    """Contiguous padded-tile indices per shard: int [shards, tiles/shard].

    Every padded tile index appears exactly once; entries ``>= n_tiles``
    are padding tiles (all-zero bricks after :func:`pad_bp_tiles`).
    """
    shards = max(shards, 1)
    per = padded_tiles(n_tiles, shards) // shards
    return np.arange(shards * per, dtype=np.int64).reshape(shards, per)


def pad_bp_tiles(bp: BlockPatternWeight, shards: int) -> BlockPatternWeight:
    """Copy of ``bp`` with the tile axis zero-padded for ``shards`` devices.

    Padded tiles have all-zero ``w_comp`` bricks, ``block_ids == 0`` (they
    gather block 0 and multiply by zeros) and ``nnz == 0``.  ``n_out`` and
    the permutations are untouched: padded output columns sit past every
    ``inv_order`` entry, so the inverse permutation drops them and
    ``dense()`` reconstructs the identical matrix.  Quantized weights pad
    ``w_scales`` with zeros too, so padding tiles dequantize to exact
    zeros on every backend.
    """
    pad = padded_tiles(bp.n_tiles, shards) - bp.n_tiles
    if pad == 0:
        return bp
    extra = {}
    if bp.w_scales is not None:
        extra["w_scales"] = jnp.pad(bp.w_scales, ((0, pad), (0, 0)))
    return dataclasses.replace(
        bp,
        w_comp=jnp.pad(bp.w_comp, ((0, pad), (0, 0), (0, 0), (0, 0))),
        block_ids=jnp.pad(bp.block_ids, ((0, pad), (0, 0))),
        nnz=np.pad(np.asarray(bp.nnz), (0, pad)).astype(np.int32),
        **extra,
    )


def partition_from_mesh(mesh, partition: NetworkPartition | None = None):
    """Resolve (and validate) a partition against an actual mesh.

    With ``partition=None`` the split is read off the mesh's ``data`` /
    ``model`` axis sizes (absent axes count as 1).  An explicit partition
    must name axes the mesh has, at the sizes the mesh has — a program
    partitioned for 4 chips must not silently run on 2.
    """
    axis_sizes = dict(mesh.shape)
    if partition is None:
        return NetworkPartition(
            data=axis_sizes.get("data", 1), model=axis_sizes.get("model", 1)
        )
    for axis, want in (
        (partition.data_axis, partition.data),
        (partition.model_axis, partition.model),
    ):
        have = axis_sizes.get(axis, 1)
        if want != have:
            raise ValueError(
                f"partition wants {axis}={want} but mesh has {axis}={have} "
                f"(mesh shape {axis_sizes})"
            )
    return partition


def partition_network(
    program,
    data: int = 1,
    model: int = 1,
    data_axis: str = "data",
    model_axis: str = "model",
):
    """Record a partition on a compiled program (weights stay unpadded).

    Returns a new ``CompiledNetwork`` carrying the partition; tile padding
    happens when the executor realizes the partition on a mesh, so the
    stored artifact (and ``serialize.py``) keeps the compact operands.
    The partition is statically verified against the program (axis names
    distinct, tile assignment a disjoint cover of every layer's padded
    tile axis) and an invalid split raises
    :class:`~repro.analysis.diagnostics.VerificationError` here, at
    declaration time, instead of surfacing as a shape error inside
    ``shard_map`` later.
    """
    part = NetworkPartition(
        data=data, model=model, data_axis=data_axis, model_axis=model_axis
    )
    from repro.analysis.verify import verify_partition

    verify_partition(program, part).raise_if_errors("partition_network")
    return dataclasses.replace(program, partition=part)
