"""Traffic-serving front end for compiled programs.

``InferenceService`` mirrors ``runtime/serve.py``'s ``ServeLoop`` control
plane for the classification workload: a fixed number of batch slots, a
request queue drained generation by generation, and per-request results
written back onto the request objects.  Full generations hit one jitted
batch shape; a partial final generation runs at its natural size (one
extra trace per distinct size, at most ``batch_slots`` ever) rather than
being zero-padded — the model's BN stand-in normalises over *batch*
statistics, so padded dead slots would contaminate real requests' logits.

With ``collect_stats=True`` every served batch also measures its
activation-skip counters (``engine/stats.py``); the service accumulates
them across requests into ``activation_stats``, so
``service.hardware_report()`` prices energy from the skip probabilities
*realized on the traffic actually served* rather than an assumption.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.engine.executor import make_forward
from repro.engine.program import CompiledNetwork
from repro.engine.stats import ActivationStats

__all__ = ["ClassifyRequest", "InferenceService"]


@dataclasses.dataclass
class ClassifyRequest:
    """One image in, logits + argmax label out."""

    image: np.ndarray  # [C, H, W]
    logits: np.ndarray | None = None
    label: int | None = None
    done: bool = False


class InferenceService:
    """Slot-based batched classification over a jitted engine forward."""

    def __init__(
        self,
        program: CompiledNetwork,
        batch_slots: int = 8,
        backend: str | None = None,
        interpret: bool | None = None,
        collect_stats: bool = False,
        mesh=None,
        partition=None,
    ):
        """With ``mesh=`` every generation executes sharded
        (``engine/partition.py``): batch slots split over the mesh's data
        axis, each layer's tiles over the model axis.  Full generations
        shard when ``batch_slots`` divides by the data axis; a partial
        final generation that doesn't falls back to replicated batch rows
        inside the same mesh forward, keeping exact numerics either way.
        """
        self.program = program
        self.batch_slots = batch_slots
        self.collect_stats = collect_stats
        self.mesh = mesh
        self._forward = make_forward(
            program, backend=backend, interpret=interpret,
            collect_stats=collect_stats, mesh=mesh, partition=partition,
        )
        self.batches_run = 0
        self.activation_stats: ActivationStats | None = None

    def _input_shape(self) -> tuple[int, int, int]:
        cfg = self.program.config
        return (cfg.conv_channels[0][0], cfg.input_hw, cfg.input_hw)

    def reset_stats(self) -> None:
        self.activation_stats = None

    def _record_stats(self, stats: ActivationStats) -> None:
        self.activation_stats = (
            stats if self.activation_stats is None
            else self.activation_stats.merge(stats)
        )

    def serve(self, requests: list[ClassifyRequest]) -> list[ClassifyRequest]:
        """Drain ``requests`` through the fixed-slot batch loop."""
        shape = self._input_shape()
        for start in range(0, len(requests), self.batch_slots):
            batch = requests[start : start + self.batch_slots]
            x = np.zeros((len(batch), *shape), np.float32)
            for i, r in enumerate(batch):
                img = np.asarray(r.image, np.float32)
                if img.shape != shape:
                    raise ValueError(
                        f"request image {img.shape} != expected {shape}"
                    )
                x[i] = img
            out = self._forward(x)
            if self.collect_stats:
                out, stats = out
                self._record_stats(stats)
            logits = np.asarray(jax.device_get(out))
            self.batches_run += 1
            for i, r in enumerate(batch):
                r.logits = logits[i]
                r.label = int(np.argmax(logits[i]))
                r.done = True
        return requests

    def classify(self, images: np.ndarray) -> np.ndarray:
        """Convenience: [N, C, H, W] -> labels [N]."""
        reqs = [ClassifyRequest(image=img) for img in np.asarray(images)]
        self.serve(reqs)
        return np.array([r.label for r in reqs], np.int64)

    def hardware_report(self, assumed_skip: float | None = None, **kw) -> dict:
        """Crossbar pricing from the skip statistics of the served traffic.

        Falls back to the program's assumed/no-skip pricing when no
        requests have been served with ``collect_stats`` yet.
        """
        return self.program.hardware_report(
            skip_stats=self.activation_stats, assumed_skip=assumed_skip, **kw
        )
