"""Traffic-serving front end for compiled programs.

``InferenceService`` serves classification requests through the shared
continuous-batching scheduler (``engine/scheduler.py``, the control plane
extracted from ``runtime/serve.py``'s ``ServeLoop``): an optionally
bounded request queue, a fixed number of batch slots refilled as they
free up, and per-request latency / occupancy metrics.

Every executed batch has the *same* ``[batch_slots, C, H, W]`` shape —
free slots ride along as zero-padded dead rows flagged by a validity
mask — so the jitted forward is traced exactly once, no matter how
requests arrive.  ``channel_norm`` is per-sample, which makes that safe:
a request's logits are bit-identical whether it runs alone, co-batched
with other requests, or next to dead slots.

With ``collect_stats=True`` every served batch also measures its
activation-skip counters (``engine/stats.py``); the validity mask
excludes dead slots from both the counters and the window totals, so the
accumulated ``activation_stats`` equal a one-shot stats forward over
exactly the served images and ``service.hardware_report()`` prices
energy from the skip probabilities *realized on the traffic actually
served* rather than an assumption.
"""

from __future__ import annotations

import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.executor import make_forward, warmup_forward
from repro.engine.program import CompiledNetwork
from repro.engine.scheduler import SlotScheduler
from repro.engine.stats import ActivationStats
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.api import Request as ServeRequest

__all__ = ["ClassifyRequest", "InferenceService"]


class ClassifyRequest(ServeRequest):
    """Deprecated: use :class:`repro.serve.Request` (``image=`` form)."""

    def __init__(self, image, logits=None, label=None, done: bool = False):
        warnings.warn(
            "repro.engine.service.ClassifyRequest is deprecated; use "
            "repro.serve.Request(image=...)",
            DeprecationWarning, stacklevel=2,
        )
        super().__init__(image=image, logits=logits, label=label, done=done)


class InferenceService:
    """Continuous-batching classification over a jitted engine forward."""

    def __init__(
        self,
        program: CompiledNetwork,
        batch_slots: int = 8,
        backend: str | None = None,
        interpret: bool | None = None,
        collect_stats: bool = False,
        mesh=None,
        partition=None,
        max_queue: int = 0,
        clock: Callable[[], float] = time.monotonic,
        tracer: Tracer | None = None,
    ):
        """With ``mesh=`` every batch executes sharded
        (``engine/partition.py``): batch slots split over the mesh's data
        axis, each layer's tiles over the model axis.  Because the batch
        shape is always the full ``batch_slots``, the data axis divides
        it whenever ``batch_slots % data == 0`` — partially filled
        batches shard exactly like full ones instead of falling back to
        replication.

        ``max_queue`` bounds the number of waiting requests (0 =
        unbounded); a full queue raises
        :class:`~repro.engine.scheduler.SchedulerFull` from
        :meth:`submit` — the backpressure signal under load.

        ``tracer`` puts the service on a shared Perfetto timeline: every
        request becomes an async span (enqueue -> admit -> done, via the
        scheduler), each executed batch a ``service.step`` span, and
        queue depth / live slots counter tracks.  The tracer is *not*
        handed to the jitted forward — serving always runs the
        single-trace jitted path; use a separate tracer-instrumented
        ``make_forward`` for per-layer timings.
        """
        self.program = program
        self.batch_slots = batch_slots
        self.collect_stats = collect_stats
        self.mesh = mesh
        self._forward = make_forward(
            program, backend=backend, interpret=interpret,
            collect_stats=collect_stats, mesh=mesh, partition=partition,
        )
        self._tracer = tracer or NULL_TRACER
        self.scheduler = SlotScheduler(
            batch_slots, max_queue=max_queue, clock=clock, tracer=tracer
        )
        shape = self._input_shape()
        # persistent slot buffer: freed slots are zeroed, so the fixed
        # batch is always "live images + zero padding"
        self._slots_x = np.zeros((batch_slots, *shape), np.float32)
        self.batches_run = 0
        self.activation_stats: ActivationStats | None = None

    def _input_shape(self) -> tuple[int, int, int]:
        cfg = self.program.config
        return (cfg.conv_channels[0][0], cfg.input_hw, cfg.input_hw)

    def trace_count(self) -> int:
        """How many times the underlying forward has been traced."""
        return self._forward.trace_count()

    def warmup(self) -> None:
        """Trace/compile the forward at the serving batch shape without
        sending traffic through the scheduler (metrics stay at zero)."""
        warmup_forward(self._forward, self.program, self.batch_slots)

    @property
    def metrics(self) -> dict:
        """Scheduler metrics: queue/latency/occupancy of the served load."""
        return self.scheduler.snapshot()

    def reset_stats(self) -> None:
        self.activation_stats = None

    def reset_metrics(self) -> None:
        """Start a fresh scheduler-metrics window (e.g. post warm-up)."""
        self.scheduler.reset_metrics()

    def _record_stats(self, stats: ActivationStats) -> None:
        self.activation_stats = (
            stats if self.activation_stats is None
            else self.activation_stats.merge(stats)
        )

    def _validate(self, img: np.ndarray) -> np.ndarray:
        shape = self._input_shape()
        img = np.asarray(img, np.float32)
        if img.shape != shape:
            raise ValueError(f"request image {img.shape} != expected {shape}")
        return img

    def submit(self, request: ServeRequest) -> ServeRequest:
        """Validate and enqueue one request (raises ``SchedulerFull`` when
        the bounded queue is full, ``ValueError`` on a bad image shape)."""
        request.image = self._validate(request.image)
        self.scheduler.submit(request)
        return request

    def try_submit(self, request: ServeRequest) -> bool:
        """Validate and enqueue; ``False`` when the bounded queue is full
        (the shed path the ``repro.serve`` session turns into
        ``Overloaded`` — ``SchedulerFull`` never escapes that route)."""
        request.image = self._validate(request.image)
        return self.scheduler.try_submit(request)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def step(self) -> list[ServeRequest]:
        """Refill free slots from the queue and run one fixed-shape batch.

        Returns the requests completed by this batch (empty when there
        was nothing to serve).
        """
        sched = self.scheduler
        for slot, req in sched.refill():
            self._slots_x[slot] = req.image
        valid = sched.valid_mask()
        if not valid.any():
            return []
        with self._tracer.span(
            "service.step", cat="serve", live=int(valid.sum()),
            batch_slots=self.batch_slots,
        ):
            out = self._forward(jnp.asarray(self._slots_x), valid)
            if self.collect_stats:
                out, stats = out
                self._record_stats(stats)
            logits = np.asarray(jax.device_get(out))
        self.batches_run += 1
        sched.record_step()
        finished = []
        for slot, req in sched.live():
            req.logits = logits[slot]
            req.label = int(np.argmax(logits[slot]))
            req.done = True
            sched.complete(slot)
            self._slots_x[slot] = 0.0  # dead slots stay zero-padded
            finished.append(req)
        return finished

    def run(self) -> list[ServeRequest]:
        """Serve until the queue and every slot are drained."""
        finished = []
        while self.scheduler.has_work():
            finished.extend(self.step())
        return finished

    def serve(self, requests: list[ServeRequest]) -> list[ServeRequest]:
        """Drain ``requests`` through the scheduler.

        All request shapes are validated *before* any batch runs, so a
        malformed request rejects the whole call up front instead of
        leaving earlier requests served and later ones untouched.
        Submission interleaves with serving, so a bounded queue never
        overflows from a large one-shot batch.
        """
        images = [self._validate(r.image) for r in requests]
        for r, img in zip(requests, images):
            r.image = img
        pending = list(requests)
        while pending or self.scheduler.has_work():
            # capacity probe, not try_submit: a full queue mid-drain is
            # backpressure handled here, not a rejection to count
            while pending and self.scheduler.has_capacity():
                self.scheduler.submit(pending.pop(0))
            self.step()
        return requests

    def classify(self, images: np.ndarray) -> np.ndarray:
        """Convenience: [N, C, H, W] -> labels [N]."""
        reqs = [ServeRequest(image=img) for img in np.asarray(images)]
        self.serve(reqs)
        return np.array([r.label for r in reqs], np.int64)

    def metrics_text(self) -> str:
        """Prometheus text exposition of the scheduler metrics — what an
        RPC front end serves from its ``/metrics`` endpoint."""
        return self.scheduler.metrics.to_prometheus(prefix="engine_service")

    def hardware_report(self, assumed_skip: float | None = None, **kw) -> dict:
        """Crossbar pricing from the skip statistics of the served traffic.

        Falls back to the program's assumed/no-skip pricing when no
        requests have been served with ``collect_stats`` yet.
        """
        return self.program.hardware_report(
            skip_stats=self.activation_stats, assumed_skip=assumed_skip, **kw
        )
