"""Lowering: pattern-pruned CNN params -> executable ``CompiledNetwork``.

Per conv layer the dense weights ``[C_out, C_in, K, K]`` are viewed as the
im2col matmul ``[C_in*K*K, C_out]``, zero-padded up to (block, tile)
multiples, and compressed into a :class:`BlockPatternWeight` via the
*exact* path of ``core/sparse.build_block_pattern``: block masks are the
true nonzero structure (``nonzero_block_masks``), so reorder -> compress ->
index produces real kernel operands and the compressed program computes
bit-the-same weights as the pruned dense network.  The FC head is lowered
onto the same path.

Pattern bits (``core/pruning.PruneResult.pattern_bits``) ride along per
layer so the compiled artifact can be priced on the crossbar model
(``CompiledNetwork.hardware_report``); when absent they are recovered from
the weights' nonzero masks.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.mapping import CrossbarConfig, MappingCandidate
from repro.core.mapsearch import (
    MappingSearchConfig,
    MappingSearchResult,
    choose_fc_reorder,
    search_layer_mapping,
)
from repro.core.patterns import kernel_masks, masks_to_bits
from repro.core.quantize import n_cell_slices, quantize_bp
from repro.core.sparse import (
    BlockPatternWeight,
    build_block_pattern,
    nonzero_block_masks,
)
from repro.engine.program import CompiledConv, CompiledFC, CompiledNetwork
from repro.models.cnn import CNNConfig
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = ["EngineConfig", "CompileOptions", "PRECISIONS", "lower_matrix",
           "lower_conv", "lower_fc", "conv_mapping_search",
           "compile_network"]

PRECISIONS = ("fp32", "int8")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Compile-time geometry of the spmm lowering.

    Defaults match the Pallas kernel's MXU-aligned bricks; smaller values
    trade alignment for finer-grained zero compression (useful on the XLA
    CPU path where kernel-granular blocks expose the pruning sparsity).

    ``precision`` selects the stored weight representation: 'fp32' (the
    historical exact path) or 'int8' — per-row-group symmetric int8
    bricks + fp32 scales (``core/quantize.py``), the paper's bit-sliced
    cell storage made executable.  ``cell_bits`` is the RRAM cell width
    the int payload is sliced over for hardware pricing (4-bit cells by
    default, matching ``CrossbarConfig``); it does not change the stored
    numbers, only how ``hardware_report`` derives cells-per-weight.
    """

    block: int = 128
    tile: int = 128
    precision: str = "fp32"
    cell_bits: int = 4

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got "
                f"{self.precision!r}"
            )
        if self.cell_bits < 1:
            raise ValueError(f"cell_bits must be >= 1, got {self.cell_bits}")


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Everything :func:`compile_network` accepts beyond the network itself.

    One frozen object in place of the loose kwargs that accreted on the
    compile entry point (``ecfg``/``precision``/``tracer``/``verify``/
    ``optimize``) — build it once, thread it through configs and tests,
    and the compile call stays ``compile_network(cfg, params, bits,
    options=opts)`` no matter how many knobs exist.

    The geometry fields mirror :class:`EngineConfig` (same defaults, same
    validation); :meth:`engine_config` projects them back out for the
    ``lower_*`` helpers, which keep taking a plain ``EngineConfig``.

    ``verify``/``optimize``/``tracer`` carry the compile-pass switches —
    see :func:`compile_network` for their semantics.
    """

    block: int = 128
    tile: int = 128
    precision: str = "fp32"
    cell_bits: int = 4
    verify: str | None = None
    optimize: "str | MappingSearchConfig | None" = None
    tracer: Tracer | None = None

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got "
                f"{self.precision!r}"
            )
        if self.cell_bits < 1:
            raise ValueError(f"cell_bits must be >= 1, got {self.cell_bits}")
        if self.verify not in (None, "warn", "strict"):
            raise ValueError(
                f"verify must be None, 'warn' or 'strict', got "
                f"{self.verify!r}"
            )
        if self.optimize is not None and self.optimize != "auto" and not (
            isinstance(self.optimize, MappingSearchConfig)
        ):
            raise ValueError(
                f"optimize must be None, 'auto' or a MappingSearchConfig, "
                f"got {self.optimize!r}"
            )

    @classmethod
    def from_engine_config(cls, ecfg: EngineConfig, **kw) -> "CompileOptions":
        """Lift a lowering geometry into full compile options."""
        return cls(block=ecfg.block, tile=ecfg.tile,
                   precision=ecfg.precision, cell_bits=ecfg.cell_bits, **kw)

    def engine_config(self) -> EngineConfig:
        """The :class:`EngineConfig` these options imply."""
        return EngineConfig(block=self.block, tile=self.tile,
                            precision=self.precision,
                            cell_bits=self.cell_bits)


def _pad_axis(a: np.ndarray, axis: int, mult: int) -> np.ndarray:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths)


def conv_matrix(w: np.ndarray) -> np.ndarray:
    """[C_out, C_in, Kh, Kw] -> im2col matmul view [C_in*Kh*Kw, C_out].

    Row index is ``c * Kh*Kw + (dy*Kw + dx)`` — the patch layout the
    executor extracts.
    """
    w = np.asarray(w)
    co = w.shape[0]
    return w.reshape(co, -1).T


def lower_matrix(
    wm: np.ndarray, block: int, tile: int, precision: str = "fp32",
    tracer: Tracer | None = None, reorder: str = "pattern",
) -> BlockPatternWeight:
    """Pad a dense [K, N] matrix to (block, tile) multiples and compress it
    losslessly from its nonzero structure; ``precision='int8'`` then
    quantizes the compressed bricks (``core/quantize.quantize_bp``).

    ``reorder`` selects the column-permutation strategy
    (``core/sparse.REORDERS``); every strategy yields the same semantics
    through the stored inverse permutation.

    With a ``tracer`` the lowering phases land as ``compile``-category
    spans: ``prune`` (nonzero-structure mask discovery), ``reorder`` +
    ``pack`` (inside ``build_block_pattern``), ``quantize``."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"precision must be one of {PRECISIONS}, got {precision!r}"
        )
    tracer = tracer or NULL_TRACER
    wp = _pad_axis(_pad_axis(np.asarray(wm, np.float32), 0, block), 1, tile)
    with tracer.span("prune", cat="compile", shape=list(wp.shape)):
        masks = nonzero_block_masks(wp, block)
    bp = build_block_pattern(wp, block=block, tile=tile, masks=masks,
                             tracer=tracer, reorder=reorder)
    if precision == "int8":
        with tracer.span("quantize", cat="compile", shape=list(wp.shape)):
            bp = quantize_bp(bp)
    return bp


def lower_conv(
    name: str,
    w: np.ndarray,
    b: np.ndarray,
    pattern_bits: np.ndarray | None,
    out_hw: int,
    pool_after: bool,
    ecfg: EngineConfig,
    tracer: Tracer | None = None,
    mapping: MappingCandidate | None = None,
) -> CompiledConv:
    w = np.asarray(w, np.float32)
    c_out, c_in, kh, kw = w.shape
    if kh != kw:
        raise ValueError(f"{name}: non-square kernel {kh}x{kw}")
    if pattern_bits is None:
        pattern_bits = masks_to_bits(kernel_masks(w))
    reorder = mapping.reorder if mapping is not None else "pattern"
    return CompiledConv(
        name=name,
        c_in=c_in,
        c_out=c_out,
        kernel=kh,
        out_hw=out_hw,
        pool_after=pool_after,
        bp=lower_matrix(conv_matrix(w), ecfg.block, ecfg.tile,
                        ecfg.precision, tracer=tracer, reorder=reorder),
        bias=np.asarray(b, np.float32).copy(),
        pattern_bits=np.asarray(pattern_bits, np.int64).copy(),
        mapping=mapping,
    )


def lower_fc(
    w: np.ndarray, b: np.ndarray, ecfg: EngineConfig,
    tracer: Tracer | None = None, reorder: str = "pattern",
) -> CompiledFC:
    w = np.asarray(w, np.float32)
    d_in, d_out = w.shape
    return CompiledFC(
        d_in=d_in,
        d_out=d_out,
        bp=lower_matrix(w, ecfg.block, ecfg.tile, ecfg.precision,
                        tracer=tracer, reorder=reorder),
        bias=np.asarray(b, np.float32).copy(),
        reorder=reorder,
    )


def _fixed_candidate(ecfg: EngineConfig) -> MappingCandidate:
    """The fixed scheme a search must match-or-beat: the paper's default
    geometry, with cells/weight derived from the program's precision the
    same way ``hardware_report`` derives it."""
    base = CrossbarConfig()
    cells = (
        n_cell_slices(ecfg.cell_bits)
        if ecfg.precision == "int8"
        else base.cells_per_weight
    )
    return MappingCandidate(
        rows=base.rows,
        cols=base.cols,
        cells_per_weight=cells,
        ou_rows=base.ou_rows,
        ou_cols=base.ou_cols,
    )


def conv_mapping_search(
    w: np.ndarray,
    pattern_bits: np.ndarray | None,
    out_hw: int,
    ecfg: EngineConfig = EngineConfig(),
    search: MappingSearchConfig | None = None,
) -> MappingSearchResult:
    """Run the mapping design-space search for one conv layer.

    Builds exactly the search inputs ``compile_network(optimize=...)``
    uses — the layer's pattern bits, the padded matmul view's block
    masks, the precision-derived fixed scheme — and returns the full
    :class:`~repro.core.mapsearch.MappingSearchResult` (benchmarks call
    this standalone to time the search and check determinism against the
    compiled program).
    """
    w = np.asarray(w, np.float32)
    if pattern_bits is None:
        pattern_bits = masks_to_bits(kernel_masks(w))
    kernel_size = w.shape[2] * w.shape[3]
    wp = _pad_axis(
        _pad_axis(conv_matrix(w), 0, ecfg.block), 1, ecfg.tile
    )
    masks = nonzero_block_masks(wp, ecfg.block)
    return search_layer_mapping(
        np.asarray(pattern_bits, np.int64),
        kernel_size=kernel_size,
        windows=out_hw * out_hw,
        fixed=_fixed_candidate(ecfg),
        search=search,
        masks=masks,
        tile=ecfg.tile,
    )


def compile_network(
    cfg: CNNConfig,
    params: dict,
    pattern_bits: dict[str, np.ndarray] | None = None,
    ecfg: EngineConfig | None = None,
    precision: str | None = None,
    tracer: Tracer | None = None,
    verify: str | None = None,
    optimize: "str | MappingSearchConfig | None" = None,
    *,
    options: CompileOptions | None = None,
) -> CompiledNetwork:
    """Lower a (pruned) CNN end-to-end into a :class:`CompiledNetwork`.

    Args:
      cfg: network geometry (``models.cnn.CNNConfig``).
      params: parameter pytree ``{conv1: {w, b}, ..., fc: {w, b}}``.
      pattern_bits: per-conv packed 3x3 pattern bitmasks
        (``PruneResult.pattern_bits``); recovered from the weights' nonzero
        structure for layers not listed.
      options: a :class:`CompileOptions` carrying the lowering geometry
        and every compile-pass switch.  This is the preferred form; the
        loose keyword arguments below are deprecated aliases kept for one
        release and cannot be combined with ``options=``.
      ecfg: deprecated — spmm lowering geometry (block/tile, stored
        precision); use the matching :class:`CompileOptions` fields.
      precision: deprecated — shorthand override of ``ecfg.precision``
        ('fp32'/'int8'); use ``CompileOptions(precision=...)``.
      tracer: deprecated alias of ``CompileOptions(tracer=...)``: optional
        span tracer (``obs/trace.py``).  The whole compile becomes a
        ``compile_network`` span containing one ``lower:<name>`` span per
        layer, each wrapping its phase spans
        (prune -> reorder -> pack -> quantize), so a Perfetto load of the
        trace shows exactly where compile time goes.
      verify: deprecated alias of ``CompileOptions(verify=...)``:
        post-condition check of the compiled program via
        ``repro.analysis.verify`` — ``'strict'`` raises
        :class:`~repro.analysis.diagnostics.VerificationError` on any
        error diagnostic, ``'warn'`` emits a Python warning instead,
        ``None`` (default) skips the pass on this hot compile path.
        When the structural pass is clean, the range certification pass
        (``repro.analysis.ranges``, its own ``ranges`` compile span)
        also runs: V5xx diagnostics join the same report and the
        resulting :class:`~repro.analysis.ranges.RangeCertificate` is
        attached as ``program.certificate``.
      optimize: deprecated alias of ``CompileOptions(optimize=...)``:
        per-layer mapping design-space search
        (``core/mapsearch.py``) — ``'auto'`` uses the default
        :class:`~repro.core.mapsearch.MappingSearchConfig`, or pass a
        config to pick axes/seed/budget; ``None`` (default) keeps the
        fixed paper scheme.  The chosen candidates ride on
        ``CompiledConv.mapping`` (priced by ``hardware_report``, saved in
        manifest v3) and each layer's search lands as a
        ``search:<name>`` compile span.

    The deprecated-kwargs form compiles a bit-identical program to the
    equivalent ``options=`` form (``tests/test_compile_options.py`` pins
    this), it just warns on the way.
    """
    legacy = [
        name for name, value in (
            ("ecfg", ecfg), ("precision", precision), ("tracer", tracer),
            ("verify", verify), ("optimize", optimize),
        ) if value is not None
    ]
    if options is not None:
        if legacy:
            raise TypeError(
                "compile_network: pass options=CompileOptions(...) alone; "
                f"also got deprecated kwarg(s) {legacy}"
            )
    else:
        if legacy:
            warnings.warn(
                "compile_network's loose kwargs "
                "(ecfg/precision/tracer/verify/optimize) are deprecated; "
                "pass options=CompileOptions(...) instead",
                DeprecationWarning, stacklevel=2,
            )
        base = ecfg if ecfg is not None else EngineConfig()
        options = CompileOptions(
            block=base.block,
            tile=base.tile,
            precision=precision if precision is not None else base.precision,
            cell_bits=base.cell_bits,
            verify=verify,
            optimize=optimize,
            tracer=tracer,
        )
    ecfg = options.engine_config()
    verify = options.verify
    if isinstance(options.optimize, MappingSearchConfig):
        search_cfg = options.optimize
    elif options.optimize == "auto":
        search_cfg = MappingSearchConfig()
    else:
        search_cfg = None
    tracer = options.tracer or NULL_TRACER
    pattern_bits = pattern_bits or {}
    convs = []
    hw = cfg.input_hw
    with tracer.span(
        "compile_network", cat="compile",
        layers=cfg.num_convs + 1, precision=ecfg.precision,
        optimize=search_cfg is not None,
    ):
        for i in range(1, cfg.num_convs + 1):
            name = f"conv{i}"
            pool = i in cfg.pool_after
            mapping = None
            if search_cfg is not None:
                with tracer.span(f"search:{name}", cat="compile") as sp:
                    res = conv_mapping_search(
                        params[name]["w"], pattern_bits.get(name), hw,
                        ecfg, search_cfg,
                    )
                    mapping = res.chosen
                    sp.args.update(
                        evaluations=res.evaluations,
                        improved=res.improved,
                        chosen=mapping.to_manifest(),
                        area_cells=res.cost.area_cells,
                        fixed_area_cells=res.fixed_cost.area_cells,
                    )
            with tracer.span(f"lower:{name}", cat="compile"):
                convs.append(
                    lower_conv(
                        name,
                        params[name]["w"],
                        params[name]["b"],
                        pattern_bits.get(name),
                        out_hw=hw,
                        pool_after=pool,
                        ecfg=ecfg,
                        tracer=tracer,
                        mapping=mapping,
                    )
                )
            if pool:
                hw //= 2
        fc_reorder = "pattern"
        if search_cfg is not None:
            with tracer.span("search:fc", cat="compile") as sp:
                wfc = _pad_axis(
                    _pad_axis(
                        np.asarray(params["fc"]["w"], np.float32),
                        0, ecfg.block,
                    ),
                    1, ecfg.tile,
                )
                fc_reorder, counts = choose_fc_reorder(
                    nonzero_block_masks(wfc, ecfg.block),
                    ecfg.tile, search_cfg.reorders,
                )
                sp.args.update(chosen=fc_reorder, bricks=counts)
        with tracer.span("lower:fc", cat="compile"):
            fc = lower_fc(params["fc"]["w"], params["fc"]["b"], ecfg,
                          tracer=tracer, reorder=fc_reorder)
    program = CompiledNetwork(
        config=cfg, convs=convs, fc=fc, block=ecfg.block, tile=ecfg.tile,
        precision=ecfg.precision, cell_bits=ecfg.cell_bits,
    )
    if verify is not None:
        from repro.analysis.ranges import analyze_network
        from repro.analysis.verify import verify_network

        with tracer.span("verify", cat="compile"):
            report = verify_network(program)
        # the range certification pass only runs over structurally sound
        # programs (its interval math assumes the verifier's contracts);
        # V5xx diagnostics land in the same report, the certificate rides
        # on the program (priced by hardware_report, saved in manifest v4)
        if report.ok:
            with tracer.span("ranges", cat="compile") as sp:
                report, cert = analyze_network(program, report=report)
                program.certificate = cert
                sp.args.update(
                    fp32_safe=cert.fp32_safe,
                    certified_cells=cert.certified_cells(),
                )
        if verify == "strict":
            report.raise_if_errors("compile_network")
        elif not report.ok:
            warnings.warn(
                "compile_network produced a program that fails "
                "verification:\n" + report.format(),
                stacklevel=2,
            )
    return program
