"""Lowering: pattern-pruned CNN params -> executable ``CompiledNetwork``.

Per conv layer the dense weights ``[C_out, C_in, K, K]`` are viewed as the
im2col matmul ``[C_in*K*K, C_out]``, zero-padded up to (block, tile)
multiples, and compressed into a :class:`BlockPatternWeight` via the
*exact* path of ``core/sparse.build_block_pattern``: block masks are the
true nonzero structure (``nonzero_block_masks``), so reorder -> compress ->
index produces real kernel operands and the compressed program computes
bit-the-same weights as the pruned dense network.  The FC head is lowered
onto the same path.

Pattern bits (``core/pruning.PruneResult.pattern_bits``) ride along per
layer so the compiled artifact can be priced on the crossbar model
(``CompiledNetwork.hardware_report``); when absent they are recovered from
the weights' nonzero masks.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.patterns import kernel_masks, masks_to_bits
from repro.core.quantize import quantize_bp
from repro.core.sparse import (
    BlockPatternWeight,
    build_block_pattern,
    nonzero_block_masks,
)
from repro.engine.program import CompiledConv, CompiledFC, CompiledNetwork
from repro.models.cnn import CNNConfig
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = ["EngineConfig", "PRECISIONS", "lower_matrix", "lower_conv",
           "lower_fc", "compile_network"]

PRECISIONS = ("fp32", "int8")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Compile-time geometry of the spmm lowering.

    Defaults match the Pallas kernel's MXU-aligned bricks; smaller values
    trade alignment for finer-grained zero compression (useful on the XLA
    CPU path where kernel-granular blocks expose the pruning sparsity).

    ``precision`` selects the stored weight representation: 'fp32' (the
    historical exact path) or 'int8' — per-row-group symmetric int8
    bricks + fp32 scales (``core/quantize.py``), the paper's bit-sliced
    cell storage made executable.  ``cell_bits`` is the RRAM cell width
    the int payload is sliced over for hardware pricing (4-bit cells by
    default, matching ``CrossbarConfig``); it does not change the stored
    numbers, only how ``hardware_report`` derives cells-per-weight.
    """

    block: int = 128
    tile: int = 128
    precision: str = "fp32"
    cell_bits: int = 4

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got "
                f"{self.precision!r}"
            )
        if self.cell_bits < 1:
            raise ValueError(f"cell_bits must be >= 1, got {self.cell_bits}")


def _pad_axis(a: np.ndarray, axis: int, mult: int) -> np.ndarray:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths)


def conv_matrix(w: np.ndarray) -> np.ndarray:
    """[C_out, C_in, Kh, Kw] -> im2col matmul view [C_in*Kh*Kw, C_out].

    Row index is ``c * Kh*Kw + (dy*Kw + dx)`` — the patch layout the
    executor extracts.
    """
    w = np.asarray(w)
    co = w.shape[0]
    return w.reshape(co, -1).T


def lower_matrix(
    wm: np.ndarray, block: int, tile: int, precision: str = "fp32",
    tracer: Tracer | None = None,
) -> BlockPatternWeight:
    """Pad a dense [K, N] matrix to (block, tile) multiples and compress it
    losslessly from its nonzero structure; ``precision='int8'`` then
    quantizes the compressed bricks (``core/quantize.quantize_bp``).

    With a ``tracer`` the lowering phases land as ``compile``-category
    spans: ``prune`` (nonzero-structure mask discovery), ``reorder`` +
    ``pack`` (inside ``build_block_pattern``), ``quantize``."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"precision must be one of {PRECISIONS}, got {precision!r}"
        )
    tracer = tracer or NULL_TRACER
    wp = _pad_axis(_pad_axis(np.asarray(wm, np.float32), 0, block), 1, tile)
    with tracer.span("prune", cat="compile", shape=list(wp.shape)):
        masks = nonzero_block_masks(wp, block)
    bp = build_block_pattern(wp, block=block, tile=tile, masks=masks,
                             tracer=tracer)
    if precision == "int8":
        with tracer.span("quantize", cat="compile", shape=list(wp.shape)):
            bp = quantize_bp(bp)
    return bp


def lower_conv(
    name: str,
    w: np.ndarray,
    b: np.ndarray,
    pattern_bits: np.ndarray | None,
    out_hw: int,
    pool_after: bool,
    ecfg: EngineConfig,
    tracer: Tracer | None = None,
) -> CompiledConv:
    w = np.asarray(w, np.float32)
    c_out, c_in, kh, kw = w.shape
    if kh != kw:
        raise ValueError(f"{name}: non-square kernel {kh}x{kw}")
    if pattern_bits is None:
        pattern_bits = masks_to_bits(kernel_masks(w))
    return CompiledConv(
        name=name,
        c_in=c_in,
        c_out=c_out,
        kernel=kh,
        out_hw=out_hw,
        pool_after=pool_after,
        bp=lower_matrix(conv_matrix(w), ecfg.block, ecfg.tile,
                        ecfg.precision, tracer=tracer),
        bias=np.asarray(b, np.float32).copy(),
        pattern_bits=np.asarray(pattern_bits, np.int64).copy(),
    )


def lower_fc(
    w: np.ndarray, b: np.ndarray, ecfg: EngineConfig,
    tracer: Tracer | None = None,
) -> CompiledFC:
    w = np.asarray(w, np.float32)
    d_in, d_out = w.shape
    return CompiledFC(
        d_in=d_in,
        d_out=d_out,
        bp=lower_matrix(w, ecfg.block, ecfg.tile, ecfg.precision,
                        tracer=tracer),
        bias=np.asarray(b, np.float32).copy(),
    )


def compile_network(
    cfg: CNNConfig,
    params: dict,
    pattern_bits: dict[str, np.ndarray] | None = None,
    ecfg: EngineConfig = EngineConfig(),
    precision: str | None = None,
    tracer: Tracer | None = None,
    verify: str | None = None,
) -> CompiledNetwork:
    """Lower a (pruned) CNN end-to-end into a :class:`CompiledNetwork`.

    Args:
      cfg: network geometry (``models.cnn.CNNConfig``).
      params: parameter pytree ``{conv1: {w, b}, ..., fc: {w, b}}``.
      pattern_bits: per-conv packed 3x3 pattern bitmasks
        (``PruneResult.pattern_bits``); recovered from the weights' nonzero
        structure for layers not listed.
      ecfg: spmm lowering geometry (block/tile, stored precision).
      precision: shorthand override of ``ecfg.precision`` ('fp32'/'int8').
      tracer: optional span tracer (``obs/trace.py``).  The whole compile
        becomes a ``compile_network`` span containing one ``lower:<name>``
        span per layer, each wrapping its phase spans
        (prune -> reorder -> pack -> quantize), so a Perfetto load of the
        trace shows exactly where compile time goes.
      verify: post-condition check of the compiled program via
        ``repro.analysis.verify`` — ``'strict'`` raises
        :class:`~repro.analysis.diagnostics.VerificationError` on any
        error diagnostic, ``'warn'`` emits a Python warning instead,
        ``None`` (default) skips the pass on this hot compile path.
    """
    if verify not in (None, "warn", "strict"):
        raise ValueError(
            f"verify must be None, 'warn' or 'strict', got {verify!r}"
        )
    if precision is not None:
        ecfg = dataclasses.replace(ecfg, precision=precision)
    tracer = tracer or NULL_TRACER
    pattern_bits = pattern_bits or {}
    convs = []
    hw = cfg.input_hw
    with tracer.span(
        "compile_network", cat="compile",
        layers=cfg.num_convs + 1, precision=ecfg.precision,
    ):
        for i in range(1, cfg.num_convs + 1):
            name = f"conv{i}"
            pool = i in cfg.pool_after
            with tracer.span(f"lower:{name}", cat="compile"):
                convs.append(
                    lower_conv(
                        name,
                        params[name]["w"],
                        params[name]["b"],
                        pattern_bits.get(name),
                        out_hw=hw,
                        pool_after=pool,
                        ecfg=ecfg,
                        tracer=tracer,
                    )
                )
            if pool:
                hw //= 2
        with tracer.span("lower:fc", cat="compile"):
            fc = lower_fc(params["fc"]["w"], params["fc"]["b"], ecfg,
                          tracer=tracer)
    program = CompiledNetwork(
        config=cfg, convs=convs, fc=fc, block=ecfg.block, tile=ecfg.tile,
        precision=ecfg.precision, cell_bits=ecfg.cell_bits,
    )
    if verify is not None:
        from repro.analysis.verify import verify_network

        with tracer.span("verify", cat="compile"):
            report = verify_network(program)
        if verify == "strict":
            report.raise_if_errors("compile_network")
        elif not report.ok:
            import warnings

            warnings.warn(
                "compile_network produced a program that fails "
                "verification:\n" + report.format(),
                stacklevel=2,
            )
    return program
