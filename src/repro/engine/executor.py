"""Executor: run a ``CompiledNetwork`` through the Pallas/XLA spmm kernels.

``make_forward`` returns a jitted batched forward: per conv layer it
extracts im2col patches (conv-as-spmm), dispatches through
``kernels/ops.pattern_spmm`` (Pallas TPU kernel, interpreted Pallas or XLA
fallback on CPU) — which applies the stored inverse output permutation
(the Output Indexing Unit) — then bias + shared ``channel_norm``/ReLU and
the 2x2 maxpool where the schedule says so, matching ``cnn_apply`` on the
pruned weights to numerical tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.program import CompiledConv, CompiledFC, CompiledNetwork
from repro.kernels.ops import pattern_spmm
from repro.kernels.ops import _pad_to as _pad_axis_to_mult
from repro.models.cnn import channel_norm, max_pool_2x2

__all__ = ["extract_patches", "make_forward", "execute"]


def extract_patches(x: jax.Array, k: int) -> jax.Array:
    """im2col for stride-1 'same' convs: [B, C, H, W] -> [B, H, W, C*k*k].

    Patch layout matches ``lowering.conv_matrix``: feature index is
    ``c * k*k + (dy*k + dx)``.
    """
    b, c, h, w = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    taps = [
        xp[:, :, dy : dy + h, dx : dx + w]
        for dy in range(k)
        for dx in range(k)
    ]
    patches = jnp.stack(taps, axis=-1)  # [B, C, H, W, k*k]
    return patches.transpose(0, 2, 3, 1, 4).reshape(b, h, w, c * k * k)


def _pad_features(x: jax.Array, to: int) -> jax.Array:
    """Zero-pad the feature axis up to ``to`` (the bp's padded K).

    The feature count never exceeds ``to``, so padding to a multiple of
    ``to`` via the shared kernels helper lands exactly on ``to``.
    """
    assert x.shape[-1] <= to
    return _pad_axis_to_mult(x, x.ndim - 1, to)


def _run_conv(
    op: CompiledConv,
    x: jax.Array,
    backend: str | None,
    interpret: bool | None,
    bm: int | None,
) -> jax.Array:
    b, c, h, w = x.shape
    patches = extract_patches(x, op.kernel)  # [B, H, W, C*k*k]
    patches = _pad_features(patches.reshape(b * h * w, -1), op.bp.k_in)
    y = pattern_spmm(patches, op.bp, backend=backend, interpret=interpret,
                     bm=bm)
    y = y[:, : op.c_out] + jnp.asarray(op.bias)
    y = y.reshape(b, h, w, op.c_out).transpose(0, 3, 1, 2)
    y = jax.nn.relu(channel_norm(y))
    if op.pool_after:
        y = max_pool_2x2(y)
    return y


def _run_fc(
    op: CompiledFC,
    x: jax.Array,
    backend: str | None,
    interpret: bool | None,
    bm: int | None,
) -> jax.Array:
    xf = _pad_features(x, op.bp.k_in)
    y = pattern_spmm(xf, op.bp, backend=backend, interpret=interpret, bm=bm)
    return y[:, : op.d_out] + jnp.asarray(op.bias)


def make_forward(
    program: CompiledNetwork,
    backend: str | None = None,
    interpret: bool | None = None,
    bm: int | None = None,
):
    """Build the jitted batched forward for ``program``.

    Args:
      backend: 'pallas' | 'xla' | None (auto: Pallas on TPU, XLA elsewhere).
      interpret: force Pallas interpret mode (None: auto off-TPU).
      bm: spmm row tile; None autotunes from the batch size.

    Returns: fn(x: [B, C, H, W]) -> logits [B, num_classes].
    """

    def forward(x: jax.Array) -> jax.Array:
        for op in program.convs:
            x = _run_conv(op, x, backend, interpret, bm)
        x = x.mean(axis=(2, 3))  # global average pool
        return _run_fc(program.fc, x, backend, interpret, bm)

    return jax.jit(forward)


def execute(
    program: CompiledNetwork,
    x: jax.Array,
    backend: str | None = None,
    interpret: bool | None = None,
    bm: int | None = None,
) -> jax.Array:
    """One-shot convenience wrapper around :func:`make_forward`.

    The jitted forward is cached on the program per dispatch options, so
    repeated calls don't re-trace.
    """
    cache = program.__dict__.setdefault("_forward_cache", {})
    key = (backend, interpret, bm)
    if key not in cache:
        cache[key] = make_forward(program, backend, interpret, bm)
    return cache[key](x)
