"""Executor: run a ``CompiledNetwork`` through the Pallas/XLA spmm kernels.

``make_forward`` returns a jitted batched forward: per conv layer it
extracts im2col patches (conv-as-spmm), dispatches through
``kernels/ops.pattern_spmm`` (Pallas TPU kernel, interpreted Pallas or XLA
fallback on CPU) — which applies the stored inverse output permutation
(the Output Indexing Unit) — then bias + shared ``channel_norm``/ReLU and
the 2x2 maxpool where the schedule says so, matching ``cnn_apply`` on the
pruned weights to numerical tolerance.

With ``collect_stats=True`` the forward additionally counts, per layer
and per OU row-group (= (input channel, pattern) pair), how many input
selections were entirely zero — the quantity the paper's Input
Preprocessing Unit skips on.  The counters are plain masked reductions
over the very patches the spmm consumes, so they are jit-compatible and
backend-agnostic: they ride alongside both the Pallas and the XLA spmm
dispatch unchanged.  ``engine/stats.py`` aggregates them and
``CompiledNetwork.hardware_report`` prices energy/cycles from them.

``channel_norm`` is strictly per-sample (spatial axes only), so every
batch row is computed independently of its neighbours: the same image
produces bit-identical logits alone, co-batched, or surrounded by
zero-padded dead slots.  The serving scheduler exploits that by always
executing one fixed ``batch_slots`` shape — the forward traces exactly
once — and passing a row-validity mask that excludes dead slots from the
skip counters and window totals, keeping the measured statistics exact.

Quantized programs (``precision='int8'`` at compile time) run through the
same dispatch unchanged: ``pattern_spmm`` sees the int8 bricks +
row-group scales on the ``BlockPatternWeight`` and switches to the
int8-input/int32-accumulate kernel variant, quantizing activations
per im2col row on the fly (``core/quantize.quantize_rows``).  One caveat:
sharded-vs-unsharded agreement for quantized programs is bounded by the
*quantization* error, not fp32 noise — an ulp-level reassociation
difference in one layer can flip an int8 rounding in the next layer's
dynamic activation quantization.

With ``mesh=`` the same program executes *sharded* across a device mesh
(``engine/partition.py``): each spmm runs tile-parallel under
``shard_map`` — every ``model``-axis device computes the output columns
of its contiguous slab of (zero-padded) tiles, scatters them into full
width, and a ``psum`` combines the partial outputs before the global
inverse permutation — while batch rows and the skip counters split over
the ``data`` axis (counters ``psum``-reduced back to the global count).
Padding tiles multiply zeros, so sharded and unsharded execution agree to
fp32 tolerance and the measured statistics agree exactly.
"""

from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.engine.partition import pad_bp_tiles, partition_from_mesh
from repro.engine.program import CompiledConv, CompiledFC, CompiledNetwork
from repro.engine.stats import (
    ActivationStats,
    skip_patterns_and_masks,
    stats_from_counts,
)
from repro.kernels.ops import pattern_spmm, pattern_spmm_raw
from repro.kernels.ops import _pad_to as _pad_axis_to_mult
from repro.models.cnn import channel_norm, max_pool_2x2
from repro.obs.trace import Tracer
from repro.parallel.sharding import shard_block_pattern

__all__ = ["extract_patches", "make_forward", "warmup_forward", "execute"]


def extract_patches(x: jax.Array, k: int) -> jax.Array:
    """im2col for stride-1 'same' convs: [B, C, H, W] -> [B, H, W, C*k*k].

    Patch layout matches ``lowering.conv_matrix``: feature index is
    ``c * k*k + (dy*k + dx)``.
    """
    b, c, h, w = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    taps = [
        xp[:, :, dy : dy + h, dx : dx + w]
        for dy in range(k)
        for dx in range(k)
    ]
    patches = jnp.stack(taps, axis=-1)  # [B, C, H, W, k*k]
    return patches.transpose(0, 2, 3, 1, 4).reshape(b, h, w, c * k * k)


def _pad_features(x: jax.Array, to: int) -> jax.Array:
    """Zero-pad the feature axis up to ``to`` (the bp's padded K).

    The feature count never exceeds ``to``, so padding to a multiple of
    ``to`` via the shared kernels helper lands exactly on ``to``.
    """
    assert x.shape[-1] <= to
    return _pad_axis_to_mult(x, x.ndim - 1, to)


def zero_selection_counts(
    patches: jax.Array,
    c_in: int,
    kk: int,
    masks: np.ndarray,
    row_valid: jax.Array | None = None,
) -> jax.Array:
    """Count all-zero input selections per OU row-group.

    patches: [M, c_in*kk] unpadded im2col windows; masks: [P, kk] bool,
    the layer's pattern position masks (``skip_patterns_and_masks``).
    Returns int32 [c_in, P]: entry (c, i) is the number of windows whose
    channel-c activations at ``masks[i]``'s positions are all zero — the
    selections the Input Preprocessing Unit would skip.  The all-zero
    pattern selects nothing and counts every window (vacuous all()).

    row_valid: optional bool [M]; ``False`` rows are excluded from every
    count.  The serving scheduler marks zero-padded dead batch slots this
    way — an all-zero padded row would otherwise count as 100%-skippable
    traffic and silently inflate the measured energy win.
    """
    m = patches.shape[0]
    z = patches.reshape(m, c_in, 1, kk) == 0.0
    keep = jnp.asarray(masks)[None, None]  # [1, 1, P, kk]
    all_zero = jnp.all(z | ~keep, axis=-1)  # [M, C, P]
    if row_valid is not None:
        all_zero = all_zero & row_valid[:, None, None]
    return all_zero.sum(axis=0, dtype=jnp.int32)


class _Dispatch:
    """Single-device spmm + stat-counter dispatch (the historical path)."""

    def __init__(self, backend, interpret, bm):
        self.backend = backend
        self.interpret = interpret
        self.bm = bm

    def prepare(self, bp):
        """Per-layer operand prep (identity here; padding when sharded)."""
        return bp

    def spmm(self, x2d: jax.Array, bp, prepared) -> jax.Array:
        return pattern_spmm(
            x2d, bp, backend=self.backend, interpret=self.interpret,
            bm=self.bm,
        )

    def counts(self, patches, c_in, kk, masks, row_valid=None) -> jax.Array:
        return zero_selection_counts(patches, c_in, kk, masks, row_valid)


class _ShardedDispatch(_Dispatch):
    """Mesh execution: tile-parallel spmm (scatter + psum over the model
    axis), batch rows and skip counters split over the data axis."""

    def __init__(self, backend, interpret, bm, mesh, part):
        super().__init__(backend, interpret, bm)
        self.mesh = mesh
        self.part = part

    def prepare(self, bp):
        """Pad the tile axis for the model shards and place the slabs."""
        return shard_block_pattern(
            pad_bp_tiles(bp, self.part.model), self.mesh,
            model_axis=self.part.model_axis,
        )

    def _data_spec(self, m: int) -> str | None:
        """Shard batch rows over 'data' when they divide; else replicate.

        The divisibility decision is made on static shapes at trace time,
        so partial service generations keep exact single-device numerics.
        """
        part = self.part
        return (
            part.data_axis if part.data > 1 and m % part.data == 0 else None
        )

    def spmm(self, x2d: jax.Array, bp, prepared) -> jax.Array:
        part = self.part
        model, maxis = part.model, part.model_axis
        width = (prepared.n_tiles // model) * bp.tile
        full_width = prepared.n_tiles * bp.tile
        dspec = self._data_spec(x2d.shape[0])
        mspec = maxis if model > 1 else None
        quantized = prepared.w_scales is not None

        def local(xl, w_comp, block_ids, *scales):
            # Quantized operands ride the same slab split: each device
            # holds its tiles' int8 bricks + row-group scales and
            # quantizes its (replicated-along-model) activation rows
            # identically, so the psum still combines disjoint column
            # slabs of already-dequantized fp32 partials.
            yl = pattern_spmm_raw(
                xl, w_comp, block_ids, bp.block,
                backend=self.backend, interpret=self.interpret, bm=self.bm,
                w_scales=scales[0] if quantized else None,
            )
            # The slabs are disjoint, so a tiled all_gather would also
            # reassemble them with less traffic; the scatter + psum form
            # is kept because it stays correct for any tile->device
            # assignment, not just the contiguous one.
            yf = jnp.zeros((xl.shape[0], full_width), yl.dtype)
            if model > 1:
                off = jax.lax.axis_index(maxis) * width
                yf = jax.lax.dynamic_update_slice(yf, yl, (0, off))
                yf = jax.lax.psum(yf, maxis)
            else:
                yf = jax.lax.dynamic_update_slice(yf, yl, (0, 0))
            return yf

        args = (x2d, prepared.w_comp, prepared.block_ids)
        in_specs = (P(dspec, None), P(mspec), P(mspec))
        if quantized:
            args += (prepared.w_scales,)
            in_specs += (P(mspec),)
        y = shard_map(
            local,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=P(dspec, None),
            check_rep=False,
        )(*args)
        # Output Indexing Unit: global inverse permutation after the psum
        # (padded columns sit past every inv_order entry and are dropped)
        y = jnp.take(y, jnp.asarray(bp.inv_order), axis=1)
        return y.astype(x2d.dtype)

    def counts(self, patches, c_in, kk, masks, row_valid=None) -> jax.Array:
        part = self.part
        dspec = self._data_spec(patches.shape[0])
        if dspec is None:
            return zero_selection_counts(patches, c_in, kk, masks, row_valid)

        def local(pl, *rv):
            return jax.lax.psum(
                zero_selection_counts(
                    pl, c_in, kk, masks, rv[0] if rv else None
                ),
                part.data_axis,
            )

        args = (patches,)
        in_specs: tuple = (P(dspec, None),)
        if row_valid is not None:
            # the per-sample validity rows shard with their patch rows
            args += (row_valid,)
            in_specs += (P(dspec),)
        return shard_map(
            local,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=P(None, None),
            check_rep=False,
        )(*args)


def _run_conv(
    op: CompiledConv,
    x: jax.Array,
    disp: _Dispatch,
    prepared,
    stat_masks: np.ndarray | None = None,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    b, c, h, w = x.shape
    patches = extract_patches(x, op.kernel)  # [B, H, W, C*k*k]
    patches = patches.reshape(b * h * w, -1)
    counts = None
    if stat_masks is not None:
        # every patch row belongs to one sample; dead-slot samples are
        # excluded from the skip counters
        row_valid = None if valid is None else jnp.repeat(valid, h * w)
        counts = disp.counts(
            patches, op.c_in, op.kernel * op.kernel, stat_masks, row_valid
        )
    patches = _pad_features(patches, op.bp.k_in)
    y = disp.spmm(patches, op.bp, prepared)
    y = y[:, : op.c_out] + jnp.asarray(op.bias)
    y = y.reshape(b, h, w, op.c_out).transpose(0, 3, 1, 2)
    y = jax.nn.relu(channel_norm(y))
    if op.pool_after:
        y = max_pool_2x2(y)
    return y, counts


def _run_fc(
    op: CompiledFC,
    x: jax.Array,
    disp: _Dispatch,
    prepared,
) -> jax.Array:
    xf = _pad_features(x, op.bp.k_in)
    y = disp.spmm(xf, op.bp, prepared)
    return y[:, : op.d_out] + jnp.asarray(op.bias)


def _layer_windows(
    program: CompiledNetwork, x_shape, live_rows: int | None = None
) -> dict[str, int]:
    """Windows (input positions) each conv layer sees for this input.

    ``live_rows`` overrides the batch size when some rows are dead slots
    (serving validity mask): only live samples contribute windows, so the
    measured skip fractions divide by exactly the traffic observed.
    """
    b, _, h, w = x_shape
    if live_rows is not None:
        b = live_rows
    windows = {}
    for op in program.convs:
        windows[op.name] = b * h * w
        if op.pool_after:
            h, w = h // 2, w // 2
    return windows


def make_forward(
    program: CompiledNetwork,
    backend: str | None = None,
    interpret: bool | None = None,
    bm: int | None = None,
    collect_stats: bool = False,
    mesh=None,
    partition=None,
    tracer: Tracer | None = None,
):
    """Build the jitted batched forward for ``program``.

    Args:
      backend: 'pallas' | 'xla' | None (auto: Pallas on TPU, XLA elsewhere).
      interpret: force Pallas interpret mode (None: auto off-TPU).
      bm: spmm row tile; None autotunes from the batch size.
      collect_stats: also measure per-layer all-zero-selection counts.
      mesh: a ``jax.sharding.Mesh`` to execute on.  Tiles split over the
        mesh's model axis (psum-combined partial outputs), batch rows and
        stat counters over the data axis; without a mesh the historical
        single-device path runs, and the two agree to fp32 tolerance.
      partition: explicit :class:`~repro.engine.partition.NetworkPartition`
        (defaults to ``program.partition``, else derived from the mesh);
        validated against the mesh's axis sizes.
      tracer: optional span tracer (``obs/trace.py``).  With an *enabled*
        tracer, calls run an **instrumented** layer-by-layer path: each
        layer's dispatch is wrapped in a ``layer:<name>`` span and
        blocked on (``block_until_ready``), so the span durations are
        real per-layer wall times, accumulated and exposed via
        ``fn.observed_times()`` — the measured side of
        ``hardware_report(observed=...)``'s predicted-vs-measured drift
        table.  The instrumented path computes the same numbers but is
        *not* the jitted whole-forward (per-layer blocking defeats
        op fusion across layers); use it to profile, not to serve.
        With ``tracer=None`` (or a disabled tracer) the historical jitted
        path runs byte-identically: no extra jit inputs, no clock reads,
        ``fn.trace_count()`` unchanged.

    Returns: fn(x: [B, C, H, W], valid=None) -> logits [B, num_classes],
    or, with ``collect_stats``, fn(x, valid=None) ->
    (logits, :class:`ActivationStats`).  ``valid`` is an optional bool
    [B] row-validity mask: the serving scheduler zero-pads dead batch
    slots and marks them ``False`` so the fixed batch shape traces once
    while the skip statistics (counters *and* window totals) cover only
    live traffic.  ``channel_norm`` is per-sample, so dead rows never
    influence live logits; their own outputs are meaningless and must be
    dropped by the caller.  The returned callable exposes
    ``fn.trace_count()``, the number of times the forward has been traced
    (a retrace means a new batch shape hit the jit cache), and
    ``fn.observed_times()``, the mean measured seconds per layer over the
    instrumented calls so far (empty until a traced call ran).
    """
    if mesh is None:
        if partition is not None:
            raise ValueError("partition= requires mesh=")
        disp: _Dispatch = _Dispatch(backend, interpret, bm)
    else:
        part = partition_from_mesh(mesh, partition or program.partition)
        disp = _ShardedDispatch(backend, interpret, bm, mesh, part)

    prepared = {op.name: disp.prepare(op.bp) for op in program.convs}
    prepared["fc"] = disp.prepare(program.fc.bp)

    stat_masks = {}
    if collect_stats:
        for op in program.convs:
            _, masks = skip_patterns_and_masks(
                op.pattern_bits, op.kernel * op.kernel
            )
            stat_masks[op.name] = masks

    traces = {"n": 0}

    def forward(x: jax.Array, valid: jax.Array | None = None):
        traces["n"] += 1  # python side effect: runs once per trace
        counts = {}
        for op in program.convs:
            x, cnt = _run_conv(
                op, x, disp, prepared[op.name], stat_masks.get(op.name),
                valid,
            )
            if cnt is not None:
                counts[op.name] = cnt
        x = x.mean(axis=(2, 3))  # global average pool
        logits = _run_fc(program.fc, x, disp, prepared["fc"])
        return (logits, counts) if collect_stats else logits

    jitted = jax.jit(forward)

    # per-layer wall time accumulated by the instrumented (traced) path:
    # name -> [calls, total seconds on the tracer's clock]
    observed: dict[str, list] = {}

    def _observe(name: str, seconds: float) -> None:
        acc = observed.setdefault(name, [0, 0.0])
        acc[0] += 1
        acc[1] += seconds

    def instrumented(x: jax.Array, valid: jax.Array | None):
        """Eager layer-by-layer forward: same math, spans + blocking per
        layer so each span's duration is that layer's real wall time."""
        with tracer.span(
            "forward", cat="execute", batch=int(x.shape[0])
        ) as fsp:
            counts = {}
            for op in program.convs:
                with tracer.span(
                    f"layer:{op.name}", cat="execute", op="conv"
                ) as sp:
                    x, cnt = _run_conv(
                        op, x, disp, prepared[op.name],
                        stat_masks.get(op.name), valid,
                    )
                    x = jax.block_until_ready(x)
                _observe(op.name, sp.dur)
                if cnt is not None:
                    counts[op.name] = cnt
            with tracer.span("layer:gap", cat="execute", op="pool"):
                x = jax.block_until_ready(x.mean(axis=(2, 3)))
            with tracer.span("layer:fc", cat="execute", op="fc") as sp:
                logits = jax.block_until_ready(
                    _run_fc(program.fc, x, disp, prepared["fc"])
                )
            _observe("fc", sp.dur)
            fsp.args["layers"] = len(program.convs) + 2
        return (logits, counts) if collect_stats else logits

    def _dispatch(x, valid):
        if tracer is not None and tracer.enabled:
            return instrumented(x, valid)
        return jitted(x, valid)

    def _as_valid(valid):
        return None if valid is None else jnp.asarray(valid, bool)

    if not collect_stats:
        def fn(x: jax.Array, valid=None) -> jax.Array:
            return _dispatch(x, _as_valid(valid))
    else:
        def fn(
            x: jax.Array, valid=None
        ) -> tuple[jax.Array, ActivationStats]:
            logits, counts = _dispatch(x, _as_valid(valid))
            live = None if valid is None else int(np.asarray(valid).sum())
            stats = stats_from_counts(
                program.convs,
                {k: np.asarray(v) for k, v in counts.items()},
                _layer_windows(program, x.shape, live_rows=live),
            )
            return logits, stats

    fn.trace_count = lambda: traces["n"]
    fn.observed_times = lambda: {
        name: total / calls for name, (calls, total) in observed.items()
    }
    return fn


def warmup_forward(fn, program: CompiledNetwork, batch_slots: int):
    """Trace ``fn`` at the fixed serving batch shape, before traffic.

    Runs one all-dead batch — zeros with an all-``False`` validity mask,
    exactly the shape/dtype signature the serving scheduler executes —
    and blocks until ready, so a front end pays jit tracing (and
    compilation) at boot instead of on its first request, without
    pushing a synthetic request through the scheduler (boot leaves the
    served-traffic metrics untouched).  Returns ``fn``.
    """
    cfg = program.config
    x = jnp.zeros(
        (batch_slots, cfg.conv_channels[0][0], cfg.input_hw, cfg.input_hw),
        jnp.float32,
    )
    valid = np.zeros(batch_slots, bool)
    out = fn(x, valid)
    jax.block_until_ready(out[0] if isinstance(out, tuple) else out)
    return fn


# `execute`'s per-program forward cache would otherwise retain every mesh
# ever passed (device buffers included) for the program's lifetime.
_FORWARD_CACHE_MAX = 8


def _dispatch_key(backend, interpret, bm, mesh, partition):
    """Stable, value-based cache key for a dispatch configuration.

    Meshes are fingerprinted by axis names/shape and device ids rather
    than object identity, so two equal meshes share one cache entry and a
    dropped mesh object is not pinned alive by the key.  ``partition`` is
    a frozen dataclass and hashes by value already.
    """
    mesh_key = None
    if mesh is not None:
        devices = np.asarray(mesh.devices)
        mesh_key = (
            tuple(mesh.axis_names),
            devices.shape,
            tuple(int(d.id) for d in devices.ravel()),
        )
    return (backend, interpret, bm, mesh_key, partition)


def execute(
    program: CompiledNetwork,
    x: jax.Array,
    backend: str | None = None,
    interpret: bool | None = None,
    bm: int | None = None,
    mesh=None,
    partition=None,
) -> jax.Array:
    """One-shot convenience wrapper around :func:`make_forward`.

    The jitted forward is LRU-cached on the program per dispatch
    configuration (mesh fingerprint, not identity), capped at
    ``_FORWARD_CACHE_MAX`` entries so long-lived programs don't pin every
    mesh/partition they ever executed on.
    """
    cache = program.__dict__.get("_forward_cache")
    if not isinstance(cache, OrderedDict):
        cache = program.__dict__["_forward_cache"] = OrderedDict()
    key = _dispatch_key(backend, interpret, bm, mesh, partition)
    fwd = cache.get(key)
    if fwd is None:
        fwd = make_forward(
            program, backend, interpret, bm, mesh=mesh, partition=partition
        )
        cache[key] = fwd
        while len(cache) > _FORWARD_CACHE_MAX:
            cache.popitem(last=False)
    else:
        cache.move_to_end(key)
    return fwd(x)
