"""End-to-end accelerator simulation: area / energy / cycles (paper §V).

Compares, per layer and aggregated:

  naive   — Fig-1 mapping (filters as columns, zeros stored), OU mechanism,
            no input preprocessing -> no activation-sparsity skipping.
  pattern — kernel-reordering mapping (this paper): compressed pattern
            blocks, OU limited to a block, input preprocessing selects only
            the pattern's activations and skips all-zero selections.

Metrics:
  area   — crossbar count (Fig 7: 'crossbar array numbers').
  energy — sum over OU activations of Table-I component energies, weighted
           by windows and by the expected non-skip probability (Fig 8).
  cycles — layers execute sequentially, crossbars within a layer in
           parallel, one OU activation per crossbar per cycle: cycles =
           windows * max over crossbars of expected OU activations (§V-C).

Activation zero statistics come from an actual forward pass of the network
(im2col convs + ReLU, unit-variance renormalisation standing in for BN),
sampled at ``n_windows`` output positions per layer.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.crossbar import EnergyModel
from repro.core.indexing import build_index_stream, index_overhead_bits
from repro.core.mapping import (
    CrossbarConfig,
    MappingCandidate,
    map_layer,
    map_layer_naive,
)
from repro.core.ou import OUSchedule, naive_ou_schedule, pattern_ou_schedule
from repro.core.patterns import bits_to_mask
from repro.core.synthetic import (
    SyntheticLayer,
    TABLE_II,
    synthesize_network,
)

__all__ = [
    "LayerResult",
    "MappingCost",
    "SimulationReport",
    "SkipDistribution",
    "drift_table",
    "mapping_cost",
    "simulate_layer",
    "simulate_layer_multi",
    "simulate_network",
    "simulate_dataset",
    "forward_zero_stats",
]


# ---------------------------------------------------------------------------
# activation statistics
# ---------------------------------------------------------------------------


def _im2col(x: np.ndarray, k: int = 3, pad: int = 1) -> np.ndarray:
    """x: [B, C, H, W] -> patches [B, H, W, C, k*k] (stride 1, 'same')."""
    b, c, h, w = x.shape
    xp = np.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    out = np.empty((b, h, w, c, k * k), dtype=x.dtype)
    idx = 0
    for dy in range(k):
        for dx in range(k):
            out[..., idx] = xp[:, :, dy : dy + h, dx : dx + w].transpose(0, 2, 3, 1)
            idx += 1
    return out


def forward_zero_stats(
    layers: list[SyntheticLayer],
    input_hw: int,
    batch: int = 2,
    n_windows: int = 256,
    seed: int = 0,
) -> list[np.ndarray]:
    """Forward random inputs through the synthetic net; return, per layer,
    a boolean zero-indicator array [n_windows, C_in, 9] over sampled output
    positions of that layer's input patches."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, layers[0].spec.c_in, input_hw, input_hw)).astype(
        np.float32
    )
    # first layer input is an image: no ReLU zeros, but keep the real stats
    stats: list[np.ndarray] = []
    hw = input_hw
    for i, layer in enumerate(layers):
        spec = layer.spec
        patches = _im2col(x)  # [B, H, W, C, 9]
        b, h, w_, c, kk = patches.shape
        flat = patches.reshape(b * h * w_, c, kk)
        take = min(n_windows, flat.shape[0])
        sel = rng.choice(flat.shape[0], size=take, replace=False)
        stats.append(flat[sel] == 0.0)

        wmat = layer.weights.reshape(spec.c_out, spec.c_in * kk).T  # [C*9, C_out]
        y = flat.reshape(b * h * w_, c * kk) @ wmat
        y = y.reshape(b, h, w_, spec.c_out).transpose(0, 3, 1, 2)
        std = y.std()
        y = y / (std if std > 0 else 1.0)  # BN stand-in
        y = np.maximum(y, 0.0)  # ReLU
        # pool when the *next* layer's spatial size shrinks
        if i + 1 < len(layers) and layers[i + 1].spec.out_hw < spec.out_hw:
            b2, c2, h2, w2 = y.shape
            y = y[:, :, : h2 // 2 * 2, : w2 // 2 * 2]
            y = y.reshape(b2, c2, h2 // 2, 2, w2 // 2, 2).max(axis=(3, 5))
        x = y.astype(np.float32)
        hw = x.shape[-1]
    return stats


@dataclasses.dataclass
class SkipDistribution:
    """Empirical all-zero-input-selection probabilities per OU row-group.

    ``probs[(channel, pattern)]`` is the measured probability that the
    input selection feeding an OU of that (channel, pattern bitmask) pair
    is entirely zero — e.g. counted by the inference engine on real served
    activations (``engine/stats.py``).  ``windows`` records the sample
    size; pairs not measured fall back to ``default`` (an *assumed*
    probability; 0.0 keeps the no-skip upper bound).
    """

    probs: dict[tuple[int, int], float] = dataclasses.field(
        default_factory=dict
    )
    windows: int = 0
    default: float = 0.0

    def fraction(self, channel: int, pattern: int) -> float:
        return float(
            self.probs.get((int(channel), int(pattern)), self.default)
        )


def _skip_fractions(
    sched: OUSchedule, zero_ind: "np.ndarray | SkipDistribution | float | None"
) -> np.ndarray:
    """Expected all-zero-input fraction per OU (0 if no stats / channel=-1).

    ``zero_ind`` selects the skip-probability source:
      * None            — no skipping (upper-bound energy);
      * float p         — *assumed* uniform probability p for every
                          channel-attributed OU;
      * SkipDistribution — *measured* per-(channel, pattern) probabilities;
      * ndarray [W,C,k] — boolean zero indicators from a sampled forward
                          pass (``forward_zero_stats``).
    """
    n = len(sched)
    if zero_ind is None or n == 0:
        return np.zeros(n)
    if isinstance(zero_ind, (int, float, np.integer, np.floating)):
        return np.where(sched.channel >= 0, float(zero_ind), 0.0)
    if isinstance(zero_ind, SkipDistribution):
        skip = np.zeros(n)
        for i in range(n):
            ch = int(sched.channel[i])
            if ch < 0:
                continue
            skip[i] = zero_ind.fraction(ch, int(sched.pattern[i]))
        return skip
    skip = np.zeros(n)
    # group by (channel, pattern) — few unique pairs per layer
    pairs = {}
    for i in range(n):
        ch, pat = int(sched.channel[i]), int(sched.pattern[i])
        if ch < 0:
            continue
        pairs.setdefault((ch, pat), []).append(i)
    k = zero_ind.shape[-1]
    for (ch, pat), idxs in pairs.items():
        if ch >= zero_ind.shape[1]:
            continue
        pos = np.nonzero(bits_to_mask(pat, k))[0]
        if pos.size == 0:
            frac = 1.0
        else:
            frac = float(np.all(zero_ind[:, ch, pos], axis=1).mean())
        skip[idxs] = frac
    return skip


# ---------------------------------------------------------------------------
# per-layer simulation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerResult:
    name: str
    windows: int
    naive_crossbars: int
    ours_crossbars: int
    naive_energy_pj: float
    ours_energy_pj: float
    naive_cycles: float
    ours_cycles: float
    naive_breakdown: dict[str, float]
    ours_breakdown: dict[str, float]
    index_bits: int
    stored_kernels: int
    total_kernels: int
    utilization: float
    # crossbar area in *cells* — the comparable unit once per-layer
    # crossbar dims differ (a searched 128x128 crossbar is not a 512x512)
    naive_area_cells: int = 0
    ours_area_cells: int = 0


def _sched_energy_cycles(
    sched: OUSchedule,
    skip: np.ndarray,
    windows: int,
    energy: EnergyModel,
) -> tuple[float, float, dict[str, float]]:
    live = 1.0 - skip
    e_per = energy.ou_energy(sched.wordlines, sched.bitlines)
    total_e = float((e_per * live).sum()) * windows
    breakdown = energy.breakdown(sched.wordlines, sched.bitlines, live)
    breakdown = {k: v * windows for k, v in breakdown.items()}
    if len(sched) == 0:
        return 0.0, 0.0, breakdown
    per_xbar = np.bincount(
        sched.crossbar, weights=live, minlength=sched.num_crossbars
    )
    cycles = float(per_xbar.max()) * windows
    return total_e, cycles, breakdown


def simulate_layer_multi(
    layer: SyntheticLayer,
    skip_sources: dict,
    config: CrossbarConfig = CrossbarConfig(),
    energy: EnergyModel = EnergyModel(),
    naive_skips: bool = False,
    block_order: str = "pattern",
    naive_config: CrossbarConfig | None = None,
) -> dict[str, LayerResult]:
    """Price one layer under several skip-probability sources at once.

    Mapping, OU schedules and the index stream depend only on the pattern
    bits, so they are computed once and re-priced per entry of
    ``skip_sources`` (name -> any ``_skip_fractions`` source) — pricing a
    layer no-skip/assumed/measured costs one ``map_layer``, not three.

    ``block_order`` is forwarded to ``map_layer`` (the pattern-pruned
    side only).  ``naive_config`` prices the Fig-1 baseline at a
    different geometry than ``config`` — when a searched per-layer
    mapping shrinks the crossbar, the naive comparison must stay at the
    *reference* geometry or the area-efficiency ratio silently inflates;
    ``None`` keeps both sides on ``config`` (the historical behaviour).
    """
    spec = layer.spec
    windows = spec.out_hw * spec.out_hw

    mapping = map_layer(layer.pattern_bits, config, spec.kernel_size,
                        block_order)
    sched_ours = pattern_ou_schedule(mapping)
    naive = map_layer_naive(spec.c_out, spec.c_in, spec.kernel_size,
                            naive_config if naive_config is not None
                            else config)
    sched_nv = naive_ou_schedule(naive)
    stream = build_index_stream(mapping)
    idx = index_overhead_bits(stream)

    out = {}
    for key, zero_ind in skip_sources.items():
        skip_ours = _skip_fractions(sched_ours, zero_ind)
        e_ours, cyc_ours, bd_ours = _sched_energy_cycles(
            sched_ours, skip_ours, windows, energy
        )
        skip_nv = (
            _skip_fractions(sched_nv, zero_ind)
            if naive_skips
            else np.zeros(len(sched_nv))
        )
        e_nv, cyc_nv, bd_nv = _sched_energy_cycles(
            sched_nv, skip_nv, windows, energy
        )
        out[key] = LayerResult(
            name=spec.name,
            windows=windows,
            naive_crossbars=naive.num_crossbars,
            ours_crossbars=mapping.num_crossbars,
            naive_energy_pj=e_nv,
            ours_energy_pj=e_ours,
            naive_cycles=cyc_nv,
            ours_cycles=cyc_ours,
            naive_breakdown=bd_nv,
            ours_breakdown=bd_ours,
            index_bits=idx["total_bits"],
            stored_kernels=mapping.stored_kernels,
            total_kernels=mapping.total_kernels,
            utilization=mapping.utilization,
            naive_area_cells=naive.cells_total,
            ours_area_cells=mapping.cells_total,
        )
    return out


# ---------------------------------------------------------------------------
# mapping cost model (design-space search)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MappingCost:
    """Predicted hardware cost of one :class:`MappingCandidate`.

    Produced by :func:`mapping_cost` through the *same* pricing chain as
    :func:`simulate_layer_multi` (``map_layer`` → ``pattern_ou_schedule``
    → ``_sched_energy_cycles``), so every number here equals the
    simulator's no-skip pricing of the realized mapping bit-for-bit —
    the property suite asserts zero drift, not a tolerance.
    """

    crossbars: int
    area_cells: int
    energy_pj: float
    cycles: float
    utilization: float


def mapping_cost(
    pattern_bits: np.ndarray,
    candidate: MappingCandidate,
    windows: int,
    kernel_size: int = 9,
    energy: EnergyModel = EnergyModel(),
) -> MappingCost:
    """Price ``candidate`` on a layer's pattern bits without skipping.

    This is the pure cost model the mapping search minimizes.  It is the
    no-skip (upper bound) pricing: search must not depend on activation
    statistics, which vary per served batch, or the chosen mapping would
    not be a compile-time constant.
    """
    cfg = candidate.crossbar_config()
    mapping = map_layer(pattern_bits, cfg, kernel_size,
                        candidate.block_order)
    sched = pattern_ou_schedule(mapping)
    e, cyc, _ = _sched_energy_cycles(
        sched, np.zeros(len(sched)), windows, energy
    )
    return MappingCost(
        crossbars=mapping.num_crossbars,
        area_cells=mapping.cells_total,
        energy_pj=e,
        cycles=cyc,
        utilization=mapping.utilization,
    )


def drift_table(
    predicted_cycles: dict[str, float],
    measured_s: dict[str, float],
) -> dict:
    """Predicted-vs-measured cost drift across layers.

    The simulator predicts per-layer *cycles*; the instrumented executor
    measures per-layer *seconds* — incommensurable units, so the honest
    comparison is each layer's **share** of the network total: a perfect
    cost model assigns every layer the same fraction of predicted cycles
    as of measured wall time.  Per layer the table reports both shares,
    their difference (``share_drift``, positive = the layer is more
    expensive in reality than predicted), and the implied seconds/cycle
    rate; the summary's ``rate_spread`` (max/min implied rate over
    layers) is 1.0 exactly when prediction and measurement are
    proportional, and grows with model error.  This is the trust signal
    a mapping optimizer needs before it searches over simulator pricing.

    Layers present on only one side are listed (``unmeasured`` /
    ``unpredicted``) rather than silently dropped.
    """
    common = [n for n in predicted_cycles if n in measured_s]
    tot_p = sum(float(predicted_cycles[n]) for n in common)
    tot_m = sum(float(measured_s[n]) for n in common)
    rows = []
    for name in common:
        pred = float(predicted_cycles[name])
        meas = float(measured_s[name])
        p_share = pred / tot_p if tot_p > 0 else 0.0
        m_share = meas / tot_m if tot_m > 0 else 0.0
        rows.append(
            {
                "name": name,
                "predicted_cycles": pred,
                "measured_s": meas,
                "predicted_share": p_share,
                "measured_share": m_share,
                "share_drift": m_share - p_share,
                "s_per_cycle": meas / pred if pred > 0 else None,
            }
        )
    rates = [r["s_per_cycle"] for r in rows if r["s_per_cycle"]]
    drifts = [abs(r["share_drift"]) for r in rows]
    return {
        "layers": rows,
        "max_abs_share_drift": max(drifts, default=0.0),
        "mean_abs_share_drift": (
            sum(drifts) / len(drifts) if drifts else 0.0
        ),
        "rate_spread": (max(rates) / min(rates)) if rates else None,
        "unmeasured": sorted(set(predicted_cycles) - set(measured_s)),
        "unpredicted": sorted(set(measured_s) - set(predicted_cycles)),
    }


def simulate_layer(
    layer: SyntheticLayer,
    zero_ind: "np.ndarray | SkipDistribution | float | None",
    config: CrossbarConfig = CrossbarConfig(),
    energy: EnergyModel = EnergyModel(),
    naive_skips: bool = False,
) -> LayerResult:
    return simulate_layer_multi(
        layer, {"_": zero_ind}, config, energy, naive_skips
    )["_"]


@dataclasses.dataclass
class SimulationReport:
    dataset: str
    layers: list[LayerResult]

    def _sum(self, attr: str) -> float:
        return float(sum(getattr(l, attr) for l in self.layers))

    @property
    def area_efficiency(self) -> float:
        return self._sum("naive_crossbars") / max(self._sum("ours_crossbars"), 1)

    @property
    def crossbar_savings(self) -> float:
        return 1.0 - self._sum("ours_crossbars") / max(
            self._sum("naive_crossbars"), 1
        )

    @property
    def energy_efficiency(self) -> float:
        return self._sum("naive_energy_pj") / max(self._sum("ours_energy_pj"), 1e-9)

    @property
    def speedup(self) -> float:
        return self._sum("naive_cycles") / max(self._sum("ours_cycles"), 1e-9)

    @property
    def index_overhead_kb(self) -> float:
        return self._sum("index_bits") / 8.0 / 1024.0

    def breakdown(self, which: str) -> dict[str, float]:
        out: dict[str, float] = {}
        for l in self.layers:
            for k, v in getattr(l, f"{which}_breakdown").items():
                out[k] = out.get(k, 0.0) + v
        return out

    def summary(self) -> dict[str, float]:
        return {
            "area_efficiency": self.area_efficiency,
            "crossbar_savings": self.crossbar_savings,
            "energy_efficiency": self.energy_efficiency,
            "speedup": self.speedup,
            "index_overhead_kb": self.index_overhead_kb,
            "naive_crossbars": self._sum("naive_crossbars"),
            "ours_crossbars": self._sum("ours_crossbars"),
        }


def simulate_network(
    dataset: str,
    layers: list[SyntheticLayer],
    input_hw: int,
    config: CrossbarConfig = CrossbarConfig(),
    energy: EnergyModel = EnergyModel(),
    naive_skips: bool = False,
    n_windows: int = 256,
    stats_hw: int | None = None,
    batch: int = 2,
    seed: int = 0,
) -> SimulationReport:
    """Simulate all layers; ``stats_hw`` can downscale the forward pass used
    for activation statistics (window *counts* always use the true size)."""
    stats = forward_zero_stats(
        layers, stats_hw or input_hw, batch=batch, n_windows=n_windows, seed=seed
    )
    results = [
        simulate_layer(layer, zi, config, energy, naive_skips)
        for layer, zi in zip(layers, stats)
    ]
    return SimulationReport(dataset=dataset, layers=results)


def simulate_dataset(
    dataset: str,
    seed: int = 0,
    naive_skips: bool = False,
    config: CrossbarConfig = CrossbarConfig(),
    stats_hw: int | None = None,
) -> SimulationReport:
    """Synthesize the Table-II-matched network for ``dataset`` and simulate."""
    stats, layers = synthesize_network(dataset, seed=seed)
    if stats_hw is None and dataset == "imagenet":
        stats_hw = 112  # forward-pass downscale for CPU time; counts use 224
    return simulate_network(
        dataset,
        layers,
        stats.input_hw,
        config=config,
        naive_skips=naive_skips,
        stats_hw=stats_hw,
        batch=1 if dataset == "imagenet" else 2,
        seed=seed,
    )
