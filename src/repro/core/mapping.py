"""Kernel-reordering weight mapping onto RRAM crossbars (paper §III-B, Figs 4-5).

Workflow, per convolution layer and per input channel:

  1. group kernels (one per output channel) by their pattern,
  2. drop all-zero-pattern kernels entirely (never stored, never computed),
  3. compress each group by deleting the pattern's zero rows -> a dense
     *pattern block* of shape [pattern_size, n_kernels_with_that_pattern],
  4. sort the channel's blocks by pattern size (rows) descending,
  5. greedily pack blocks onto 512x512 crossbars:
       - the first block opens a column *strip* at the top,
       - the next block goes *below* the previous one (left-aligned) if the
         strip has enough rows left,
       - otherwise it opens a new strip in fresh columns (top-aligned); the
         rows left behind in the old strip are wasted ("grey area"),
  6. channels are mapped one after another onto the same running packing
     ("store all the weights channel by channel").

Each 16-bit weight occupies ``cells_per_weight`` adjacent 4-bit cells
(bit-slicing); widths below are tracked in *cells*.

The mapping also emits the index stream the architecture needs (paper §IV-C,
§V-D): per stored kernel, its output-channel index; per layer, the pattern
shape table.  ``indexing.py`` sizes the overhead, ``simulator.py`` prices
energy/cycles, ``ou.py`` derives the OU schedule.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from repro.core.patterns import ALL_ZERO, PatternDict, pattern_sizes

__all__ = [
    "BLOCK_ORDERS",
    "CrossbarConfig",
    "MappingCandidate",
    "Placement",
    "PatternBlock",
    "LayerMapping",
    "NaiveMapping",
    "map_layer",
    "map_layer_naive",
]

# packing orders map_layer understands; the optimizer (core/mapsearch.py)
# searches over them and the verifier (V205) rejects anything else
BLOCK_ORDERS = ("pattern", "channel", "width", "similarity", "hybrid")


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Hardware geometry (paper Table I)."""

    rows: int = 512
    cols: int = 512  # in cells
    cells_per_weight: int = 4  # 16-bit weights / 4 bits per cell
    ou_rows: int = 9
    ou_cols: int = 8  # in cells

    @property
    def weight_cols(self) -> int:
        return self.cols // self.cells_per_weight


@dataclasses.dataclass(frozen=True)
class MappingCandidate:
    """One point of the mapping design space (geometry + strategy tags).

    A candidate pins down everything ``hardware_report`` needs to price a
    layer — crossbar dims, cells per weight, OU shape, the crossbar
    packing order (``block_order``, a ``map_layer`` order) — plus the
    operand-level column ``reorder`` strategy
    (``core/sparse.reorder_columns``), which never changes the priced
    hardware numbers but does change the compressed operand's brick
    count.  ``core/mapsearch.py`` searches over candidates per layer;
    the chosen one rides on ``CompiledConv.mapping`` and in the saved
    manifest (format v3).

    Deliberately *not* validated at construction: the verifier
    (V205/V206) owns validity so corrupted saves surface as diagnostics,
    not construction errors.
    """

    rows: int = 512
    cols: int = 512  # in cells
    cells_per_weight: int = 4
    ou_rows: int = 9
    ou_cols: int = 8  # in cells
    block_order: str = "pattern"
    reorder: str = "pattern"

    def crossbar_config(self) -> CrossbarConfig:
        return CrossbarConfig(
            rows=self.rows,
            cols=self.cols,
            cells_per_weight=self.cells_per_weight,
            ou_rows=self.ou_rows,
            ou_cols=self.ou_cols,
        )

    def sort_key(self) -> tuple:
        """Deterministic total order (search tie-breaking)."""
        return (
            self.rows, self.cols, self.cells_per_weight,
            self.ou_rows, self.ou_cols, self.block_order, self.reorder,
        )

    def to_manifest(self) -> dict:
        return {
            "rows": self.rows,
            "cols": self.cols,
            "cells_per_weight": self.cells_per_weight,
            "ou_rows": self.ou_rows,
            "ou_cols": self.ou_cols,
            "block_order": self.block_order,
            "reorder": self.reorder,
        }

    @classmethod
    def from_manifest(cls, entry: dict) -> "MappingCandidate":
        return cls(
            rows=int(entry["rows"]),
            cols=int(entry["cols"]),
            cells_per_weight=int(entry["cells_per_weight"]),
            ou_rows=int(entry["ou_rows"]),
            ou_cols=int(entry["ou_cols"]),
            block_order=str(entry["block_order"]),
            reorder=str(entry["reorder"]),
        )


@dataclasses.dataclass(frozen=True)
class PatternBlock:
    """A compressed dense block: kernels of one pattern in one input channel."""

    channel: int  # input channel index
    pattern: int  # pattern bitmask
    height: int  # pattern size (rows)
    kernel_ids: tuple[int, ...]  # output-channel indices, in mapped order

    @property
    def n_kernels(self) -> int:
        return len(self.kernel_ids)


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where one (possibly split) block landed."""

    block: PatternBlock
    crossbar: int
    row0: int
    col0: int  # in cells
    width_cells: int

    @property
    def height(self) -> int:
        return self.block.height


@dataclasses.dataclass
class LayerMapping:
    """Result of mapping one layer with the pattern-pruned scheme."""

    config: CrossbarConfig
    placements: list[Placement]
    num_crossbars: int
    cells_used: int  # nonzero weight cells actually stored
    cells_wasted: int  # grey area inside claimed strips
    stored_kernels: int  # kernel instances with a nonzero pattern
    total_kernels: int  # C_out * C_in kernel instances
    c_out: int
    c_in: int
    kernel_size: int

    @property
    def cells_total(self) -> int:
        return self.num_crossbars * self.config.rows * self.config.cols

    @property
    def utilization(self) -> float:
        return self.cells_used / max(self.cells_total, 1)


@dataclasses.dataclass
class NaiveMapping:
    """The Fig-1 baseline: one filter per logical column, zeros stored."""

    config: CrossbarConfig
    num_crossbars: int
    rows_total: int  # C_in * K
    cols_total: int  # C_out * cells_per_weight
    c_out: int
    c_in: int
    kernel_size: int

    @property
    def cells_total(self) -> int:
        return self.num_crossbars * self.config.rows * self.config.cols


class _Packer:
    """Greedy strip packer over a growing list of crossbars (Fig 5)."""

    def __init__(self, config: CrossbarConfig):
        self.cfg = config
        self.crossbar = 0
        self.col0 = 0  # start column (cells) of the current strip
        self.strip_w = 0  # current strip width (cells)
        self.row = 0  # next free row in the current strip
        self.wasted = 0
        self.placements: list[Placement] = []

    def _open_strip(self, w: int, h: int) -> tuple[int, int, int]:
        cfg = self.cfg
        # account waste left behind in the strip we are abandoning
        if self.strip_w > 0:
            self.wasted += (cfg.rows - self.row) * self.strip_w
        self.col0 += self.strip_w
        if self.col0 + w > cfg.cols:
            # move to a fresh crossbar; the rest of this one is waste
            self.wasted += (cfg.cols - self.col0) * cfg.rows
            self.crossbar += 1
            self.col0 = 0
        self.strip_w = w
        self.row = h
        return self.crossbar, 0, self.col0

    def place(self, block: PatternBlock, width_cells: int) -> None:
        cfg = self.cfg
        h, w = block.height, width_cells
        if w > cfg.cols:
            raise ValueError("block wider than crossbar; split before placing")
        if self.strip_w > 0 and cfg.rows - self.row >= h:
            # place below the previous block, left-aligned
            xb, r0, c0 = self.crossbar, self.row, self.col0
            if w > self.strip_w:
                if self.col0 + w <= cfg.cols:
                    # widen the strip; the rows above the widened part are grey
                    self.wasted += self.row * (w - self.strip_w)
                    self.strip_w = w
                else:
                    xb, r0, c0 = self._open_strip(w, h)
                    self.placements.append(
                        Placement(block, xb, r0, c0, w)
                    )
                    return
            if w < self.strip_w:
                self.wasted += h * (self.strip_w - w)
            self.row += h
            self.placements.append(Placement(block, xb, r0, c0, w))
        else:
            xb, r0, c0 = self._open_strip(w, h)
            self.placements.append(Placement(block, xb, r0, c0, w))

    def finish(self) -> tuple[int, int]:
        """Returns (num_crossbars, wasted_cells_inside_claimed_area)."""
        if self.strip_w > 0:
            self.wasted += (self.cfg.rows - self.row) * self.strip_w
        used_crossbars = self.crossbar + 1 if self.placements else 0
        return used_crossbars, self.wasted


def _blocks_for_channel(
    channel: int,
    bits_c: np.ndarray,
    sizes_c: np.ndarray,
) -> list[PatternBlock]:
    """Group one input channel's kernels by pattern (paper Fig 4 reorder)."""
    blocks: dict[int, list[int]] = {}
    for out_ch, b in enumerate(bits_c):
        b = int(b)
        if b == ALL_ZERO:
            continue
        blocks.setdefault(b, []).append(out_ch)
    out = [
        PatternBlock(
            channel=channel,
            pattern=b,
            height=int(sizes_c[kernels[0]]),
            kernel_ids=tuple(kernels),
        )
        for b, kernels in blocks.items()
    ]
    # sort by pattern size descending (paper Fig 5), stable by pattern id
    out.sort(key=lambda blk: (-blk.height, blk.pattern))
    return out


def _pattern_similarity_rank(patterns: Iterable[int]) -> dict[int, int]:
    """Greedy nearest-neighbour chain over a layer's unique patterns.

    Starts from the largest pattern (most set bits; ties toward the
    smaller bitmask) and repeatedly appends the unvisited pattern with
    the greatest bit overlap with the current one (ties: smaller
    symmetric difference, then smaller bitmask) — the bit-level
    column-similarity ordering of arXiv 2511.14202 applied at pattern
    granularity.  Returns pattern -> chain rank; fully deterministic.
    """
    uniq = sorted(set(int(p) for p in patterns))
    if not uniq:
        return {}
    pop = {p: bin(p).count("1") for p in uniq}
    cur = min(uniq, key=lambda p: (-pop[p], p))
    remaining = set(uniq)
    rank: dict[int, int] = {}
    while True:
        rank[cur] = len(rank)
        remaining.discard(cur)
        if not remaining:
            return rank
        cur = min(
            remaining,
            key=lambda p: (-bin(cur & p).count("1"),
                           bin(cur ^ p).count("1"), p),
        )


def map_layer(
    pattern_bits: np.ndarray,
    config: CrossbarConfig = CrossbarConfig(),
    kernel_size: int = 9,
    block_order: str = "pattern",
) -> LayerMapping:
    """Map one layer's pattern-pruned kernels onto crossbars.

    Args:
      pattern_bits: [C_out, C_in] packed pattern bitmask per kernel instance.
      config: crossbar geometry.
      kernel_size: flattened kernel size (9 for 3x3).
      block_order: packing order of the pattern blocks.
        'pattern' — all blocks sorted by (pattern size desc, pattern,
          channel): same-pattern blocks are adjacent, so strips hold blocks
          of near-identical width.  This matches the paper's index layout
          ('we store the indexes pattern by pattern in the same order as
          mapping the pattern blocks to the crossbar') and is required to
          reach the paper's reported area efficiency.  Default.
        'channel' — the paper's §III-B narration read literally: channels
          one after another, blocks sorted by pattern size inside each
          channel.  Mixes block widths inside strips and packs much worse;
          kept for comparison.
        'width' — beyond-paper: global sort by width desc then height desc
          (best-fit-decreasing flavour); slightly better than 'pattern'.
        'similarity' — beyond-paper: blocks follow the greedy
          pattern-similarity chain (``_pattern_similarity_rank``), width
          descending within a pattern, so strips hold near-identical
          *shapes* even when pattern ids are far apart.
        'hybrid' — beyond-paper: height descending first (the packer's
          strongest signal), similarity-chain rank within equal heights.

    Returns:
      LayerMapping with placements and area accounting.
    """
    bits = np.asarray(pattern_bits, dtype=np.int64)
    if bits.ndim != 2:
        raise ValueError(f"pattern_bits must be [C_out, C_in], got {bits.shape}")
    c_out, c_in = bits.shape
    sizes = pattern_sizes(bits)  # [C_out, C_in]

    blocks: list[PatternBlock] = []
    for c in range(c_in):
        blocks.extend(_blocks_for_channel(c, bits[:, c], sizes[:, c]))
    if block_order == "pattern":
        # pattern-major (paper §IV-C index order); width-descending inside a
        # pattern group so strip widths shrink monotonically
        blocks.sort(key=lambda b: (-b.height, b.pattern, -b.n_kernels, b.channel))
    elif block_order == "width":
        blocks.sort(key=lambda b: (-b.n_kernels, -b.height, b.pattern, b.channel))
    elif block_order in ("similarity", "hybrid"):
        rank = _pattern_similarity_rank(b.pattern for b in blocks)
        if block_order == "similarity":
            blocks.sort(
                key=lambda b: (rank[b.pattern], -b.n_kernels, b.channel)
            )
        else:
            blocks.sort(
                key=lambda b: (-b.height, rank[b.pattern], -b.n_kernels,
                               b.channel)
            )
    elif block_order != "channel":
        raise ValueError(f"unknown block_order {block_order!r}")

    packer = _Packer(config)
    cells_used = 0
    stored = 0
    cpw = config.cells_per_weight
    max_w_cells = config.cols

    for block in blocks:
        stored += block.n_kernels
        cells_used += block.height * block.n_kernels * cpw
        # split blocks wider than one crossbar
        max_kernels = max_w_cells // cpw
        ids = block.kernel_ids
        for i in range(0, len(ids), max_kernels):
            part = dataclasses.replace(block, kernel_ids=ids[i : i + max_kernels])
            packer.place(part, part.n_kernels * cpw)

    n_xbar, wasted = packer.finish()
    return LayerMapping(
        config=config,
        placements=packer.placements,
        num_crossbars=n_xbar,
        cells_used=cells_used,
        cells_wasted=wasted,
        stored_kernels=stored,
        total_kernels=c_out * c_in,
        c_out=c_out,
        c_in=c_in,
        kernel_size=kernel_size,
    )


def map_layer_naive(
    c_out: int,
    c_in: int,
    kernel_size: int = 9,
    config: CrossbarConfig = CrossbarConfig(),
) -> NaiveMapping:
    """The Fig-1 baseline: whole filters as columns, zeros included.

    The (C_in*K) x (C_out*cells_per_weight) dense matrix is tiled over
    crossbars; every tile is a full crossbar (the paper's reported baseline
    crossbar counts are ceil-tilings of the dense weight matrix).
    """
    rows = c_in * kernel_size
    cols = c_out * config.cells_per_weight
    n = math.ceil(rows / config.rows) * math.ceil(cols / config.cols)
    return NaiveMapping(
        config=config,
        num_crossbars=n,
        rows_total=rows,
        cols_total=cols,
        c_out=c_out,
        c_in=c_in,
        kernel_size=kernel_size,
    )
