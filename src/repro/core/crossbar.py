"""RRAM crossbar area/energy model (paper Table I, §V-A).

Energy accounting follows the paper: RRAM-related components (crossbar
array, ADCs, DACs) dominate (>80% of chip energy per ISAAC), so only those
are priced.  Per OU activation:

  E_ou = E_array + n_active_bitlines * E_adc + n_active_wordlines * E_dac

with Table I constants: ADC 8b @ 1.67 pJ/op, DAC 4b @ 0.0182 pJ/op, array
4.8 pJ per OU op, OU size 9x8 (9 wordlines x 8 bitlines), 4-bit cells,
512x512 crossbars.  16-bit weights occupy 4 adjacent cells (bit slicing), so
8 bitlines cover 2 weight columns.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mapping import CrossbarConfig

__all__ = ["EnergyModel", "ou_energy", "CrossbarConfig"]


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Per-op energies in pJ (paper Table I)."""

    adc_pj: float = 1.67  # per bitline conversion
    dac_pj: float = 0.0182  # per wordline drive
    array_pj_per_ou: float = 4.8  # per OU activation

    def ou_energy(
        self, wordlines: np.ndarray | int, bitlines: np.ndarray | int
    ) -> np.ndarray:
        """Energy (pJ) of OU activations with the given active line counts."""
        wl = np.asarray(wordlines, dtype=np.float64)
        bl = np.asarray(bitlines, dtype=np.float64)
        return self.array_pj_per_ou + bl * self.adc_pj + wl * self.dac_pj

    def breakdown(
        self,
        wordlines: np.ndarray,
        bitlines: np.ndarray,
        counts: np.ndarray | None = None,
    ) -> dict[str, float]:
        """Component-wise energy (pJ) summed over OU activations.

        ``counts`` weights each entry (e.g. windows per OU position, or the
        expected non-skipped activation count).
        """
        wl = np.asarray(wordlines, dtype=np.float64)
        bl = np.asarray(bitlines, dtype=np.float64)
        n = np.ones_like(wl) if counts is None else np.asarray(counts, np.float64)
        return {
            "array_pj": float((self.array_pj_per_ou * n).sum()),
            "adc_pj": float((bl * self.adc_pj * n).sum()),
            "dac_pj": float((wl * self.dac_pj * n).sum()),
        }


def ou_energy(
    wordlines: np.ndarray | int,
    bitlines: np.ndarray | int,
    model: EnergyModel = EnergyModel(),
) -> np.ndarray:
    return model.ou_energy(wordlines, bitlines)
