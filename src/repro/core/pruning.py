"""ADMM-based pattern pruning (paper §III-A, following ref [11]).

Pipeline (the paper's flowchart, Fig 3):

  1. train a dense network,
  2. irregular (magnitude) pruning to the target sparsity + finetune,
  3. compute the pattern PDF per layer, select top-K candidates,
  4. ADMM phase: minimise  loss(W) + (rho/2)||W - Z + U||^2  with
       Z = project_to_patterns(W + U),  U <- U + W - Z
     re-projecting Z every ``admm_every`` steps,
  5. hard projection onto the dictionary + masked retraining
     (gradients masked so pruned positions stay zero).

Everything is a pure function over parameter pytrees; conv weights use
layout [C_out, C_in, Kh, Kw].  The miniature end-to-end validation (small
CNN, synthetic data) lives in tests/test_pruning.py and
examples/pattern_prune_cnn.py — it reproduces the paper's qualitative
claim: pattern pruning reaches irregular-pruning-level sparsity with little
accuracy loss while using only a handful of patterns per layer.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns as P

__all__ = ["PruneConfig", "PruneResult", "magnitude_prune", "build_dictionaries",
           "admm_pattern_prune", "project_params", "sparsity_of"]


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    target_sparsity: float = 0.75
    num_patterns: int = 6  # nonzero patterns per layer
    rho: float = 1e-2
    admm_steps: int = 300
    admm_every: int = 20
    retrain_steps: int = 300
    metric: str = "magnitude"


@dataclasses.dataclass
class PruneResult:
    params: dict
    dictionaries: dict[str, P.PatternDict]
    pattern_bits: dict[str, np.ndarray]

    def layer_sparsity(self, name: str) -> float:
        w = np.asarray(self.params[name]["w"])
        return 1.0 - float((np.abs(w) > 0).mean())


def sparsity_of(params: dict, conv_names: list[str]) -> float:
    nnz = tot = 0
    for n in conv_names:
        w = np.asarray(params[n]["w"])
        nnz += int((np.abs(w) > 0).sum())
        tot += w.size
    return 1.0 - nnz / tot


def magnitude_prune(params: dict, conv_names: list[str], sparsity: float) -> dict:
    """Irregular magnitude pruning, global threshold across conv layers."""
    mags = np.concatenate(
        [np.abs(np.asarray(params[n]["w"])).ravel() for n in conv_names]
    )
    thresh = np.quantile(mags, sparsity)
    out = dict(params)
    for n in conv_names:
        layer = dict(out[n])
        w = np.asarray(layer["w"])
        layer["w"] = jnp.asarray(np.where(np.abs(w) > thresh, w, 0.0))
        out[n] = layer
    return out


def build_dictionaries(
    params: dict, conv_names: list[str], num_patterns: int
) -> dict[str, P.PatternDict]:
    """Per-layer top-K pattern dictionaries from the PDF of observed masks."""
    out = {}
    for n in conv_names:
        w = np.asarray(params[n]["w"])
        k = w.shape[-1] * w.shape[-2]
        bits = P.masks_to_bits(P.kernel_masks(w))
        pdf = P.pattern_pdf(bits)
        out[n] = P.select_candidates(pdf, num_patterns, k)
    return out


def project_params(
    params: dict,
    dictionaries: dict[str, P.PatternDict],
    metric: str = "magnitude",
) -> tuple[dict, dict[str, np.ndarray]]:
    """Hard-project every conv layer onto its dictionary."""
    out = dict(params)
    bits_out = {}
    for n, pdict in dictionaries.items():
        layer = dict(out[n])
        w = np.asarray(layer["w"])
        proj, bits = P.project_to_patterns(w, pdict, metric=metric)
        layer["w"] = jnp.asarray(proj)
        out[n] = layer
        bits_out[n] = bits
    return out, bits_out


def _masks_from_bits(bits: np.ndarray, k: int, shape) -> jnp.ndarray:
    m = ((bits[..., None] >> np.arange(k)) & 1).astype(np.float32)
    return jnp.asarray(m.reshape(shape))


def admm_pattern_prune(
    params: dict,
    conv_names: list[str],
    loss_fn: Callable[[dict, jax.Array, jax.Array], jax.Array],
    data_iter,
    cfg: PruneConfig,
    opt,
    lr: float = 3e-3,
    seed: int = 0,
) -> PruneResult:
    """Full pattern-pruning pipeline on an already-trained network.

    Args:
      params: trained parameter pytree (``{name: {'w':..., 'b':...}}``).
      conv_names: layers to pattern-prune.
      loss_fn: (params, x, y) -> scalar loss.
      data_iter: iterator of (x, y) batches.
      cfg: pruning configuration.
      opt: ``repro.optim.Optimizer``.
    """
    # 1) irregular pruning
    params = magnitude_prune(params, conv_names, cfg.target_sparsity)
    # 2) candidate dictionaries from the pattern PDF
    dictionaries = build_dictionaries(params, conv_names, cfg.num_patterns)

    # 3) ADMM phase
    Z, _ = project_params(params, dictionaries, cfg.metric)
    U = {n: jnp.zeros_like(params[n]["w"]) for n in conv_names}
    rho = cfg.rho

    def admm_loss(p, x, y, z, u):
        base = loss_fn(p, x, y)
        reg = sum(
            0.5 * rho * jnp.sum((p[n]["w"] - z[n]["w"] + u[n]) ** 2)
            for n in conv_names
        )
        return base + reg

    opt_state = opt.init(params)
    step_fn = jax.jit(
        lambda p, s, x, y, z, u: _admm_step(p, s, x, y, z, u, admm_loss, opt, lr)
    )
    for step in range(cfg.admm_steps):
        x, y = next(data_iter)
        params, opt_state = step_fn(params, opt_state, x, y, Z, U)
        if (step + 1) % cfg.admm_every == 0:
            # Z-update: project W+U ; U-update: dual ascent
            WU = {
                n: {"w": params[n]["w"] + U[n], "b": params[n]["b"]}
                for n in conv_names
            }
            Zn, _ = project_params(WU, dictionaries, cfg.metric)
            Z = Zn
            U = {n: U[n] + params[n]["w"] - Z[n]["w"] for n in conv_names}

    # 4) hard projection + masked retrain
    params, bits = project_params(params, dictionaries, cfg.metric)
    masks = {
        n: _masks_from_bits(
            bits[n], dictionaries[n].k, np.asarray(params[n]["w"]).shape
        )
        for n in conv_names
    }

    def masked_loss(p, x, y):
        return loss_fn(p, x, y)

    grad_fn = jax.value_and_grad(masked_loss)

    @jax.jit
    def retrain_step(p, s, x, y):
        _, g = grad_fn(p, x, y)
        g = dict(g)
        for n in conv_names:
            gl = dict(g[n])
            gl["w"] = gl["w"] * masks[n]
            g[n] = gl
        return opt.update(g, s, p, lr)

    opt_state = opt.init(params)
    for _ in range(cfg.retrain_steps):
        x, y = next(data_iter)
        params, opt_state = retrain_step(params, opt_state, x, y)
    # re-assert exact zeros (optimizer weight decay can perturb)
    for n in conv_names:
        layer = dict(params[n])
        layer["w"] = layer["w"] * masks[n]
        params = {**params, n: layer}

    return PruneResult(params=params, dictionaries=dictionaries, pattern_bits=bits)


def _admm_step(p, s, x, y, z, u, admm_loss, opt, lr):
    _, g = jax.value_and_grad(admm_loss)(p, x, y, z, u)
    return opt.update(g, s, p, lr)
