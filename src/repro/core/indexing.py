"""Weight index buffer encoding + overhead accounting (paper §IV-C, §V-D).

Because kernels are reordered inside every input channel, the architecture
stores, pattern block by pattern block (in placement order):

  - the output-channel index of every stored kernel (<= 9 bits for 512
    output channels),
  - per pattern: the pattern shape bitmask (k bits) and its size.

All-zero-pattern kernels are not stored in the crossbars, so they cost no
index either — the paper's index overhead is dominated by the nonzero-
pattern kernel count.

``decode_placements`` reconstructs every weight's (crossbar, row, col) from
the index stream alone, replaying the greedy placement strategy — the same
procedure §IV-C describes for the Output Indexing Unit.  Tests assert it
round-trips against the mapper's actual placements.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.mapping import (
    CrossbarConfig,
    LayerMapping,
    Placement,
    _Packer,
    PatternBlock,
)

__all__ = ["IndexStream", "build_index_stream", "index_overhead_bits",
           "decode_placements"]


@dataclasses.dataclass
class IndexStream:
    """The serialized index content for one layer."""

    # per stored (split) block, in placement order:
    block_patterns: list[int]  # pattern bitmask
    block_channels: list[int]  # input channel
    block_kernel_ids: list[tuple[int, ...]]  # output-channel index list
    c_out: int
    kernel_size: int

    @property
    def stored_kernels(self) -> int:
        return sum(len(ids) for ids in self.block_kernel_ids)

    @property
    def num_blocks(self) -> int:
        return len(self.block_patterns)


def build_index_stream(mapping: LayerMapping) -> IndexStream:
    return IndexStream(
        block_patterns=[p.block.pattern for p in mapping.placements],
        block_channels=[p.block.channel for p in mapping.placements],
        block_kernel_ids=[p.block.kernel_ids for p in mapping.placements],
        c_out=mapping.c_out,
        kernel_size=mapping.kernel_size,
    )


def index_overhead_bits(stream: IndexStream) -> dict[str, int]:
    """Index buffer size (paper §V-D).

    kernel indexes: ceil(log2(C_out)) bits per stored kernel.
    pattern table:  per block, the pattern shape (k bits) + size
                    (ceil(log2(k+1)) bits) + channel id — the paper calls
                    this part negligible; we count it anyway.
    """
    idx_bits = max(1, math.ceil(math.log2(max(stream.c_out, 2))))
    kernel_bits = stream.stored_kernels * idx_bits
    k = stream.kernel_size
    per_block = k + math.ceil(math.log2(k + 1)) + 16  # shape + size + channel
    table_bits = stream.num_blocks * per_block
    return {
        "kernel_index_bits": kernel_bits,
        "pattern_table_bits": table_bits,
        "total_bits": kernel_bits + table_bits,
        "bits_per_kernel_index": idx_bits,
    }


def decode_placements(
    stream: IndexStream, config: CrossbarConfig = CrossbarConfig()
) -> list[Placement]:
    """Reconstruct weight placement purely from the index stream (§IV-C).

    'First, we get the index of the pattern with the biggest pattern size
    ... if there are enough rows behind the current block for next block,
    then we know it is placed there, otherwise ... in new columns.'

    The decoder replays the exact packer used by the mapper, which is the
    point: placement is a *deterministic function of the index stream*, so
    the hardware never stores coordinates.
    """
    packer = _Packer(config)
    cpw = config.cells_per_weight
    for pat, chan, ids in zip(
        stream.block_patterns, stream.block_channels, stream.block_kernel_ids
    ):
        height = bin(int(pat)).count("1")
        block = PatternBlock(
            channel=chan, pattern=pat, height=height, kernel_ids=tuple(ids)
        )
        packer.place(block, block.n_kernels * cpw)
    packer.finish()
    return packer.placements
