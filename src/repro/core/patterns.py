"""Pattern extraction, selection and projection (paper §III-A).

A *pattern* is the boolean nonzero-mask of a convolution kernel (e.g. a 3x3
kernel has 2**9 = 512 possible patterns, including the all-zero pattern).
Pattern pruning constrains every kernel in a layer to a small per-layer
dictionary of patterns:

  1. start from an irregularly pruned network,
  2. compute the PDF of the observed patterns per layer,
  3. keep the top-K most probable patterns as the candidate dictionary,
  4. project every kernel onto its nearest candidate pattern
     (projection = elementwise multiply with the candidate mask),
  5. retrain, repeat.

Masks are represented as integer bitmasks over the flattened kernel
positions (bit i set <=> position i nonzero), which makes PDF computation,
hamming distance and dictionary handling cheap and hashable.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Sequence

import numpy as np

__all__ = [
    "PatternDict",
    "kernel_masks",
    "masks_to_bits",
    "bits_to_mask",
    "pattern_pdf",
    "select_candidates",
    "project_to_patterns",
    "pattern_sizes",
    "ALL_ZERO",
]

ALL_ZERO = 0  # bitmask of the all-zero pattern


def kernel_masks(weights: np.ndarray, atol: float = 0.0) -> np.ndarray:
    """Boolean nonzero masks for a conv weight tensor.

    Args:
      weights: [C_out, C_in, Kh, Kw] (or already flattened [C_out, C_in, K]).
      atol: magnitude at or below which a weight counts as zero.

    Returns:
      bool array [C_out, C_in, K] with K = Kh*Kw.
    """
    w = np.asarray(weights)
    if w.ndim == 4:
        w = w.reshape(w.shape[0], w.shape[1], -1)
    if w.ndim != 3:
        raise ValueError(f"expected 3D/4D weights, got shape {w.shape}")
    return np.abs(w) > atol


def masks_to_bits(masks: np.ndarray) -> np.ndarray:
    """Pack boolean masks [..., K] into integer bitmasks [...]."""
    masks = np.asarray(masks, dtype=np.int64)
    k = masks.shape[-1]
    if k > 62:
        raise ValueError(f"kernel size {k} too large for bitmask packing")
    weights = (1 << np.arange(k, dtype=np.int64))
    return (masks * weights).sum(axis=-1)


def bits_to_mask(bits: int, k: int) -> np.ndarray:
    """Unpack an integer bitmask into a boolean mask of length k."""
    return ((int(bits) >> np.arange(k)) & 1).astype(bool)


def pattern_pdf(bits: np.ndarray) -> dict[int, float]:
    """Probability density over patterns, from packed kernel bitmasks."""
    bits = np.asarray(bits).reshape(-1)
    counts = Counter(int(b) for b in bits)
    total = float(bits.size)
    return {b: c / total for b, c in counts.items()}


@dataclasses.dataclass(frozen=True)
class PatternDict:
    """A per-layer pattern dictionary.

    Attributes:
      k: flattened kernel size (e.g. 9 for 3x3).
      patterns: sorted tuple of integer bitmasks. Always contains ALL_ZERO —
        the paper never stores all-zero kernels, so projection must be able
        to produce them.
    """

    k: int
    patterns: tuple[int, ...]

    def __post_init__(self):
        pats = tuple(sorted(set(int(p) for p in self.patterns) | {ALL_ZERO}))
        object.__setattr__(self, "patterns", pats)

    @property
    def num_patterns(self) -> int:
        return len(self.patterns)

    @property
    def num_nonzero_patterns(self) -> int:
        return len(self.patterns) - 1

    def masks(self) -> np.ndarray:
        """[P, k] boolean masks."""
        return np.stack([bits_to_mask(p, self.k) for p in self.patterns])

    def sizes(self) -> np.ndarray:
        """[P] nonzero count of each pattern."""
        return self.masks().sum(axis=-1).astype(np.int64)


def pattern_sizes(bits: np.ndarray) -> np.ndarray:
    """Popcount of packed bitmasks (vectorised)."""
    bits = np.asarray(bits, dtype=np.uint64)
    out = np.zeros(bits.shape, dtype=np.int64)
    b = bits.copy()
    while b.any():
        out += (b & np.uint64(1)).astype(np.int64)
        b >>= np.uint64(1)
    return out


def select_candidates(
    pdf: dict[int, float], num_patterns: int, k: int
) -> PatternDict:
    """Top-K most probable patterns (paper: 'largest probability' candidates).

    The all-zero pattern is always included *in addition* (it costs no
    crossbar area and no index storage, and lets the projection drop whole
    kernels — the paper's all-zero-pattern ratio is 27–41%).
    """
    ranked = sorted(pdf.items(), key=lambda kv: (-kv[1], kv[0]))
    chosen = [b for b, _ in ranked if b != ALL_ZERO][:num_patterns]
    return PatternDict(k=k, patterns=tuple(chosen) + (ALL_ZERO,))


def _distance_matrix(
    weights_flat: np.ndarray,
    kbits: np.ndarray,
    pdict: PatternDict,
    metric: str,
) -> np.ndarray:
    """Distance from every kernel to every candidate pattern.

    metrics:
      'hamming'   — bit distance between the kernel's own mask and the pattern
                    (the paper's 'common vector distance' on masks).
      'magnitude' — L2 norm of the weights *discarded* by projecting onto the
                    pattern (energy-preserving; what retraining actually
                    cares about).  Used as the default.
    """
    pmasks = pdict.masks().astype(np.float64)  # [P, k]
    if metric == "hamming":
        kmask = np.stack([bits_to_mask(b, pdict.k) for b in kbits]).astype(
            np.float64
        )  # [n, k]
        # xor distance = |a| + |b| - 2 a.b
        return (
            kmask.sum(-1, keepdims=True)
            + pmasks.sum(-1)[None, :]
            - 2.0 * kmask @ pmasks.T
        )
    if metric == "magnitude":
        w2 = weights_flat.astype(np.float64) ** 2  # [n, k]
        kept = w2 @ pmasks.T  # [n, P] energy kept by each pattern
        total = w2.sum(-1, keepdims=True)
        return total - kept  # energy discarded
    raise ValueError(f"unknown metric {metric!r}")


def project_to_patterns(
    weights: np.ndarray,
    pdict: PatternDict,
    metric: str = "magnitude",
    zero_threshold: float = 0.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Project every kernel onto its nearest dictionary pattern (paper §III-A).

    Projection of a kernel onto a pattern = elementwise multiplication of the
    kernel with the pattern mask.

    Args:
      weights: [C_out, C_in, Kh, Kw] or [C_out, C_in, K].
      pdict: candidate patterns.
      metric: see _distance_matrix.
      zero_threshold: kernels whose total L2 is at or below this are projected
        straight to the all-zero pattern.

    Returns:
      (projected_weights, pattern_bits) where projected_weights has the input
      shape and pattern_bits is [C_out, C_in] packed bitmasks of the chosen
      patterns.
    """
    w = np.asarray(weights, dtype=np.float64)
    orig_shape = w.shape
    if w.ndim == 4:
        w = w.reshape(w.shape[0], w.shape[1], -1)
    co, ci, k = w.shape
    if k != pdict.k:
        raise ValueError(f"kernel size {k} != dictionary size {pdict.k}")

    flat = w.reshape(-1, k)
    kbits = masks_to_bits(np.abs(flat) > 0)
    dist = _distance_matrix(flat, kbits, pdict, metric)

    # Tie-break: prefer the *smaller* pattern on equal distance (less area).
    sizes = pdict.sizes()
    order = np.lexsort((sizes, ))  # stable by size
    dist_ordered = dist[:, order]
    choice_ordered = np.argmin(dist_ordered, axis=1)
    choice = order[choice_ordered]

    # Dead kernels -> all-zero pattern.
    zero_idx = pdict.patterns.index(ALL_ZERO)
    l2 = np.sqrt((flat**2).sum(-1))
    choice = np.where(l2 <= zero_threshold, zero_idx, choice)

    pmasks = pdict.masks()  # [P, k]
    projected = flat * pmasks[choice]
    bits = np.array([pdict.patterns[c] for c in choice], dtype=np.int64)
    return (
        projected.reshape(orig_shape).astype(np.asarray(weights).dtype),
        bits.reshape(co, ci),
    )
