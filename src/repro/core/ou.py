"""Operation-Unit (OU) scheduling (paper §II-A, §IV-C).

Only ``ou_rows x ou_cols`` cells can be activated per cycle (ADC resolution
and cell-deviation limits), and in the pattern-pruned mapping every OU must
lie *inside* one pattern block: rows of different patterns correspond to
different selected inputs and cannot share a wordline activation.

The schedules below are vectorised: one numpy row per OU, not per-object —
VGG-scale layers produce 1e5+ OUs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mapping import CrossbarConfig, LayerMapping, NaiveMapping

__all__ = ["OUSchedule", "pattern_ou_schedule", "naive_ou_schedule"]


@dataclasses.dataclass
class OUSchedule:
    """Per-OU arrays (all the same length).

    crossbar:   crossbar id the OU lives on
    wordlines:  active wordline count (== pattern size for pattern blocks)
    bitlines:   active bitline (cell) count, <= ou_cols
    channel:    input channel whose activations feed the OU (-1 if several)
    pattern:    pattern bitmask selecting the fed input positions
                (for the naive schedule: the full kernel mask)
    """

    crossbar: np.ndarray
    wordlines: np.ndarray
    bitlines: np.ndarray
    channel: np.ndarray
    pattern: np.ndarray

    def __len__(self) -> int:
        return int(self.crossbar.shape[0])

    @property
    def num_crossbars(self) -> int:
        return int(self.crossbar.max()) + 1 if len(self) else 0


def pattern_ou_schedule(mapping: LayerMapping) -> OUSchedule:
    """OUs of a pattern-pruned mapping: each placement tiles its columns
    into ou_cols-wide OUs; every OU stays inside its pattern block."""
    cfg = mapping.config
    xbars, wls, bls, chans, pats = [], [], [], [], []
    for p in mapping.placements:
        if p.height > cfg.ou_rows:
            # patterns are <= 9 nonzeros for 3x3 kernels; guard for generality
            raise ValueError("pattern block taller than an OU is unsupported")
        n_full, rem = divmod(p.width_cells, cfg.ou_cols)
        n = n_full + (1 if rem else 0)
        xbars.append(np.full(n, p.crossbar, dtype=np.int32))
        wls.append(np.full(n, p.height, dtype=np.int32))
        b = np.full(n, cfg.ou_cols, dtype=np.int32)
        if rem:
            b[-1] = rem
        bls.append(b)
        chans.append(np.full(n, p.block.channel, dtype=np.int32))
        pats.append(np.full(n, p.block.pattern, dtype=np.int64))
    if not xbars:
        z = np.zeros(0, dtype=np.int32)
        return OUSchedule(z, z, z, z, z.astype(np.int64))
    return OUSchedule(
        np.concatenate(xbars),
        np.concatenate(wls),
        np.concatenate(bls),
        np.concatenate(chans),
        np.concatenate(pats),
    )


def naive_ou_schedule(naive: NaiveMapping) -> OUSchedule:
    """OUs of the Fig-1 baseline.

    The dense (C_in*K) x (C_out*cells_per_weight) matrix is tiled over
    crossbars; inside each crossbar, OU row-bands are ``ou_rows`` tall.  For
    K == ou_rows (3x3 kernels, OU 9x8) bands align exactly with input
    channels, which is how we attribute the fed channel for the all-zero
    input skip check.  Bands that straddle a channel boundary get
    channel = -1 (never skippable — conservative, and rare).
    """
    cfg = naive.config
    k = naive.kernel_size
    full_mask = (1 << k) - 1

    rows_total, cols_total = naive.rows_total, naive.cols_total
    row_tiles = -(-rows_total // cfg.rows)
    col_tiles = -(-cols_total // cfg.cols)

    xbars, wls, bls, chans, pats = [], [], [], [], []
    xbar_id = 0
    for rt in range(row_tiles):
        r0 = rt * cfg.rows
        tile_rows = min(cfg.rows, rows_total - r0)
        # band boundaries inside this tile
        band_starts = np.arange(0, tile_rows, cfg.ou_rows)
        band_heights = np.minimum(cfg.ou_rows, tile_rows - band_starts)
        abs_starts = band_starts + r0
        # channel attribution: band fully inside channel c iff
        # floor(start/k) == floor((start+h-1)/k)
        c_lo = abs_starts // k
        c_hi = (abs_starts + band_heights - 1) // k
        band_chan = np.where(c_lo == c_hi, c_lo, -1).astype(np.int32)
        for ct in range(col_tiles):
            c0 = ct * cfg.cols
            tile_cols = min(cfg.cols, cols_total - c0)
            n_full, rem = divmod(tile_cols, cfg.ou_cols)
            ngroups = n_full + (1 if rem else 0)
            group_bl = np.full(ngroups, cfg.ou_cols, dtype=np.int32)
            if rem:
                group_bl[-1] = rem
            nb = band_heights.shape[0]
            xbars.append(np.full(nb * ngroups, xbar_id, dtype=np.int32))
            wls.append(np.repeat(band_heights.astype(np.int32), ngroups))
            bls.append(np.tile(group_bl, nb))
            chans.append(np.repeat(band_chan, ngroups))
            pats.append(np.full(nb * ngroups, full_mask, dtype=np.int64))
            xbar_id += 1
    return OUSchedule(
        np.concatenate(xbars),
        np.concatenate(wls),
        np.concatenate(bls),
        np.concatenate(chans),
        np.concatenate(pats),
    )
