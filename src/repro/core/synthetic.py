"""Synthetic pattern-pruned VGG16 networks matching the paper's Table II.

The paper evaluates its *mapping* on pattern-pruned VGG16 checkpoints
(CIFAR-10/100/ImageNet).  Training those checkpoints needs GPU-weeks and the
original datasets; the mapping evaluation, however, only depends on the
pruning *statistics*: per-layer pattern counts, overall sparsity, and the
all-zero-pattern ratio — all of which Table II / §V-D report exactly.  This
module synthesises weight tensors whose statistics match those numbers, so
Figs 7-8 and the speedup/index-overhead analyses can be reproduced at full
scale.  (The pruning *algorithm* itself is validated end-to-end in miniature
by ``repro.core.pruning`` + ``tests/test_pruning.py``.)

Layer geometry is VGG16 config-D: 13 conv layers, 3x3 kernels, maxpool after
layers 2, 4, 7, 10, 13.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.patterns import ALL_ZERO, PatternDict

__all__ = [
    "VGG16_CONV_CHANNELS",
    "TABLE_II",
    "LayerSpec",
    "SyntheticLayer",
    "vgg16_layer_specs",
    "synthesize_network",
]

# (c_in, c_out) per conv layer, VGG16-D
VGG16_CONV_CHANNELS = [
    (3, 64), (64, 64),
    (64, 128), (128, 128),
    (128, 256), (256, 256), (256, 256),
    (256, 512), (512, 512), (512, 512),
    (512, 512), (512, 512), (512, 512),
]

# spatial output size per conv layer (stride-1 'same' convs, pool /2)
_POOL_AFTER = {2, 4, 7, 10, 13}


@dataclasses.dataclass(frozen=True)
class DatasetStats:
    """Paper Table II + §V-D statistics."""

    name: str
    input_hw: int
    sparsity: float  # post-pattern-pruning conv weight sparsity
    zero_pattern_ratio: float  # fraction of kernels with the all-zero pattern
    patterns_per_layer: tuple[int, ...]  # Table II (incl. the all-zero pattern)


TABLE_II: dict[str, DatasetStats] = {
    "cifar10": DatasetStats(
        "cifar10", 32, 0.8603, 0.409,
        (2, 2, 2, 6, 8, 8, 8, 6, 5, 4, 6, 6, 8),
    ),
    "cifar100": DatasetStats(
        "cifar100", 32, 0.8523, 0.274,
        (2, 2, 2, 2, 2, 8, 8, 8, 5, 6, 7, 6, 8),
    ),
    "imagenet": DatasetStats(
        "imagenet", 224, 0.8248, 0.285,
        (2, 2, 2, 2, 2, 9, 12, 12, 9, 10, 6, 4, 4),
    ),
}


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str
    c_in: int
    c_out: int
    out_hw: int  # output feature-map side -> windows = out_hw**2
    kernel_size: int = 9


@dataclasses.dataclass
class SyntheticLayer:
    spec: LayerSpec
    pdict: PatternDict
    pattern_bits: np.ndarray  # [C_out, C_in]
    weights: np.ndarray  # [C_out, C_in, 9]


def vgg16_layer_specs(input_hw: int) -> list[LayerSpec]:
    specs = []
    hw = input_hw
    for i, (ci, co) in enumerate(VGG16_CONV_CHANNELS, start=1):
        specs.append(LayerSpec(f"conv{i}", ci, co, hw))
        if i in _POOL_AFTER:
            hw //= 2
    return specs


def _sample_distinct_patterns(
    rng: np.random.Generator, sizes: list[int], k: int
) -> list[int]:
    """Distinct nonzero bitmasks with the requested popcounts."""
    chosen: set[int] = set()
    out = []
    for s in sizes:
        for _ in range(1000):
            pos = rng.choice(k, size=s, replace=False)
            bits = int(np.sum(1 << pos.astype(np.int64)))
            if bits not in chosen:
                chosen.add(bits)
                out.append(bits)
                break
        else:  # pragma: no cover - 9 choose s always has room
            raise RuntimeError("could not sample distinct pattern")
    return out


def _allocate_fractions(
    sizes: np.ndarray, nonzero_frac: float, target_mean_size: float
) -> np.ndarray:
    """Find f_i >= 0 with sum f = nonzero_frac and sum f_i s_i / nonzero_frac
    = target_mean_size, via exponential tilting f_i ~ exp(-lam * s_i)."""
    sizes = sizes.astype(np.float64)
    lo, hi = -50.0, 50.0
    for _ in range(200):
        lam = 0.5 * (lo + hi)
        w = np.exp(-lam * (sizes - sizes.mean()))
        mean = float((w * sizes).sum() / w.sum())
        if mean > target_mean_size:
            lo = lam
        else:
            hi = lam
    w = np.exp(-lam * (sizes - sizes.mean()))
    return nonzero_frac * w / w.sum()


def synthesize_layer(
    spec: LayerSpec,
    n_patterns: int,
    zero_ratio: float,
    target_sparsity: float,
    rng: np.random.Generator,
    weight_scale: float = 1.0,
) -> SyntheticLayer:
    k = spec.kernel_size
    n_nonzero = max(1, n_patterns - 1)  # Table II counts include the all-zero
    # mean nonzeros per *stored* kernel needed to hit the layer sparsity
    mean_size = k * (1.0 - target_sparsity) / max(1.0 - zero_ratio, 1e-9)
    mean_size = float(np.clip(mean_size, 1.0, k))
    lo = max(1, int(np.floor(mean_size)) - 1)
    hi = min(k, int(np.ceil(mean_size)) + 2)
    size_pool = list(range(lo, hi + 1))
    sizes = [size_pool[i % len(size_pool)] for i in range(n_nonzero)]
    if int(np.floor(mean_size)) not in sizes:
        sizes[0] = int(np.floor(mean_size))
    pats = _sample_distinct_patterns(rng, sizes, k)
    sizes_arr = np.array(sizes, dtype=np.float64)

    fracs = _allocate_fractions(sizes_arr, 1.0 - zero_ratio, mean_size)
    probs = np.concatenate([[zero_ratio], fracs])
    probs = probs / probs.sum()
    all_pats = np.array([ALL_ZERO] + pats, dtype=np.int64)

    n_kernels = spec.c_out * spec.c_in
    choice = rng.choice(len(all_pats), size=n_kernels, p=probs)
    bits = all_pats[choice].reshape(spec.c_out, spec.c_in)

    masks = ((bits[..., None] >> np.arange(k)) & 1).astype(np.float64)
    fan_in = max(spec.c_in * k, 1)
    w = rng.normal(0.0, weight_scale / np.sqrt(fan_in), size=(spec.c_out, spec.c_in, k))
    weights = (w * masks).astype(np.float32)

    pdict = PatternDict(k=k, patterns=tuple(int(p) for p in all_pats))
    return SyntheticLayer(spec=spec, pdict=pdict, pattern_bits=bits, weights=weights)


def synthesize_network(
    dataset: str, seed: int = 0
) -> tuple[DatasetStats, list[SyntheticLayer]]:
    """Synthesize all 13 conv layers matching Table II for ``dataset``."""
    stats = TABLE_II[dataset]
    rng = np.random.default_rng(seed)
    specs = vgg16_layer_specs(stats.input_hw)
    layers = [
        synthesize_layer(
            spec,
            n_patterns=stats.patterns_per_layer[i],
            zero_ratio=stats.zero_pattern_ratio,
            target_sparsity=stats.sparsity,
            rng=rng,
        )
        for i, spec in enumerate(specs)
    ]
    return stats, layers


def network_sparsity(layers: list[SyntheticLayer]) -> float:
    nnz = sum(int((np.abs(l.weights) > 0).sum()) for l in layers)
    tot = sum(l.weights.size for l in layers)
    return 1.0 - nnz / tot


def network_zero_pattern_ratio(layers: list[SyntheticLayer]) -> float:
    zero = sum(int((l.pattern_bits == ALL_ZERO).sum()) for l in layers)
    tot = sum(l.pattern_bits.size for l in layers)
    return zero / tot
