"""TPU-native block-pattern sparse matmul layer (hardware adaptation, DESIGN §3).

The paper's pipeline — pattern dictionary -> kernel reordering -> zero
compression -> OU-granular dense compute -> index-driven select/reorder —
re-expressed at MXU granularity:

  * the contraction dimension K is split into 128-row *blocks*;
  * every output column gets a *block mask* (which blocks are nonzero),
    constrained to a small per-layer dictionary (pattern pruning);
  * output columns are permuted so equal-mask columns are adjacent
    (kernel reordering) and grouped into 128-column *tiles*;
  * weights are stored compressed: only the nonzero blocks of each tile,
    as dense [block, tile] bricks (zero-row compression);
  * compute walks, per output tile, only its nonzero blocks via a
    prefetched ``block_ids`` table — the Input Preprocessing Unit becomes
    an index map, the OU becomes the MXU tile (kernels/pattern_spmm.py);
  * results are un-permuted by the stored inverse permutation
    (Output Indexing Unit).

FLOPs and weight bytes drop by exactly the block density.  This module
holds the layout builder, the XLA reference execution path (used by the
distributed dry-run — Pallas TPU kernels don't lower on the CPU backend),
and the projection ("pattern pruning") of dense weights.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import NULL_TRACER

__all__ = [
    "REORDERS",
    "BlockPatternWeight",
    "build_block_pattern",
    "nonzero_block_masks",
    "reorder_columns",
    "predicted_tile_nnz",
    "pattern_spmm_xla",
    "pattern_spmm_xla_quant",
    "block_density",
]

# column-reorder strategies build_block_pattern understands; the mapping
# optimizer (core/mapsearch.py) searches over them, V205 validates tags
REORDERS = ("pattern", "similarity", "hybrid")


@dataclasses.dataclass
class BlockPatternWeight:
    """Compressed block-pattern weight for y = x @ W, W: [K, N].

    Attributes:
      w_comp:     [n_tiles, k_max, block, tile] — dense bricks, zero padded.
                  fp32, or int8 when quantized (``core/quantize.py``).
      block_ids:  [n_tiles, k_max] int32 — which K-block each brick is;
                  padded entries point at block 0 with zero weights.
      nnz:        [n_tiles] int32 — valid bricks per tile.
      new_order:  [N] int32 — column permutation (new position -> original).
      inv_order:  [N] int32 — inverse permutation (original -> new).
      k_in, n_out, block, tile: geometry.
      dict_masks: [P, n_blocks] bool — the layer's pattern dictionary.
      w_scales:   [n_tiles, k_max] fp32 per-row-group dequant scales, or
                  None for fp32 weights.  ``w ≈ w_scales[t, k] * w_comp``.
    """

    w_comp: jax.Array
    block_ids: jax.Array
    nnz: np.ndarray
    new_order: np.ndarray
    inv_order: np.ndarray
    k_in: int
    n_out: int
    block: int
    tile: int
    dict_masks: np.ndarray
    w_scales: jax.Array | None = None

    @property
    def n_tiles(self) -> int:
        return self.w_comp.shape[0]

    @property
    def k_max(self) -> int:
        return self.w_comp.shape[1]

    @property
    def precision(self) -> str:
        """Stored weight precision: 'fp32', or 'int8' when quantized."""
        return "int8" if self.w_scales is not None else "fp32"

    def dense(self) -> jax.Array:
        """Reconstruct the dense [K, N] weight (testing oracle).

        Quantized weights dequantize through their row-group scales, so
        the result approximates the original to the quantization bound.
        """
        nb = self.k_in // self.block
        w = np.zeros((nb, self.block, self.n_out), np.float64)
        wc = np.asarray(self.w_comp, np.float64)
        if self.w_scales is not None:
            wc = wc * np.asarray(self.w_scales, np.float64)[:, :, None, None]
        ids = np.asarray(self.block_ids)
        for t in range(self.n_tiles):
            for k in range(int(self.nnz[t])):
                cols = slice(t * self.tile, (t + 1) * self.tile)
                w[ids[t, k], :, cols] += wc[t, k]
        w = w.reshape(self.k_in, self.n_out)
        # undo the column permutation
        out = np.zeros_like(w)
        out[:, self.new_order] = w
        return jnp.asarray(out)


def block_density(bp: BlockPatternWeight) -> float:
    """Fraction of K-blocks kept (= FLOP / weight-byte ratio vs dense)."""
    n_blocks = bp.k_in // bp.block
    return float(np.sum(bp.nnz)) / (bp.n_tiles * n_blocks)


def _project_masks_to_dictionary(
    masks: np.ndarray, energies: np.ndarray, num_patterns: int
) -> np.ndarray:
    """Pattern pruning of block masks.

    masks: [N, nB] bool (desired per-column block masks),
    energies: [N, nB] block L2^2 (for energy-weighted projection).

    Returns projected masks [N, nB], each row one of <= num_patterns
    dictionary masks (plus the all-zero mask).
    """
    n, nb = masks.shape
    # PDF over observed masks
    keys = [m.tobytes() for m in masks]
    uniq: dict[bytes, int] = {}
    for k in keys:
        uniq[k] = uniq.get(k, 0) + 1
    ranked = sorted(uniq.items(), key=lambda kv: -kv[1])[:num_patterns]
    cand = np.stack(
        [np.frombuffer(k, dtype=bool).copy() for k, _ in ranked]
    )  # [P, nB]
    # project every column to the candidate keeping the most energy,
    # breaking ties toward the smaller pattern
    kept = energies @ cand.T.astype(np.float64)  # [N, P]
    sizes = cand.sum(-1)  # [P]
    score = kept - 1e-12 * sizes[None, :]
    choice = np.argmax(score, axis=1)
    return cand[choice]


def _mask_similarity_rank(uniq: np.ndarray) -> np.ndarray:
    """Greedy nearest-neighbour chain over unique block masks.

    ``uniq``: [U, nB] bool, lexicographically sorted (``np.unique`` rows).
    Starts from the heaviest mask (ties: first in lexicographic order)
    and repeatedly appends the unvisited mask with the greatest overlap
    with the current one (ties: smaller symmetric difference, then
    lexicographic position).  Adjacent-similar masks shrink each tile's
    block-mask union, i.e. the number of stored bricks.  Returns the
    chain rank per unique mask; deterministic for a given input.
    """
    u = np.asarray(uniq, bool)
    n = u.shape[0]
    rank = np.zeros(n, np.int64)
    if n == 0:
        return rank
    remaining = list(range(n))
    cur = int(np.argmax(u.sum(1)))  # argmax -> first max: deterministic
    for step in range(n):
        rank[cur] = step
        remaining.remove(cur)
        if not remaining:
            break
        inter = (u[remaining] & u[cur]).sum(1)
        xor = (u[remaining] ^ u[cur]).sum(1)
        # lexicographically smallest (-overlap, distance, position)
        best = min(range(len(remaining)),
                   key=lambda j: (-int(inter[j]), int(xor[j]), remaining[j]))
        cur = remaining[best]
    return rank


def reorder_columns(masks: np.ndarray, strategy: str = "pattern") -> np.ndarray:
    """Column permutation grouping equal block masks (kernel reordering).

    Returns ``new_order`` (int32 [N], new position -> original column).
    Every strategy groups equal-mask columns adjacently — only the order
    of the *groups* differs, so the compressed operand stays exact and
    the inverse permutation restores the original semantics:

      'pattern'    — groups in lexicographic mask order (the paper's
                     kernel reordering; the historical default).
      'similarity' — groups along a greedy bit-overlap chain
                     (``_mask_similarity_rank``): neighbouring tiles share
                     blocks, minimizing each tile's mask union.
      'hybrid'     — mask weight (set-bit count) descending first,
                     similarity-chain rank within equal weights.
    """
    masks = np.asarray(masks, bool)
    if masks.ndim != 2:
        raise ValueError(f"masks must be [N, n_blocks], got {masks.shape}")
    if strategy == "pattern":
        mask_keys = np.array([m.tobytes() for m in masks])
        return np.argsort(mask_keys, kind="stable").astype(np.int32)
    if strategy not in REORDERS:
        raise ValueError(f"unknown reorder strategy {strategy!r}")
    if masks.shape[0] == 0:
        return np.zeros(0, np.int32)
    uniq, inverse = np.unique(masks, axis=0, return_inverse=True)
    chain = _mask_similarity_rank(uniq)
    if strategy == "similarity":
        rank = chain
    else:  # hybrid
        order_u = np.lexsort((chain, -uniq.sum(1)))
        rank = np.empty(len(uniq), np.int64)
        rank[order_u] = np.arange(len(uniq))
    return np.argsort(rank[inverse.reshape(-1)], kind="stable").astype(
        np.int32
    )


def predicted_tile_nnz(
    masks: np.ndarray, new_order: np.ndarray, tile: int
) -> np.ndarray:
    """Per-tile stored-brick counts a reorder would realize, without
    building the operand: exactly the ``nnz`` ``build_block_pattern``
    computes for the same ``masks``/``new_order`` (the cost model's
    brick predictor — property-tested to be drift-free)."""
    ms = np.asarray(masks, bool)[np.asarray(new_order)]
    n, nb = ms.shape
    if n % tile:
        raise ValueError(f"N={n} not divisible by tile={tile}")
    return ms.reshape(n // tile, tile, nb).any(axis=1).sum(-1).astype(
        np.int32
    )


def nonzero_block_masks(w: np.ndarray, block: int) -> np.ndarray:
    """Exact per-column block masks from the nonzero structure of ``w``.

    w: [K, N] with K divisible by ``block``.  Returns bool [N, K//block];
    a block is kept iff it holds at least one nonzero weight, so compressing
    with these masks is lossless — the path the inference engine uses on
    already-pruned weights.
    """
    w = np.asarray(w)
    k_in, n_out = w.shape
    if k_in % block:
        raise ValueError(f"K={k_in} not divisible by block={block}")
    return (w.reshape(k_in // block, block, n_out) != 0).any(axis=1).T


def build_block_pattern(
    w: np.ndarray,
    num_patterns: int = 8,
    density: float = 0.25,
    block: int = 128,
    tile: int = 128,
    masks: np.ndarray | None = None,
    tracer=None,
    reorder: str = "pattern",
) -> BlockPatternWeight:
    """Pattern-prune + reorder + compress a dense [K, N] weight.

    Steps mirror the paper's flowchart (Fig 3) at block granularity:
    magnitude-driven block masks -> mask PDF -> top-P dictionary ->
    projection -> column reordering -> zero compression.

    When ``masks`` ([N, K//block] bool) is given, the magnitude/projection
    step is skipped and the supplied per-column block masks are used
    verbatim (``num_patterns`` and ``density`` are ignored).  With
    ``nonzero_block_masks(w, block)`` this makes the build an exact
    re-layout of an already-pruned weight.

    ``tracer`` (``obs/trace.py``) records the build's phases as spans —
    ``prune`` (mask projection), ``reorder`` (column permutation),
    ``pack`` (zero compression into bricks) — under the ``compile``
    category; ``None`` records nothing.

    ``reorder`` selects the column-permutation strategy
    (:func:`reorder_columns`).  All strategies produce the same
    ``BlockPatternWeight`` contract and identical semantics (the stored
    inverse permutation undoes the layout); they differ only in how many
    bricks the tiles need.
    """
    tracer = tracer or NULL_TRACER
    w = np.asarray(w, np.float32)
    k_in, n_out = w.shape
    if k_in % block or n_out % tile:
        raise ValueError(f"weight {w.shape} not divisible by ({block},{tile})")
    nb = k_in // block

    if masks is None:
        with tracer.span("prune", cat="compile", n_out=n_out, n_blocks=nb):
            keep = max(1, int(np.ceil(density * nb)))
            energies = (w.reshape(nb, block, n_out) ** 2).sum(1).T  # [N, nB]
            order = np.argsort(-energies, axis=1)
            masks = np.zeros((n_out, nb), bool)
            np.put_along_axis(masks, order[:, :keep], True, axis=1)
            masks = _project_masks_to_dictionary(masks, energies, num_patterns)
    else:
        masks = np.asarray(masks, bool)
        if masks.shape != (n_out, nb):
            raise ValueError(
                f"masks shape {masks.shape} != (N={n_out}, K/block={nb})"
            )

    # kernel reordering: group equal masks; the strategy orders the groups
    with tracer.span("reorder", cat="compile", n_out=n_out,
                     strategy=reorder):
        new_order = reorder_columns(masks, reorder)
        inv_order = np.argsort(new_order).astype(np.int32)
        masks_sorted = masks[new_order]
        w_sorted = w[:, new_order]

    with tracer.span("pack", cat="compile", n_out=n_out) as pack_span:
        n_tiles = n_out // tile
        tile_masks = masks_sorted.reshape(n_tiles, tile, nb).any(axis=1)
        nnz = tile_masks.sum(-1).astype(np.int32)
        k_max = max(int(nnz.max()), 1)
        pack_span.args.update(n_tiles=n_tiles, k_max=k_max)

        w_blocks = w_sorted.reshape(nb, block, n_tiles, tile)
        w_comp = np.zeros((n_tiles, k_max, block, tile), np.float32)
        block_ids = np.zeros((n_tiles, k_max), np.int32)
        for t in range(n_tiles):
            ids = np.nonzero(tile_masks[t])[0]
            for j, bid in enumerate(ids):
                # zero out the entries this tile's columns masked off
                colmask = masks_sorted[t * tile : (t + 1) * tile, bid]
                w_comp[t, j] = w_blocks[bid, :, t, :] * colmask[None, :]
                block_ids[t, j] = bid

    dict_masks = np.unique(masks, axis=0)
    return BlockPatternWeight(
        w_comp=jnp.asarray(w_comp),
        block_ids=jnp.asarray(block_ids),
        nnz=nnz,
        new_order=new_order,
        inv_order=inv_order,
        k_in=k_in,
        n_out=n_out,
        block=block,
        tile=tile,
        dict_masks=dict_masks,
    )


def pattern_spmm_xla(
    x: jax.Array,
    w_comp: jax.Array,
    block_ids: jax.Array,
    block: int,
    unpermute: jax.Array | None = None,
    out_dtype=None,
) -> jax.Array:
    """XLA execution of the compressed matmul: y = x @ W_compressed.

    x: [..., K]; w_comp: [T, k_max, block, tile]; block_ids: [T, k_max].
    Walks the k_max brick slots with a scan; each step gathers the needed
    x-block per tile (the 'input preprocessing unit') and does a dense
    [M, block] @ [block, tile] per tile.  Padded slots have zero weights.
    """
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    k_in = x.shape[-1]
    m = int(np.prod(lead)) if lead else 1
    xb = x.reshape(m, k_in // block, block)
    t, k_max, _, tile = w_comp.shape

    def step(acc, slot):
        ids, w_slot = slot  # ids: [T], w_slot: [T, block, tile]
        xg = jnp.take(xb, ids, axis=1)  # [M, T, block]
        acc = acc + jnp.einsum(
            "mtb,tbn->mtn", xg, w_slot, preferred_element_type=jnp.float32
        )
        return acc, None

    acc0 = jnp.zeros((m, t, tile), jnp.float32)
    acc, _ = jax.lax.scan(
        step, acc0, (block_ids.T, jnp.swapaxes(w_comp, 0, 1))
    )
    y = acc.reshape(m, t * tile)
    if unpermute is not None:
        y = jnp.take(y, unpermute, axis=1)
    return y.reshape(*lead, t * tile).astype(out_dtype)


def pattern_spmm_xla_quant(
    xq: jax.Array,
    x_scale: jax.Array,
    w_comp: jax.Array,
    block_ids: jax.Array,
    w_scales: jax.Array,
    block: int,
    out_dtype=jnp.float32,
) -> jax.Array:
    """XLA execution of the *int-quantized* compressed matmul.

    xq: int8 [M, K] (per-row quantized activations, scales ``x_scale``
    [M]); w_comp: int8 [T, k_max, block, tile] with per-brick row-group
    scales ``w_scales`` [T, k_max].  Each scan step is an int8 x int8 ->
    int32 contraction (the MXU-native path on TPU); the brick's row-group
    scale folds into the fp32 accumulator, and the activation row scale
    multiplies once in the output epilogue:

        y = x_scale[:, None] * sum_k w_scales[t, k] * (xq_k @ wq_{t,k})
    """
    m, k_in = xq.shape
    xb = xq.reshape(m, k_in // block, block)
    t, k_max, _, tile = w_comp.shape

    def step(acc, slot):
        ids, w_slot, s_slot = slot  # [T], [T, block, tile], [T]
        xg = jnp.take(xb, ids, axis=1)  # [M, T, block] int8
        part = jnp.einsum(
            "mtb,tbn->mtn", xg, w_slot, preferred_element_type=jnp.int32
        )
        acc = acc + s_slot[None, :, None] * part.astype(jnp.float32)
        return acc, None

    acc0 = jnp.zeros((m, t, tile), jnp.float32)
    acc, _ = jax.lax.scan(
        step,
        acc0,
        (block_ids.T, jnp.swapaxes(w_comp, 0, 1), w_scales.T),
    )
    y = acc * x_scale[:, None, None]
    return y.reshape(m, t * tile).astype(out_dtype)
