"""Per-layer mapping design-space search (beyond-paper).

The paper fixes one geometry (512x512 crossbars, 9x8 OUs, 4 cells/weight)
and one packing order for every layer.  The RRAM mapping DSE literature
(arXiv 2201.06703) shows the right geometry is *per layer*, and bit-level
column-similarity ordering (arXiv 2511.14202) can beat pattern-order
packing.  This module searches that space:

  * the candidate space is :class:`repro.core.mapping.MappingCandidate`
    — crossbar dims x OU shape x cells/weight x ``block_order`` (crossbar
    packing) x ``reorder`` (engine column permutation);
  * the cost model is :func:`repro.core.simulator.mapping_cost`, i.e.
    the *simulator's own pricing chain*, so predicted area/energy/cycles
    equal ``hardware_report`` numbers bit-for-bit (property-tested with
    zero tolerance), plus the engine-side stored-brick count predicted
    by :func:`repro.core.sparse.predicted_tile_nnz`;
  * the loop is greedy coordinate descent from the fixed scheme plus
    seeded random restarts — deterministic for a given seed, pure host
    code (this module never imports jax, so the L001/L004 lint's
    jit-reachability can never flag its ``np.random`` use);
  * selection is **Pareto-guarded**: the chosen candidate must be <= the
    fixed scheme on *both* crossbar area-cells and energy, with the
    fixed scheme itself the fallback — searched mappings are never worse
    than fixed by construction, which ``check_baseline.py`` gates.

``engine/lowering.py`` drives this per layer under
``compile_network(optimize='auto')``; the chosen candidate rides on
``CompiledConv.mapping`` into ``hardware_report`` pricing and the saved
manifest (format v3).
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.crossbar import EnergyModel
from repro.core.mapping import BLOCK_ORDERS, MappingCandidate
from repro.core.patterns import ALL_ZERO, pattern_sizes
from repro.core.simulator import MappingCost, mapping_cost
from repro.core.sparse import REORDERS, predicted_tile_nnz, reorder_columns

__all__ = [
    "DEFAULT_CROSSBAR_DIMS",
    "DEFAULT_BLOCK_ORDERS",
    "MappingSearchConfig",
    "MappingSearchResult",
    "search_layer_mapping",
    "choose_fc_reorder",
]

# (rows, cols-in-cells) geometries the default search considers: the
# paper's 512x512 plus the standard smaller RRAM macro sizes.  Smaller
# crossbars waste fewer cells on layers whose packed strips end early,
# at the price of more crossbars for big layers — exactly the per-layer
# trade the search resolves.
DEFAULT_CROSSBAR_DIMS = (
    (512, 512),
    (512, 256),
    (256, 512),
    (256, 256),
    (256, 128),
    (128, 256),
    (128, 128),
)

# 'channel' (the paper's narration read literally) is strictly dominated
# by 'pattern' on every workload we price, so the default search skips it.
DEFAULT_BLOCK_ORDERS = ("pattern", "width", "similarity", "hybrid")


@dataclasses.dataclass(frozen=True)
class MappingSearchConfig:
    """Axes and budget of the per-layer mapping search.

    The default axes keep the paper's 9x8 OU fixed: the Table-I energy
    model prices an OU activation as one array pulse + per-line ADC/DAC
    costs, which would trivially reward ever-wider OUs — searching OU
    shape is only honest with a pricing model that penalizes larger
    ADCs, so by default only crossbar dims and orderings are searched.
    ``cells_per_weight = None`` inherits the fixed scheme's value (which
    ``compile_network`` derives from the program's precision).

    ``exhaustive=True`` sweeps the full cross product instead of greedy
    descent (slow-marked tests use it as the oracle the greedy must tie
    on the smoke models).
    """

    crossbar_dims: tuple = DEFAULT_CROSSBAR_DIMS
    ou_rows: tuple = (9,)
    ou_cols: tuple = (8,)
    cells_per_weight: tuple | None = None
    block_orders: tuple = DEFAULT_BLOCK_ORDERS
    reorders: tuple = REORDERS
    seed: int = 0
    restarts: int = 2
    max_passes: int = 4
    exhaustive: bool = False

    def __post_init__(self):
        for rows, cols in self.crossbar_dims:
            if rows <= 0 or cols <= 0:
                raise ValueError(
                    f"non-positive crossbar dims ({rows}, {cols})"
                )
        for name, vals in (("ou_rows", self.ou_rows),
                           ("ou_cols", self.ou_cols),
                           ("cells_per_weight", self.cells_per_weight or ())):
            if any(v <= 0 for v in vals):
                raise ValueError(f"non-positive {name} in {vals}")
        bad = set(self.block_orders) - set(BLOCK_ORDERS)
        if bad or not self.block_orders:
            raise ValueError(f"unknown block orders {sorted(bad)}")
        bad = set(self.reorders) - set(REORDERS)
        if bad or not self.reorders:
            raise ValueError(f"unknown reorder strategies {sorted(bad)}")
        if self.restarts < 0 or self.max_passes < 1:
            raise ValueError("restarts must be >= 0, max_passes >= 1")


@dataclasses.dataclass(frozen=True)
class MappingSearchResult:
    """Outcome of one layer's search.

    ``visited`` lists every *unique* candidate the search priced (the
    property suite checks each one yields a bijective column
    permutation); ``improved`` is True iff the chosen candidate strictly
    beats the fixed scheme on the (area, energy, cycles, bricks)
    objective — ties keep the fixed scheme, so compiled layouts never
    churn without a measurable win.
    """

    chosen: MappingCandidate
    cost: MappingCost
    bricks: int
    fixed: MappingCandidate
    fixed_cost: MappingCost
    fixed_bricks: int
    improved: bool
    evaluations: int
    visited: tuple[MappingCandidate, ...]


def _axis_values(search: MappingSearchConfig, fixed: MappingCandidate) -> dict:
    cells = (
        (fixed.cells_per_weight,)
        if search.cells_per_weight is None
        else tuple(search.cells_per_weight)
    )
    return {
        "dims": tuple(search.crossbar_dims),
        "cells_per_weight": cells,
        "ou_rows": tuple(search.ou_rows),
        "ou_cols": tuple(search.ou_cols),
        "block_order": tuple(search.block_orders),
        "reorder": tuple(search.reorders),
    }


def _with_axis(c: MappingCandidate, axis: str, value) -> MappingCandidate:
    if axis == "dims":
        return dataclasses.replace(c, rows=value[0], cols=value[1])
    return dataclasses.replace(c, **{axis: value})


def search_layer_mapping(
    pattern_bits: np.ndarray,
    kernel_size: int = 9,
    windows: int = 1,
    fixed: MappingCandidate = MappingCandidate(),
    search: MappingSearchConfig | None = None,
    masks: np.ndarray | None = None,
    tile: int = 128,
    energy: EnergyModel = EnergyModel(),
) -> MappingSearchResult:
    """Search the mapping design space for one layer.

    Args:
      pattern_bits: [C_out, C_in] packed pattern bitmasks (the layer's
        pruning outcome — the search never changes *what* is pruned,
        only how it is laid out).
      kernel_size / windows: pricing context (``windows`` scales energy
        and cycles uniformly, so it cannot change the argmin; it is
        threaded through so predicted numbers match report pricing).
      fixed: the baseline scheme the result must match-or-beat.
      masks: optional [N, n_blocks] engine block masks; when given, the
        objective's last component is the stored-brick count realized by
        each ``reorder`` strategy (``predicted_tile_nnz``), letting the
        search trade equal-hardware candidates on engine memory.
      tile: engine tile width for the brick predictor.

    Deterministic: same inputs + same ``search.seed`` produce the same
    result, byte for byte (no wall clock, ``np.random`` only through a
    seeded Generator on the host).
    """
    search = search or MappingSearchConfig()
    bits = np.asarray(pattern_bits, dtype=np.int64)
    sizes = pattern_sizes(bits)
    nz = bits != ALL_ZERO
    max_height = int(sizes[nz].max()) if bool(nz.any()) else 0
    axes = _axis_values(search, fixed)

    def valid(c: MappingCandidate) -> bool:
        # pattern_ou_schedule cannot split a block across OU row groups,
        # and a weight's cell slices must fit one crossbar row
        return (
            c.ou_rows >= max_height
            and c.ou_rows <= c.rows
            and c.ou_cols <= c.cols
            and c.cells_per_weight <= c.cols
        )

    hw_cache: dict[tuple, MappingCost] = {}
    brick_cache: dict[str, int] = {}
    visited: list[MappingCandidate] = []
    seen: set[MappingCandidate] = set()

    def bricks_for(strategy: str) -> int:
        if masks is None:
            return 0
        if strategy not in brick_cache:
            order = reorder_columns(masks, strategy)
            brick_cache[strategy] = int(
                predicted_tile_nnz(masks, order, tile).sum()
            )
        return brick_cache[strategy]

    def hw_cost(c: MappingCandidate) -> MappingCost:
        # the column reorder never touches crossbar pricing: cache on the
        # hardware sub-key so reorder moves are free
        key = (c.rows, c.cols, c.cells_per_weight, c.ou_rows, c.ou_cols,
               c.block_order)
        if key not in hw_cache:
            hw_cache[key] = mapping_cost(
                bits, c, windows, kernel_size, energy
            )
        return hw_cache[key]

    def objective(c: MappingCandidate) -> tuple:
        if c not in seen:
            seen.add(c)
            visited.append(c)
        cost = hw_cost(c)
        return (cost.area_cells, cost.energy_pj, cost.cycles,
                bricks_for(c.reorder))

    if not valid(fixed):
        raise ValueError(
            f"fixed scheme {fixed} cannot realize this layer "
            f"(max pattern height {max_height})"
        )
    fixed_obj = objective(fixed)

    def descend(start: MappingCandidate) -> None:
        cur = start
        cur_key = objective(cur) + cur.sort_key()
        for _ in range(search.max_passes):
            moved = False
            for axis, values in axes.items():
                for v in values:
                    cand = _with_axis(cur, axis, v)
                    if cand == cur or not valid(cand):
                        continue
                    key = objective(cand) + cand.sort_key()
                    if key < cur_key:
                        cur, cur_key = cand, key
                        moved = True
            if not moved:
                return

    if search.exhaustive:
        for combo in itertools.product(*axes.values()):
            cand = MappingCandidate(
                rows=combo[0][0],
                cols=combo[0][1],
                cells_per_weight=combo[1],
                ou_rows=combo[2],
                ou_cols=combo[3],
                block_order=combo[4],
                reorder=combo[5],
            )
            if valid(cand):
                objective(cand)
    else:
        descend(fixed)
        rng = np.random.default_rng(search.seed)
        for _ in range(search.restarts):
            combo = {
                axis: values[int(rng.integers(len(values)))]
                for axis, values in axes.items()
            }
            start = MappingCandidate(
                rows=combo["dims"][0],
                cols=combo["dims"][1],
                cells_per_weight=combo["cells_per_weight"],
                ou_rows=combo["ou_rows"],
                ou_cols=combo["ou_cols"],
                block_order=combo["block_order"],
                reorder=combo["reorder"],
            )
            if valid(start):
                descend(start)

    # Pareto guard: never trade area against energy — the winner must be
    # <= fixed on both, so 'searched never worse than fixed' holds by
    # construction.  Ties prefer the fixed scheme (no layout churn).
    fixed_cost = hw_cost(fixed)
    qualifying = [
        c
        for c in visited
        if hw_cost(c).area_cells <= fixed_cost.area_cells
        and hw_cost(c).energy_pj <= fixed_cost.energy_pj
    ]
    chosen = min(
        qualifying,
        key=lambda c: (objective(c), c != fixed, c.sort_key()),
    )
    chosen_obj = objective(chosen)
    return MappingSearchResult(
        chosen=chosen,
        cost=hw_cost(chosen),
        bricks=bricks_for(chosen.reorder),
        fixed=fixed,
        fixed_cost=fixed_cost,
        fixed_bricks=bricks_for(fixed.reorder),
        improved=chosen_obj < fixed_obj,
        evaluations=len(visited),
        visited=tuple(visited),
    )


def choose_fc_reorder(
    masks: np.ndarray,
    tile: int = 128,
    reorders: tuple = REORDERS,
) -> tuple[str, dict[str, int]]:
    """Pick the column-reorder strategy minimizing an FC layer's bricks.

    The classifier head has no pattern-block crossbar mapping, so its
    search space is the reorder strategy alone.  Returns ``(strategy,
    bricks_by_strategy)``; ties keep the earliest strategy in
    ``reorders`` ('pattern' first by default — no churn without a win).
    """
    counts: dict[str, int] = {}
    for s in reorders:
        order = reorder_columns(masks, s)
        counts[s] = int(predicted_tile_nnz(masks, order, tile).sum())
    best = min(reorders, key=lambda s: (counts[s], reorders.index(s)))
    return best, counts
