"""Int quantization of compressed weights onto the paper's 4-bit RRAM cells.

The crossbar model (``core/mapping.CrossbarConfig``) always priced weights
as bit-sliced low-precision cells — 16-bit weights over four 4-bit cells —
while the engine executed fp32 ``w_comp``.  This module closes that gap:
weights are stored as **per-OU-row-group symmetric int8** and the
executor really runs them (``kernels/ops.pattern_spmm`` int8-input /
int32-accumulate variant), so ``hardware_report`` prices the cell model
the hardware would actually hold.

Granularity: in the compressed spmm layout a *row-group* is one stored
``[block, tile]`` brick — the rows of one K-block feeding one output tile,
exactly the row span the OU walks.  Each brick gets one fp32 scale
(``w_scales[t, k] = max|brick| / 127``), so

    w  ≈  w_scales[t, k] * q[t, k]      with  |w - s*q| <= s/2

elementwise (round-to-nearest), the bound the hypothesis property in
``tests/test_quantize.py`` checks.

Cell decomposition: an int8 weight is sign + 7 magnitude bits, stored
sign-magnitude across ``ceil(weight_bits / cell_bits)`` adjacent cells
(2 slices for 8-bit weights on 4-bit cells; the sign rides in the top
slice's spare bit, same as the paper's 16-bit / four-cell slicing).
``cell_slices`` / ``compose_cell_slices`` are the lossless round trip;
``n_cell_slices`` is what ``CompiledNetwork.hardware_report`` substitutes
for the assumed ``cells_per_weight``.

Activations are quantized dynamically per row (one scale per im2col
window) right before the spmm; the dequant ``y = x_scale * sum_k
w_scale_k * (qx_k @ qw_k)`` folds the row scale into the output epilogue.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.sparse import BlockPatternWeight

__all__ = [
    "WEIGHT_BITS",
    "QMAX",
    "n_cell_slices",
    "cells_for_magnitude",
    "group_scales",
    "quantize_groups",
    "dequantize_groups",
    "quantize_bp",
    "dequantize_bp",
    "quantize_rows",
    "cell_slices",
    "compose_cell_slices",
]

WEIGHT_BITS = 8  # stored weight precision (symmetric int8)
QMAX = 2 ** (WEIGHT_BITS - 1) - 1  # 127


def n_cell_slices(cell_bits: int = 4, weight_bits: int = WEIGHT_BITS) -> int:
    """Cells per stored weight: ``ceil(weight_bits / cell_bits)``.

    Mirrors the paper's accounting (16-bit weights / 4-bit cells = 4
    adjacent cells); int8 weights on 4-bit cells take 2.
    """
    if cell_bits < 1:
        raise ValueError(f"cell_bits must be >= 1, got {cell_bits}")
    return -(-weight_bits // cell_bits)


def cells_for_magnitude(
    mag, cell_bits: int = 4, weight_bits: int = WEIGHT_BITS
) -> np.ndarray:
    """Minimum cell slices needed to store magnitudes exactly.

    ``mag``: non-negative integer magnitudes (scalar or array), the
    largest |q| a row-group holds in some integer grid.  A magnitude of
    ``m`` needs ``bit_length(m)`` magnitude bits plus the sign bit of
    the sign-magnitude cell layout (:func:`cell_slices`), so
    ``ceil((bit_length(m) + 1) / cell_bits)`` cells; all-zero groups
    need none.  The result never exceeds :func:`n_cell_slices` for
    magnitudes within the ``weight_bits`` budget — this is the
    range→cell-count map the certification pass
    (``repro.analysis.ranges``) tabulates per OU row-group.
    """
    if cell_bits < 1:
        raise ValueError(f"cell_bits must be >= 1, got {cell_bits}")
    m = np.asarray(mag, np.int64)
    if m.size and m.min() < 0:
        raise ValueError("magnitudes must be non-negative")
    if m.size and m.max() >= (1 << (weight_bits - 1)):
        raise ValueError(
            f"magnitude {int(m.max())} exceeds the {weight_bits}-bit "
            "signed weight budget"
        )
    # bit_length(m) for integer m > 0 is exactly frexp's binary exponent
    bits = np.frexp(m.astype(np.float64))[1].astype(np.int64)
    cells = -(-(bits + 1) // cell_bits)
    return np.where(m > 0, cells, 0)


def group_scales(w: np.ndarray, group_ndim: int = 2) -> np.ndarray:
    """Symmetric scale per group: ``max|group| / QMAX``.

    The trailing ``group_ndim`` axes form one group; the returned array
    has those axes reduced away.  All-zero groups get scale 0.0 (their
    quantized weights are 0 and dequantize exactly).
    """
    w = np.asarray(w, np.float32)
    axes = tuple(range(w.ndim - group_ndim, w.ndim))
    return (np.abs(w).max(axis=axes) / QMAX).astype(np.float32)


def quantize_groups(
    w: np.ndarray, scales: np.ndarray, group_ndim: int = 2
) -> np.ndarray:
    """Round-to-nearest symmetric int8 of ``w`` under per-group ``scales``."""
    w = np.asarray(w, np.float32)
    s = np.asarray(scales, np.float32).reshape(scales.shape + (1,) * group_ndim)
    inv = np.where(s > 0, 1.0 / np.where(s > 0, s, 1.0), 0.0)
    q = np.rint(w * inv)
    return np.clip(q, -QMAX, QMAX).astype(np.int8)


def dequantize_groups(
    q: np.ndarray, scales: np.ndarray, group_ndim: int = 2
) -> np.ndarray:
    s = np.asarray(scales, np.float32).reshape(scales.shape + (1,) * group_ndim)
    return (np.asarray(q, np.float32) * s).astype(np.float32)


def quantize_bp(bp: BlockPatternWeight) -> BlockPatternWeight:
    """Quantize a compressed weight to int8 bricks + per-brick scales.

    Returns a new :class:`BlockPatternWeight` whose ``w_comp`` is int8
    ``[T, k_max, block, tile]`` and whose ``w_scales`` is fp32
    ``[T, k_max]`` — one scale per stored row-group brick.  Padded brick
    slots are all-zero, so their scale is 0 and they stay numerically
    inert under every execution path (XLA scan, Pallas, sharded).
    """
    if bp.w_scales is not None:
        return bp
    wc = np.asarray(bp.w_comp, np.float32)
    scales = group_scales(wc, group_ndim=2)  # [T, k_max]
    q = quantize_groups(wc, scales, group_ndim=2)
    return dataclasses.replace(bp, w_comp=jnp.asarray(q), w_scales=jnp.asarray(scales))


def dequantize_bp(bp: BlockPatternWeight) -> BlockPatternWeight:
    """Inverse of :func:`quantize_bp` (up to the quantization error)."""
    if bp.w_scales is None:
        return bp
    wc = dequantize_groups(
        np.asarray(bp.w_comp), np.asarray(bp.w_scales), group_ndim=2
    )
    return dataclasses.replace(bp, w_comp=jnp.asarray(wc), w_scales=None)


def quantize_rows(x):
    """Dynamic per-row symmetric int8 of activations (jit-compatible).

    x: [M, K] fp; returns (q int8 [M, K], scales fp32 [M]).  One scale
    per row — per im2col window — so the dequant is a single per-row
    multiply in the spmm output epilogue.  All-zero rows get scale 0 and
    quantize to exact zeros.
    """
    amax = jnp.abs(x).max(axis=-1)
    scale = (amax / QMAX).astype(jnp.float32)
    inv = jnp.where(amax > 0, QMAX / jnp.where(amax > 0, amax, 1.0), 0.0)
    q = jnp.clip(jnp.round(x * inv[:, None]), -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def cell_slices(q: np.ndarray, cell_bits: int = 4) -> np.ndarray:
    """Decompose int8 weights into unsigned cell slices, sign-magnitude.

    q: int8 array; returns uint8 ``[..., n_cell_slices]``: little-endian
    ``cell_bits``-bit magnitude digits, with the sign bit stored in the
    top slice's most significant spare bit.  Lossless for |q| <= QMAX
    (which :func:`quantize_groups` guarantees).
    """
    q = np.asarray(q)
    if q.dtype != np.int8:
        raise ValueError(f"expected int8 weights, got {q.dtype}")
    n = n_cell_slices(cell_bits)
    mag = np.abs(q.astype(np.int16)).astype(np.uint16)
    out = np.empty(q.shape + (n,), np.uint8)
    for i in range(n):
        out[..., i] = (mag >> (i * cell_bits)) & ((1 << cell_bits) - 1)
    # sign in the top slice's spare bit (magnitude uses weight_bits-1 bits)
    sign_bit = (WEIGHT_BITS - 1) - (n - 1) * cell_bits
    out[..., n - 1] |= ((q < 0).astype(np.uint8)) << sign_bit
    return out


def compose_cell_slices(slices: np.ndarray, cell_bits: int = 4) -> np.ndarray:
    """Inverse of :func:`cell_slices`: slices -> int8 weights."""
    slices = np.asarray(slices, np.uint16)
    n = n_cell_slices(cell_bits)
    if slices.shape[-1] != n:
        raise ValueError(
            f"expected {n} slices of {cell_bits} bits, got {slices.shape[-1]}"
        )
    sign_bit = (WEIGHT_BITS - 1) - (n - 1) * cell_bits
    top = slices[..., n - 1]
    neg = (top >> sign_bit) & 1
    top = top & ((1 << sign_bit) - 1)
    mag = np.zeros(slices.shape[:-1], np.int16)
    for i in range(n - 1):
        mag |= slices[..., i].astype(np.int16) << (i * cell_bits)
    mag |= top.astype(np.int16) << ((n - 1) * cell_bits)
    return np.where(neg == 1, -mag, mag).astype(np.int8)
