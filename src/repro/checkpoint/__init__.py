from repro.checkpoint.checkpointer import (  # noqa: F401
    Checkpointer,
    save_checkpoint,
    restore_checkpoint,
    latest_step,
)
