"""Fault-tolerant sharded checkpointing.

Design points for 1000+-node runs:

  * **Atomicity** — a checkpoint is written into ``step_<n>.tmp`` and
    ``os.replace``d to ``step_<n>`` only after every leaf and the manifest
    are fsynced; a crashed writer can never leave a half checkpoint that
    restore would pick up.
  * **Elastic restore** — leaves are stored as full (unsharded) arrays per
    leaf-path; restore device_puts them under *any* target sharding, so a
    job can come back on a different device count after failures (tests
    re-mesh 8 -> 4 devices).  For multi-TB models a per-shard layout with
    the same manifest is the drop-in extension (each process writes its
    addressable shards; manifest keys gain a shard index).
  * **Async** — ``Checkpointer(async_save=True)`` snapshots to host memory
    synchronously (device_get) and writes on a worker thread, so the train
    loop blocks only for the device->host copy.
  * **Retention** — keep the last ``keep`` checkpoints, never deleting the
    newest complete one.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "Checkpointer"]

_MANIFEST = "manifest.json"


def _leaf_paths(tree) -> list[tuple[str, object]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree) -> str:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target, shardings=None):
    """Restore into the structure of ``target``.

    ``shardings``: optional pytree of jax.sharding.Sharding matching
    ``target`` — enables elastic restore onto a different mesh.
    """
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    keys_and_leaves = _leaf_paths(target)
    shard_leaves = (
        [s for _, s in _leaf_paths(shardings)] if shardings is not None
        else [None] * len(keys_and_leaves)
    )
    restored = []
    for (key, leaf), shd in zip(keys_and_leaves, shard_leaves):
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != target "
                f"{np.shape(leaf)}"
            )
        if shd is not None:
            restored.append(jax.device_put(arr, shd))
        else:
            restored.append(jax.device_put(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, restored)


class Checkpointer:
    """Retention + optional async writes over save/restore."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._queue: queue.Queue | None = None
        self._worker: threading.Thread | None = None
        self._errors: list[BaseException] = []
        if async_save:
            self._queue = queue.Queue()
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _run(self):
        while True:
            item = self._queue.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced by wait()
                self._errors.append(e)
            finally:
                self._queue.task_done()

    def save(self, step: int, tree):
        if self.async_save:
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            self._queue.put((step, host))
        else:
            save_checkpoint(self.directory, step, tree)
            self._gc()

    def wait(self):
        if self._queue is not None:
            self._queue.join()
        if self._errors:
            raise self._errors[0]

    def close(self):
        if self._queue is not None:
            self._queue.join()
            self._queue.put(None)
            self._worker.join()

    def latest_step(self):
        return latest_step(self.directory)

    def restore(self, step: int, target, shardings=None):
        return restore_checkpoint(self.directory, step, target, shardings)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"),
                ignore_errors=True,
            )
