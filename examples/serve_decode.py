"""Batched serving driver (the paper-kind end-to-end example: the paper is
an inference accelerator, so the e2e driver serves a model with batched
requests through the slot-based continuous-batching loop).

  PYTHONPATH=src python examples/serve_decode.py [--arch granite_3_2b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.transformer import count_params, init_params
from repro.runtime.serve import ServeConfig, ServeLoop
from repro.serve import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    print(f"{cfg.name}: {count_params(params):,} params")

    scfg = ServeConfig(
        batch_slots=args.slots,
        max_seq=args.prompt_len + args.new_tokens + 8,
        eos_id=-1,
    )
    loop = ServeLoop(cfg, statics, params, scfg)

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for _ in range(args.requests)
    ]
    t0 = time.time()
    loop.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.output) for r in reqs)
    print(f"{len(reqs)} requests x {args.new_tokens} tokens "
          f"({args.slots} slots): {total} tokens in {dt:.2f}s "
          f"= {total/dt:.1f} tok/s")
    for i, r in enumerate(reqs[:3]):
        print(f"request {i}: {r.output[:10]}...")


if __name__ == "__main__":
    main()
