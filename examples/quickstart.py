"""Quickstart: the paper's pipeline on one layer, in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. synthesize a pattern-pruned conv layer (Table-II-like statistics),
2. map it onto 512x512 RRAM crossbars with the kernel-reordering scheme,
3. price area / energy / cycles vs the naive mapping (paper Figs 7-8),
4. show the same idea at MXU granularity: block-pattern SpMM (DESIGN §3).
"""

import numpy as np
import jax.numpy as jnp

from repro.core.indexing import build_index_stream, index_overhead_bits
from repro.core.mapping import map_layer, map_layer_naive
from repro.core.simulator import simulate_layer
from repro.core.synthetic import LayerSpec, synthesize_layer
from repro.core.sparse import block_density, build_block_pattern
from repro.kernels.ops import pattern_spmm

rng = np.random.default_rng(0)

# -- 1. a pattern-pruned layer: 128 -> 256 channels, 3x3 kernels ----------
spec = LayerSpec("demo", c_in=128, c_out=256, out_hw=16)
layer = synthesize_layer(
    spec, n_patterns=6, zero_ratio=0.4, target_sparsity=0.85, rng=rng
)
print(f"layer: {spec.c_in}->{spec.c_out}, "
      f"{layer.pdict.num_nonzero_patterns} nonzero patterns, "
      f"{(layer.weights == 0).mean():.1%} sparse")

# -- 2. kernel-reordering mapping -----------------------------------------
mapping = map_layer(layer.pattern_bits)
naive = map_layer_naive(spec.c_out, spec.c_in)
print(f"crossbars: ours={mapping.num_crossbars}  naive={naive.num_crossbars}"
      f"  (area efficiency {naive.num_crossbars/mapping.num_crossbars:.2f}x,"
      f" utilization {mapping.utilization:.0%})")

idx = index_overhead_bits(build_index_stream(mapping))
print(f"index overhead: {idx['total_bits']/8/1024:.1f} KB "
      f"({idx['bits_per_kernel_index']} bits/kernel)")

# -- 3. energy / cycles -----------------------------------------------------
res = simulate_layer(layer, zero_ind=None)
print(f"energy: {res.naive_energy_pj/res.ours_energy_pj:.2f}x  "
      f"speedup: {res.naive_cycles/max(res.ours_cycles,1):.2f}x "
      f"(without input-sparsity skips; the full benchmark adds them)")

# -- 4. the TPU-native form: block-pattern SpMM -----------------------------
w = rng.normal(size=(1024, 1024)).astype(np.float32)
bp = build_block_pattern(w, num_patterns=8, density=0.25)
x = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))
y = pattern_spmm(x, bp, backend="xla")
print(f"pattern_spmm: block density {block_density(bp):.2f} -> "
      f"{1/block_density(bp):.1f}x fewer FLOPs/weight-bytes, "
      f"output {y.shape}")
print("(on TPU the same call dispatches the Pallas kernel "
      "kernels/pattern_spmm.py)")
