"""End-to-end LM training driver with the full production runtime:
packed data pipeline, AdamW + cosine schedule, fault-tolerant Trainer
(async checkpoints, resume), optional pattern-sparse MLPs.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300  # ~100M

The default config (~10M params) trains a few hundred steps in CPU-minutes;
--hundred-m selects a ~100M-param model for real hardware.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, packed_batches
from repro.models.transformer import ModelConfig, count_params, init_params
from repro.optim import adamw, linear_warmup_cosine
from repro.runtime.train import (
    TrainConfig,
    Trainer,
    init_train_state,
    make_train_step,
)


def small_config(hundred_m: bool) -> ModelConfig:
    if hundred_m:
        return ModelConfig(
            name="lm100m", n_layers=12, d_model=768, vocab=32000,
            layer_types=(("attn", "mlp"),) * 12, n_heads=12, n_kv_heads=4,
            d_head=64, d_ff=2048, model_shards=1, max_seq=1024,
        )
    return ModelConfig(
        name="lm10m", n_layers=4, d_model=256, vocab=2048,
        layer_types=(("attn", "mlp"),) * 4, n_heads=8, n_kv_heads=4,
        d_head=32, d_ff=768, model_shards=1, max_seq=512,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = small_config(args.hundred_m)
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    print(f"{cfg.name}: {count_params(params)/1e6:.1f}M params")

    opt = adamw()
    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, async_ckpt=True,
    )
    lr_fn = linear_warmup_cosine(args.lr, 20, args.steps)
    step = jax.jit(make_train_step(cfg, statics, opt, lr_fn, tcfg),
                   donate_argnums=(0,))
    state = init_train_state(params, opt, tcfg)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    trainer = Trainer(step, state, packed_batches(dcfg), tcfg)
    resumed = trainer.maybe_restore()
    if resumed:
        print(f"resumed from checkpoint at step {resumed}")
    hist = trainer.run()
    for h in hist[:: max(1, len(hist) // 15)]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"({h['seconds']*1e3:.0f} ms/step)")
    print(f"final loss {hist[-1]['loss']:.4f}  "
          f"stragglers flagged: {len(trainer.straggler.flagged)}")


if __name__ == "__main__":
    main()
