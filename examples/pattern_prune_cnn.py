"""End-to-end reproduction in miniature: train -> pattern-prune -> map ->
simulate -> compile -> serve (the paper's full flowchart, Fig 3, CPU-sized,
plus the deployment path).

  PYTHONPATH=src python examples/pattern_prune_cnn.py \\
      [--precision {int8,fp32}] [--cell-bits N] [--trace-out trace.json]

Steps:
  1. train a small CNN on a synthetic 4-class task to ~100% accuracy,
  2. ADMM pattern pruning (irregular prune -> pattern PDF -> top-K
     dictionary -> ADMM -> hard projection -> masked retrain),
  3. map the pruned kernels with the kernel-reordering scheme,
  4. report the paper's three metrics on this network,
  5. compile the pruned network into an executable crossbar program and
     serve a batch of requests through the engine's classification service
     — then recompile with ``optimize='auto'`` to let the per-layer
     mapping design-space search shrink crossbar area at identical logits,
  6.-7. measured-vs-assumed energy pricing, sharded execution over a mesh,
  8. cell precision: recompile the same pruned network quantized.

Cell precision (step 8): the paper stores weights bit-sliced over 4-bit
RRAM cells; ``--precision int8`` compiles the pruned network a second
time with per-OU-row-group symmetric int8 weights that occupy
``ceil(8 / cell_bits)`` cells each (2 at the default ``--cell-bits 4``)
and *executes* them through the int8-input/int32-accumulate kernels.
That is the accuracy/area trade-off knob made measurable: the narrower
cells cut crossbar area and ADC energy (printed as the area/energy win
vs the fp32 compile), at the cost of a bounded quantization error —
printed as the max-abs logit delta and top-1 agreement vs the fp32
engine on a synthetic eval batch.  ``--precision fp32`` skips step 8;
``--cell-bits`` varies the priced cell width without touching the stored
int8 numbers (e.g. 2-bit cells -> 4 slices -> more area, same accuracy).

``--trace-out trace.json`` records steps 5+ on a span tracer
(``repro.obs``): compile phases, per-layer eager forward timings (which
also feed a predicted-vs-measured drift report), and the served
requests' lifecycles.  The script prints the top-3 slowest compile
phases and layers, and the written file loads in Perfetto or
chrome://tracing.
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapping import map_layer, map_layer_naive
from repro.core.pruning import PruneConfig, admm_pattern_prune, sparsity_of
from repro.engine import (
    CompileOptions,
    InferenceService,
    compile_network,
    load_program,
    make_forward,
    partition_network,
    save_program,
)
from repro.launch.mesh import make_mesh
from repro.models.cnn import (
    cnn_apply,
    conv_weight_names,
    init_cnn,
    mini_cnn_config,
)
from repro.optim import adamw

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--precision", choices=["int8", "fp32"], default="int8",
                help="stored cell precision for the step-8 quantized "
                     "compile (fp32 skips it)")
ap.add_argument("--cell-bits", type=int, default=4,
                help="RRAM cell width the int8 weights are sliced over "
                     "for hardware pricing")
ap.add_argument("--trace-out", default=None, metavar="FILE",
                help="write a Chrome trace-event JSON of compile/serve "
                     "spans (open in Perfetto or chrome://tracing)")
args = ap.parse_args()
if args.trace_out:
    from repro.obs import Tracer

    tracer = Tracer()
else:
    tracer = None
# build the quantized-compile config up front so bad flags fail in
# milliseconds, not after the training/pruning pipeline has run
if args.precision != "fp32":
    quant_opts = CompileOptions(precision=args.precision,
                                cell_bits=args.cell_bits)

t0 = time.time()
cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
protos = jax.random.normal(jax.random.PRNGKey(42), (4, 1, 12, 12))


def gen_batch(key, n=64):
    k1, k2 = jax.random.split(key)
    y = jax.random.randint(k1, (n,), 0, 4)
    x = protos[y] + 0.7 * jax.random.normal(k2, (n, 1, 12, 12))
    return x, y


def loss_fn(p, x, y):
    logits = cnn_apply(cfg, p, x)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


def accuracy(p):
    accs = []
    k = jax.random.PRNGKey(999)
    for _ in range(8):
        k, sk = jax.random.split(k)
        x, y = gen_batch(sk, 256)
        accs.append(float((cnn_apply(cfg, p, x).argmax(-1) == y).mean()))
    return float(np.mean(accs))


# -- 1. dense training ------------------------------------------------------
params = init_cnn(cfg, jax.random.PRNGKey(0))
opt = adamw(weight_decay=0.0)
state = opt.init(params)


@jax.jit
def step(p, s, x, y):
    _, g = jax.value_and_grad(loss_fn)(p, x, y)
    return opt.update(g, s, p, 3e-3)


key = jax.random.PRNGKey(1)
for _ in range(400):
    key, sk = jax.random.split(key)
    params, state = step(params, state, *gen_batch(sk))
acc_dense = accuracy(params)
print(f"[{time.time()-t0:5.1f}s] dense accuracy: {acc_dense:.3f}")

# -- 2. ADMM pattern pruning -------------------------------------------------
names = conv_weight_names(cfg)


def data_iter():
    k = jax.random.PRNGKey(7)
    while True:
        k, sk = jax.random.split(k)
        yield gen_batch(sk)


pcfg = PruneConfig(target_sparsity=0.7, num_patterns=4, admm_steps=200,
                   retrain_steps=200)
res = admm_pattern_prune(params, names, loss_fn, data_iter(), pcfg, opt)
acc_pruned = accuracy(res.params)
print(f"[{time.time()-t0:5.1f}s] pattern-pruned accuracy: {acc_pruned:.3f} "
      f"(drop {acc_dense-acc_pruned:+.3f}), "
      f"sparsity {sparsity_of(res.params, names):.1%}")
for n in names:
    d = res.dictionaries[n]
    print(f"  {n}: {d.num_nonzero_patterns} nonzero patterns, "
          f"layer sparsity {res.layer_sparsity(n):.1%}")

# -- 3./4. mapping + metrics --------------------------------------------------
tot_ours = tot_naive = 0
for n in names:
    bits = res.pattern_bits[n]
    m = map_layer(bits)
    nv = map_layer_naive(bits.shape[0], bits.shape[1])
    tot_ours += m.num_crossbars
    tot_naive += nv.num_crossbars
print(f"crossbars: ours={tot_ours} naive={tot_naive} "
      f"-> area efficiency {tot_naive/max(tot_ours,1):.2f}x")

# -- 5. compile into an executable crossbar program + serve ------------------
program = compile_network(cfg, res.params, res.pattern_bits,
                          options=CompileOptions(tracer=tracer))
with tempfile.TemporaryDirectory() as td:  # pay compilation once per model
    program = load_program(save_program(td + "/prog", program))
x, y = gen_batch(jax.random.PRNGKey(123), 64)
logits_ref = cnn_apply(cfg, res.params, x)
logits_eng = make_forward(program)(x)
diff = float(jnp.abs(logits_eng - logits_ref).max())
rep = program.hardware_report()
print(f"[{time.time()-t0:5.1f}s] compiled program "
      f"(max |engine - dense| = {diff:.2e}):")
for op, detail in program.op_list():
    print(f"  {op}: {detail}")
print(f"  hardware: {rep['crossbars']} crossbars "
      f"(naive {rep['naive_crossbars']}), "
      f"energy {rep['energy_pj']/1e3:.1f} nJ/img, "
      f"index {rep['index_kb']:.2f} KiB")

# -- 5b. mapping design-space search ------------------------------------------
# The paper fixes one geometry (512x512 crossbars, pattern-order packing)
# for every layer; optimize='auto' searches per layer over crossbar dims
# and packing/reorder strategies, priced by the simulator's own cost
# model, and never chooses a candidate worse than the fixed scheme on
# area or energy.  fp32 logits are bit-identical — layout only.
program_opt = compile_network(
    cfg, res.params, res.pattern_bits,
    options=CompileOptions(optimize="auto", tracer=tracer),
)
rep_opt = program_opt.hardware_report()
logits_opt = make_forward(program_opt)(x)
assert bool(jnp.array_equal(logits_opt, logits_eng)), "layout changed math"
print(f"[{time.time()-t0:5.1f}s] optimize='auto' mapping search:")
for name, m_entry in rep_opt["mapping"]["per_layer"].items():
    print(f"  {name}: {m_entry['rows']}x{m_entry['cols']} crossbars, "
          f"block_order={m_entry['block_order']}, "
          f"reorder={m_entry['reorder']}")
print(f"  area {rep_opt['area_cells']} cells vs fixed {rep['area_cells']} "
      f"({rep['area_cells']/max(rep_opt['area_cells'],1):.1f}x win), "
      f"energy {rep_opt['energy_pj']/1e3:.1f} nJ/img "
      f"(fixed {rep['energy_pj']/1e3:.1f}), logits bit-identical")

service = InferenceService(program, batch_slots=16, collect_stats=True,
                           tracer=tracer)
labels = service.classify(np.asarray(x))
acc_served = float((labels == np.asarray(y)).mean())
m = service.metrics
print(f"[{time.time()-t0:5.1f}s] served {len(labels)} requests in "
      f"{service.batches_run} batches, accuracy {acc_served:.3f}")
print(f"  scheduler: 1 traced batch shape ({service.trace_count()} trace), "
      f"occupancy {m['occupancy_mean']:.0%}, "
      f"mean latency {m['latency_mean_s']*1e3:.1f} ms")

# -- 6. measured vs assumed energy --------------------------------------------
# The service counted, per layer and OU row-group, how often an input
# selection was all-zero on the traffic it actually served; pricing from
# those *measured* skip probabilities replaces the assumed-probability
# fallback (here 0.5 — "ReLU zeroes about half").
rep_m = service.hardware_report(assumed_skip=0.5)
skip = rep_m["skip"]
print(f"energy pricing over {skip['measured_windows']} measured windows:")
print(f"  no-skip upper bound : {skip['energy_pj_noskip']/1e3:8.1f} nJ/img")
print(f"  assumed skip (p=0.5): {skip['energy_pj_assumed']/1e3:8.1f} nJ/img")
print(f"  measured skip       : {skip['energy_pj_measured']/1e3:8.1f} nJ/img "
      f"({skip['measured_discount']:.1%} below no-skip)")
print(f"  measured - assumed  : "
      f"{skip['measured_vs_assumed_delta_pj']/1e3:+8.1f} nJ/img "
      f"({skip['measured_vs_assumed_delta_frac']:+.1%})")
for lrow in rep_m["layers"]:
    st = service.activation_stats.layers.get(lrow["name"])
    if st is None:
        continue
    print(f"  {lrow['name']}: mean measured skip {st.mean_skip():.2f}, "
          f"energy {lrow['energy_pj_measured']/1e3:.1f} nJ "
          f"(no-skip {lrow['energy_pj']/1e3:.1f} nJ)")
# -- 7. sharded execution across a device mesh -------------------------------
# One compiled artifact serves from multiple chips: each layer's spmm
# tiles split over the mesh's 'model' axis (partial outputs psum-combined)
# and batch slots over 'data'.  On this host the mesh covers however many
# devices exist (run under XLA_FLAGS=--xla_force_host_platform_device_count=8
# to see a real 8-way split); outputs match the unsharded forward.
n_dev = len(jax.devices())
mesh = make_mesh((1, n_dev), ("data", "model"))
sharded_prog = partition_network(program, model=n_dev)
logits_sh = make_forward(sharded_prog, mesh=mesh)(x)
print(f"[{time.time()-t0:5.1f}s] sharded over {n_dev} device(s): "
      f"max |sharded - unsharded| = "
      f"{float(jnp.abs(logits_sh - logits_eng).max()):.2e}")
chips = sharded_prog.hardware_report()["chips"]
print(f"  per-chip split ({chips['model_shards']} tile-parallel chip(s)): "
      f"max {chips['crossbars_per_chip_max']:.1f} crossbars/chip, "
      f"bottleneck {chips['cycles_parallel']:.0f} cycles "
      f"({chips['parallel_speedup']:.2f}x vs single chip)")

# -- 8. cell precision: int-quantized 4-bit-cell execution --------------------
# The same pruned network, stored the way the crossbars would hold it:
# per-row-group symmetric int8 bricks sliced over args.cell_bits-wide
# cells, executed through the int8-input/int32-accumulate kernels.  The
# hardware report now prices the cells actually stored, so the area and
# ADC-energy win of the narrower cells appears next to the accuracy cost.
if args.precision != "fp32":
    program_q = compile_network(
        cfg, res.params, res.pattern_bits, options=quant_opts
    )
    x_eval, y_eval = gen_batch(jax.random.PRNGKey(321), 256)
    logits_fp = make_forward(program)(x_eval)
    logits_q = make_forward(program_q)(x_eval)
    top1_agree = float(
        (jnp.argmax(logits_q, -1) == jnp.argmax(logits_fp, -1)).mean()
    )
    acc_q = float((np.asarray(jnp.argmax(logits_q, -1)) ==
                   np.asarray(y_eval)).mean())
    rep_q = program_q.hardware_report()
    prec = rep_q["precision"]
    cb_fp, _ = program.weight_bytes()
    cb_q, _ = program_q.weight_bytes()
    print(f"[{time.time()-t0:5.1f}s] cell precision "
          f"({prec['weights']}, {prec['cell_bits']}-bit cells, "
          f"{prec['cells_per_weight']} cells/weight):")
    print(f"  accuracy: max |int8 - fp32| = "
          f"{float(jnp.abs(logits_q - logits_fp).max()):.2e}, "
          f"top-1 agreement {top1_agree:.1%} "
          f"(served accuracy {acc_q:.3f})")
    print(f"  area:     {rep_q['crossbars']} crossbars vs "
          f"{rep['crossbars']} fp32-priced "
          f"({rep['crossbars']/max(rep_q['crossbars'],1):.2f}x win), "
          f"weights {cb_q/1024:.1f} KiB vs {cb_fp/1024:.1f} KiB")
    print(f"  energy:   {rep_q['energy_pj']/1e3:.1f} nJ/img vs "
          f"{rep['energy_pj']/1e3:.1f} nJ/img no-skip "
          f"({rep['energy_pj']/max(rep_q['energy_pj'],1e-9):.2f}x win)")

# -- observability epilogue: where the time actually went --------------------
# The instrumented forward runs the layers eagerly, one span each, so the
# measured wall-times can sit next to the simulator's predicted cycles
# (hardware_report's drift section) and the slowest compile phases /
# layers fall straight out of the collected spans.
if tracer is not None:
    fwd_tr = make_forward(program, tracer=tracer)
    jax.block_until_ready(fwd_tr(x))
    drift = program.hardware_report(observed=fwd_tr.observed_times())["drift"]
    print(f"[{time.time()-t0:5.1f}s] predicted-vs-measured drift over "
          f"{len(drift['layers'])} layers: "
          f"max |share drift| {drift['max_abs_share_drift']:.1%}, "
          f"rate spread {drift['rate_spread']:.1f}x")
    PHASES = ("prune", "reorder", "pack", "quantize")
    top_phases = [(n, s) for n, s in tracer.slowest(16, cat="compile")
                  if n in PHASES][:3]
    print("  top-3 compile phases: "
          + ", ".join(f"{n} {s*1e3:.1f} ms" for n, s in top_phases))
    top_layers = tracer.slowest(3, cat="execute", prefix="layer:")
    print("  top-3 layers:         "
          + ", ".join(f"{n.removeprefix('layer:')} {s*1e3:.1f} ms"
                      for n, s in top_layers))
    tracer.write(args.trace_out)
    print(f"  wrote {args.trace_out} (open in Perfetto / chrome://tracing)")

print("(full-scale VGG16 numbers: PYTHONPATH=src python -m benchmarks.run"
      " --only paper; engine bench: python -m benchmarks.bench_engine)")
