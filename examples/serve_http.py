"""Serve over HTTP: boot the asyncio front end and drive it with real
sockets (the RPC-shaped end-to-end example).

Classification (default) compiles the mini pattern-pruned CNN and
serves it through ``repro.serve.classify_session``; ``--backend
generate`` serves token generation through ``generate_session`` —
per-slot decode positions, so freed slots are refilled *mid-decode*
while other requests keep decoding.

All requests go through ``POST /v1/stream`` on one connection (chunked
NDJSON, completion order); the script then prints sustained req/s,
first-result p50/p99, and mean slot occupancy from the scheduler
metrics, plus a ``/metrics`` scrape excerpt.

  PYTHONPATH=src python examples/serve_http.py
  PYTHONPATH=src python examples/serve_http.py --backend generate \\
      --requests 100 --trace-out serve_decode_trace.json --check

``--trace-out`` writes the Chrome trace-event JSON (Perfetto /
chrome://tracing) of the run — for ``--backend generate`` it carries the
``admit_mid_decode`` instants that ``benchmarks/check_baseline.py
--trace FILE --require-mid-decode`` validates in CI.  ``--check`` turns
the serving invariants (single trace, >= 90% occupancy, every request
served) into hard assertions.
"""

import argparse
import http.client
import json
import time

import jax
import numpy as np

from repro.obs.trace import Tracer
from repro.serve import ServingServer, classify_session, generate_session

OCCUPANCY_FLOOR = 0.90


def _classify_setup(slots, tracer):
    from repro.core.pruning import (
        build_dictionaries,
        magnitude_prune,
        project_params,
    )
    from repro.engine import CompileOptions, compile_network
    from repro.models.cnn import (
        conv_weight_names,
        init_cnn,
        mini_cnn_config,
    )

    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, 0.7)
    dicts = build_dictionaries(params, names, 4)
    params, bits = project_params(params, dicts)
    prog = compile_network(
        cfg, params, bits, options=CompileOptions(tracer=tracer)
    )
    session = classify_session(prog, batch_slots=slots, tracer=tracer)
    rng = np.random.default_rng(0)

    def payload(i):
        return {"image": rng.normal(size=(1, 12, 12)).tolist()}

    return session, payload


def _generate_setup(arch, slots, prompt_len, tracer):
    from repro.configs import get_smoke_config
    from repro.models.transformer import count_params, init_params
    from repro.runtime.serve import ServeConfig

    cfg = get_smoke_config(arch)
    params, _, statics = init_params(cfg, jax.random.PRNGKey(0))
    print(f"{cfg.name}: {count_params(params):,} params")
    scfg = ServeConfig(
        batch_slots=slots, max_seq=prompt_len + 24, eos_id=-1
    )
    session = generate_session(
        cfg, statics, params, scfg, tracer=tracer
    )
    rng = np.random.default_rng(0)

    def payload(i):
        # one prompt length (one prefill trace); staggered budgets so
        # completions interleave and freed slots refill mid-decode
        return {
            "prompt": rng.integers(1, cfg.vocab, prompt_len)
            .astype(int).tolist(),
            "max_new_tokens": 4 + i % 9,
        }

    return session, payload


def _stream(host, port, payloads, timeout=600):
    """POST /v1/stream and read the chunked NDJSON reply line by line."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request(
            "POST", "/v1/stream",
            json.dumps({"requests": payloads}),
            {"Content-Type": "application/json"},
        )
        resp = conn.getresponse()
        lines = []
        while True:
            line = resp.readline()
            if not line:
                break
            lines.append(json.loads(line))
        return resp.status, lines
    finally:
        conn.close()


def _get(host, port, path, timeout=30):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, resp.read().decode()
    finally:
        conn.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("classify", "generate"),
                    default="classify")
    ap.add_argument("--arch", default="granite_3_2b",
                    help="smoke model for --backend generate")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome trace-event JSON of the run")
    ap.add_argument("--check", action="store_true",
                    help="assert the serving invariants (CI smoke mode)")
    args = ap.parse_args()

    tracer = Tracer() if args.trace_out else None
    if args.backend == "classify":
        session, payload = _classify_setup(args.slots, tracer)
    else:
        session, payload = _generate_setup(
            args.arch, args.slots, args.prompt_len, tracer
        )

    srv = ServingServer(session, admit_wait_s=0.02)
    host, port = srv.start_in_thread()
    print(f"serving {args.backend} on http://{host}:{port}")
    try:
        payloads = [payload(i) for i in range(args.requests)]
        t0 = time.perf_counter()
        status, lines = _stream(host, port, payloads)
        dt = time.perf_counter() - t0

        m = session.metrics
        ok = [ln for ln in lines if ln.get("ok")]
        print(
            f"{len(ok)}/{args.requests} requests ok over HTTP "
            f"({args.slots} slots): {args.requests / dt:.1f} req/s "
            f"in {dt:.2f}s"
        )
        print(
            f"first result p50={m['first_result_p50_s'] * 1e3:.2f}ms "
            f"p99={m['first_result_p99_s'] * 1e3:.2f}ms; "
            f"occupancy={m['occupancy_mean']:.3f}; "
            f"batches={m['steps']}; traces={session.trace_count()}"
        )
        _, health = _get(host, port, "/healthz")
        print(f"/healthz {health}")
        _, metrics = _get(host, port, "/metrics")
        wanted = ("occupancy_mean", "completed_total",
                  "serve_http_requests_rate_per_s")
        for line in metrics.splitlines():
            if any(w in line for w in wanted) and "# " not in line:
                print(f"/metrics  {line}")

        if args.check:
            assert status == 200 and len(lines) == args.requests
            assert len(ok) == args.requests, "every request must be served"
            assert session.trace_count() == 1, (
                f"forward traced {session.trace_count()} times"
            )
            assert m["occupancy_mean"] >= OCCUPANCY_FLOOR, (
                f"occupancy {m['occupancy_mean']:.3f} < {OCCUPANCY_FLOOR}"
            )
            if args.backend == "generate" and tracer is not None:
                mid = [
                    e for e in tracer.events()
                    if e.get("args", {}).get("event") == "admit_mid_decode"
                ]
                assert mid, "no mid-decode admissions observed"
                print(f"check ok ({len(mid)} mid-decode admissions)")
            else:
                print("check ok")
    finally:
        srv.shutdown()
    if tracer is not None:
        tracer.write(args.trace_out)
        print(f"wrote {args.trace_out}")


if __name__ == "__main__":
    main()
