"""MoE dispatch quality: token drop rate vs capacity factor (the dropless
claim behind the capacity semantics in repro.models.moe)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.models.moe import MoEConfig, moe_init, _route


def _drop_rate(cfg: MoEConfig, t: int, seed: int) -> float:
    params, _, _ = moe_init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, cfg.d_model))
    _, top_e = _route(params, cfg, x)
    cap = int(max(1, round(t * cfg.top_k / cfg.n_experts
                           * cfg.capacity_factor)))
    counts = np.bincount(np.asarray(top_e).ravel(), minlength=cfg.n_experts)
    dropped = np.maximum(counts - cap, 0).sum()
    return float(dropped) / (t * cfg.top_k)


def run() -> list[str]:
    rows = []
    for cf in (1.0, 1.25, 2.0):
        cfg = MoEConfig(d_model=64, n_experts=32, top_k=4, d_ff_expert=16,
                        capacity_factor=cf, model_shards=1)
        drop, us = timed(_drop_rate, cfg, 8192, 0)
        rows.append(row(f"moe_drop_cf{cf}", us, f"drop_rate={drop:.4f}"))
    return rows
