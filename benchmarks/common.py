"""Shared benchmark helpers: timing + CSV row collection."""

from __future__ import annotations

import time


def timed(fn, *args, repeats: int = 1, **kwargs):
    """Returns (result, microseconds per call)."""
    fn(*args, **kwargs)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
