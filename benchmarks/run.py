"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV.  Run as:
  PYTHONPATH=src python -m benchmarks.run [--only paper|kernels|moe|roofline]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import bench_engine, bench_kernels, bench_moe, \
        bench_paper, bench_roofline

    suites = {
        "paper": bench_paper.run,
        "kernels": bench_kernels.run,
        "engine": bench_engine.run,
        "moe": bench_moe.run,
        "roofline": bench_roofline.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        try:
            for line in fn():
                print(line, flush=True)
        except Exception:
            failures += 1
            print(f"{name},0.0,SUITE-ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
