"""Roofline table reader: aggregates experiments/dryrun/*.json (written by
launch/dryrun.py) into per-(arch x shape) rows with the three roofline
terms, the dominant bottleneck, and the MODEL_FLOPS/HLO_FLOPs ratio."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str = "single", include_sparse: bool = False):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        if bool(rec.get("sparse")) != include_sparse:
            continue
        cells.append(rec)
    return cells


def run() -> list[str]:
    rows = []
    for rec in load_cells("single"):
        name = f"roofline_{rec['arch']}_{rec['shape']}"
        if rec["status"] == "skip":
            rows.append(row(name, 0.0, "SKIP(sub-quadratic-only shape)"))
            continue
        if rec["status"] != "ok":
            rows.append(row(name, 0.0, f"ERROR {rec.get('error','')[:60]}"))
            continue
        r = rec["roofline"]
        ratio = rec.get("useful_flops_ratio")
        bound = max(r, key=r.get)
        step = max(r.values())
        rows.append(row(
            name,
            rec.get("compile_s", 0) * 1e6,
            f"bound={bound.split('_')[0]} step={step*1e3:.2f}ms "
            f"c={r['compute_s']*1e3:.2f} m={r['memory_s']*1e3:.2f} "
            f"x={r['collective_s']*1e3:.2f} "
            f"useful={ratio:.2f}" if ratio else "useful=n/a",
        ))
    # multi-pod: prove the pod axis compiles everywhere
    multi = load_cells("multi")
    ok = sum(1 for r in multi if r["status"] == "ok")
    skip = sum(1 for r in multi if r["status"] == "skip")
    err = sum(1 for r in multi if r["status"] == "error")
    rows.append(row("multipod_dryrun", 0.0,
                    f"ok={ok} skip={skip} error={err}"))
    return rows
