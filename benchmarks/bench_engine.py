"""Dense ``cnn_apply`` vs compiled-engine execution, across sparsity levels.

Runs mini-CNN and VGG16 shapes on CPU, and emits a JSON report with:

  * dense-vs-engine wall-clock per (network, sparsity),
  * each compiled program's ``hardware_report()`` totals, priced three
    ways for the same compiled network: no-skip upper bound, an *assumed*
    uniform skip probability (ASSUMED_SKIP), and the skip probabilities
    *measured* on the bench activations by the stats-collecting forward —
    plus the measured-vs-assumed energy delta,
  * a ``quantized`` sub-entry per level: the same pruned network compiled
    at ``precision='int8'`` (4-bit-cell bit-sliced storage) and executed
    through the int8-input/int32-accumulate kernel — accuracy delta
    (max-abs logit difference and top-1 agreement vs the fp32 engine)
    next to the crossbar-area/energy win the narrower cells buy,
  * a ``service`` throughput entry: ``InferenceService`` draining a
    bursty 100-request trace at fixed ``batch_slots`` through the
    continuous-batching scheduler — requests/s, mean occupancy/latency,
    the single-trace guarantee (``trace_count``) and the exactness of the
    accumulated skip statistics vs a one-shot stats forward,
  * an ``http_service`` entry: the same bursty trace through the
    ``repro.serve`` asyncio HTTP front end over a real socket — req/s,
    first-result p50/p99, mean slot occupancy (gated at >= 90%), the
    single-trace invariant under socket-driven concurrency, and a
    load-shedding phase whose served/shed split must conserve requests,
  * a 1-vs-N-device sharded-execution entry: the same compiled program
    run unsharded and tile/batch-sharded over a mesh of N virtualized
    host devices (subprocess, ``--xla_force_host_platform_device_count``),
    recording both wall-clocks, the speedup, and the max output
    difference.  On virtualized CPU devices the "speedup" mostly measures
    collective overhead — the entry exists so the TPU run has a number to
    replace,
  * a consistency check: compiling the Table-II-matched synthetic cifar10
    network must reproduce ``core/simulator.simulate_dataset``'s per-layer
    crossbar counts exactly (same pattern bits -> same ``map_layer``).

Usage:
  PYTHONPATH=src python -m benchmarks.bench_engine \\
      [--out FILE] [--quick] [--smoke] [--trace-out FILE]

``--trace-out`` additionally records the service entry on a span tracer
(``repro.obs``) and writes a Chrome trace-event JSON — load it in
Perfetto or chrome://tracing to see compile phases, per-layer forward
spans, and all 100 request lifecycles on one timeline; the service
entry then also carries the predicted-vs-measured ``drift`` section.

``--smoke`` is the CI bench-regression configuration: mini-CNN only, one
sparsity level, a 2-device sharded entry — small enough for every PR, but
still covering the engine-vs-dense ratio, the quantized accuracy/area
numbers, and the simulator-consistency check that
``benchmarks/check_baseline.py`` gates against
``benchmarks/baselines/bench_smoke.json``.

As part of ``benchmarks.run`` it contributes the usual CSV rows.
"""

from __future__ import annotations

import argparse
import dataclasses
import http.client
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.obs.trace import Tracer
from repro.core.pruning import (
    build_dictionaries,
    magnitude_prune,
    project_params,
)
from repro.core.simulator import simulate_dataset
from repro.core.synthetic import synthesize_network
from repro.engine import (
    CompileOptions,
    InferenceService,
    compile_network,
    make_forward,
)
from repro.serve import Request, ServingServer, classify_session
from repro.models.cnn import (
    CNNConfig,
    cnn_apply,
    conv_weight_names,
    init_cnn,
    mini_cnn_config,
    vgg16_config,
)

SPARSITIES = (0.5, 0.75, 0.9)
# Fallback skip probability when no activations have been observed: ReLU
# on roughly centred pre-activations zeroes ~half the inputs, so a
# selection of one pattern's taps being all-zero is modelled coarsely as
# 0.5 — precisely the kind of assumption the measured path replaces.
ASSUMED_SKIP = 0.5


def _pruned(cfg: CNNConfig, sparsity: float, num_patterns: int, seed: int):
    params = init_cnn(cfg, jax.random.PRNGKey(seed))
    names = conv_weight_names(cfg)
    params = magnitude_prune(params, names, sparsity)
    dicts = build_dictionaries(params, names, num_patterns)
    return project_params(params, dicts)


EVAL_BATCH = 128  # agreement sample size: granularity 1/128 < gate slack


def _quantized_entry(cfg, params, bits, x, fp32_fn, fp32_us, rep_fp32):
    """Int8/4-bit-cell execution of the same pruned network: accuracy
    delta vs the fp32 engine next to the area/energy the cells buy.

    Timing uses the bench batch ``x``; the accuracy numbers use a larger
    synthetic eval batch so top-1 agreement has finer granularity than
    the baseline gate's slack (one argmax flip must not fail CI).

    Deep *random-init* networks (the vgg16 entry) report noticeably
    lower agreement than trained ones: per-sample ``channel_norm``
    divides by a std computed from the (quantization-noisy) activations
    of each sample, so int8 scale noise compounds layer over layer and
    random-init logits are near-tied to begin with.  The trained mini
    example and the smoke gate sit at 100% agreement."""
    progq = compile_network(
        cfg, params, bits, options=CompileOptions(precision="int8")
    )
    q_fn = make_forward(progq, backend="xla")
    _, q_us = timed(lambda: jax.block_until_ready(q_fn(x)), repeats=3)
    repq = progq.hardware_report()
    comp_bytes, _ = progq.weight_bytes()
    x_eval = jax.random.normal(
        jax.random.PRNGKey(7), (EVAL_BATCH,) + x.shape[1:]
    )
    out_fp32, out_q = fp32_fn(x_eval), q_fn(x_eval)
    top1 = float(
        (jnp.argmax(out_q, -1) == jnp.argmax(out_fp32, -1)).mean()
    )
    return {
        "precision": progq.precision,
        "cell_bits": progq.cell_bits,
        "cells_per_weight": repq["precision"]["cells_per_weight"],
        "eval_batch": EVAL_BATCH,
        "engine_us": q_us,
        "vs_fp32_engine": q_us / max(fp32_us, 1e-9),
        "max_abs_diff_vs_fp32": float(jnp.abs(out_q - out_fp32).max()),
        "top1_agreement_vs_fp32": top1,
        "weight_bytes": comp_bytes,
        "crossbars": repq["crossbars"],
        "area_efficiency": repq["area_efficiency"],
        "energy_pj_noskip": repq["energy_pj"],
        "area_win_vs_fp32": rep_fp32["crossbars"]
        / max(repq["crossbars"], 1),
        "energy_win_vs_fp32": rep_fp32["energy_pj"]
        / max(repq["energy_pj"], 1e-9),
        # same stored int8 numbers, repriced at other cell widths: the
        # accuracy column is constant, the area/energy columns move
        "cell_sweep": [
            {
                "cell_bits": cb,
                "cells_per_weight": rep_cb["precision"]["cells_per_weight"],
                "crossbars": rep_cb["crossbars"],
                "energy_pj_noskip": rep_cb["energy_pj"],
            }
            for cb in (2, 4, 8)
            for rep_cb in [
                dataclasses.replace(progq, cell_bits=cb).hardware_report()
            ]
        ],
    }


def _bench_network(name: str, cfg: CNNConfig, batch: int,
                   sparsities=SPARSITIES) -> dict:
    x = jax.random.normal(
        jax.random.PRNGKey(0),
        (batch, cfg.conv_channels[0][0], cfg.input_hw, cfg.input_hw),
    )
    entries = []
    dense_fn = jax.jit(lambda p, xx: cnn_apply(cfg, p, xx))
    for s in sparsities:
        params, bits = _pruned(cfg, s, num_patterns=8, seed=1)
        _, dense_us = timed(
            lambda: jax.block_until_ready(dense_fn(params, x)), repeats=3
        )
        prog = compile_network(cfg, params, bits)
        eng_fn = make_forward(prog, backend="xla")
        out_eng, eng_us = timed(
            lambda: jax.block_until_ready(eng_fn(x)), repeats=3
        )
        max_diff = float(
            jnp.abs(out_eng - dense_fn(params, x)).max()
        )
        _, stats = make_forward(prog, backend="xla", collect_stats=True)(x)
        rep = prog.hardware_report(
            skip_stats=stats, assumed_skip=ASSUMED_SKIP
        )
        comp_bytes, dense_bytes = prog.weight_bytes()
        entries.append(
            {
                "sparsity": s,
                "dense_us": dense_us,
                "engine_us": eng_us,
                "engine_vs_dense": eng_us / max(dense_us, 1e-9),
                "max_abs_diff": max_diff,
                "weight_bytes": comp_bytes,
                "dense_weight_bytes": dense_bytes,
                "energy_pj_noskip": rep["energy_pj"],
                "energy_pj_assumed": rep["energy_pj_assumed"],
                "energy_pj_measured": rep["energy_pj_measured"],
                "measured_vs_assumed_delta_pj":
                    rep["skip"]["measured_vs_assumed_delta_pj"],
                "measured_mean_skip": stats.mean_skip(),
                "quantized": _quantized_entry(
                    cfg, params, bits, x, eng_fn, eng_us, rep
                ),
                "hardware_report": {
                    k: v for k, v in rep.items() if k != "layers"
                },
            }
        )
    return {"network": name, "batch": batch, "input_hw": cfg.input_hw,
            "levels": entries}


# Bursty arrival trace for the service-throughput entry: burst sizes are
# fixed (not drawn at bench time) so batches_run / occupancy are
# deterministic and the baseline can gate them exactly.
SERVICE_BURSTS = (1, 7, 19, 2, 30, 5, 11, 3, 22)  # 100 requests
SERVICE_SLOTS = 8


def _service_throughput(batch_slots: int = SERVICE_SLOTS,
                        tracer: Tracer | None = None) -> dict:
    """Requests/s of ``InferenceService`` under a bursty 100-request
    arrival trace at fixed ``batch_slots``.

    The service executes every batch at the one fixed slot shape (dead
    slots zero-padded + masked), so the whole trace must hit a single
    jitted trace; the entry records that (``trace_count``), the exactness
    of the accumulated skip statistics vs a one-shot stats forward over
    the same images (``stats_exact``), and an ``overhead_vs_forward``
    ratio (service wall-clock per batch / bare forward wall-clock —
    machine speed cancels, so the baseline can gate it loosely).

    With a ``tracer`` (``--trace-out``) the same run also lands on the
    shared timeline: compile-phase spans, the per-request lifecycles of
    all 100 bursty-trace requests, and — after the timed region, so the
    throughput numbers stay clean — one instrumented per-layer forward
    whose measured wall-times feed a non-gated predicted-vs-measured
    ``drift`` section (``hardware_report(observed=...)``).
    """
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params, bits = _pruned(cfg, 0.75, num_patterns=8, seed=1)
    prog = compile_network(
        cfg, params, bits, options=CompileOptions(tracer=tracer)
    )
    svc = InferenceService(prog, batch_slots=batch_slots, backend="xla",
                           collect_stats=True, tracer=tracer)
    n = sum(SERVICE_BURSTS)
    images = np.array(jax.random.normal(
        jax.random.PRNGKey(3), (n, cfg.conv_channels[0][0],
                                cfg.input_hw, cfg.input_hw)
    ), np.float32)

    # warm the one trace outside the timed region, then reset the stats
    # and metrics windows so the entry describes only the bursty trace
    svc.serve([Request(image=images[0])])
    svc.reset_stats()
    svc.reset_metrics()
    base_batches = svc.batches_run

    reqs = [Request(image=img) for img in images]
    it = iter(reqs)
    t0 = time.perf_counter()
    for burst in SERVICE_BURSTS:
        for _ in range(burst):
            svc.submit(next(it))
        svc.step()
    svc.run()
    dt = time.perf_counter() - t0

    batches = svc.batches_run - base_batches
    fwd = make_forward(prog, backend="xla", collect_stats=True)
    out, ref_stats = fwd(jnp.asarray(images))
    jax.block_until_ready(out)
    _, fwd_us = timed(
        lambda: jax.block_until_ready(
            svc._forward(jnp.asarray(images[:batch_slots]),
                         np.ones(batch_slots, bool))[0]
        ),
        repeats=5,
    )
    stats_exact = all(
        np.array_equal(svc.activation_stats.layers[k].counts,
                       ref_stats.layers[k].counts)
        and svc.activation_stats.layers[k].windows
        == ref_stats.layers[k].windows
        for k in ref_stats.layers
    )
    m = svc.metrics
    entry = {
        "requests": n,
        "batch_slots": batch_slots,
        "bursts": list(SERVICE_BURSTS),
        "requests_per_s": n / max(dt, 1e-9),
        "batches_run": batches,
        "trace_count": svc.trace_count(),
        "occupancy_mean": m["occupancy_mean"],
        "latency_mean_s": m["latency_mean_s"],
        "latency_p50_s": m["latency_p50_s"],
        "latency_p99_s": m["latency_p99_s"],
        "queue_wait_mean_s": m["queue_wait_mean_s"],
        "overhead_vs_forward": (dt * 1e6 / max(batches, 1))
        / max(fwd_us, 1e-9),
        "stats_exact": stats_exact,
    }
    if tracer is not None:
        # outside the timed region: one eager per-layer forward for the
        # execute-category spans, then the predicted-vs-measured drift
        # section (timing-dependent, so never baseline-gated)
        tfwd = make_forward(prog, backend="xla", tracer=tracer)
        jax.block_until_ready(tfwd(jnp.asarray(images[:batch_slots])))
        rep = prog.hardware_report(skip_stats=svc.activation_stats,
                                   observed=tfwd.observed_times())
        entry["drift"] = rep["drift"]
    return entry


# HTTP shed phase: more one-shot admissions than queue + slots can hold,
# so the front door must 429 some of them while serving the rest
HTTP_SHED_SLOTS = 4
HTTP_SHED_QUEUE = 8
HTTP_SHED_REQUESTS = 40


def _stream_http(host, port, payloads, timeout=600):
    """POST /v1/stream and read the chunked NDJSON reply line by line."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request(
            "POST", "/v1/stream",
            json.dumps({"requests": payloads}),
            {"Content-Type": "application/json"},
        )
        resp = conn.getresponse()
        lines = []
        while True:
            line = resp.readline()
            if not line:
                break
            lines.append(json.loads(line))
        return resp.status, lines
    finally:
        conn.close()


def _http_service_throughput(batch_slots: int = SERVICE_SLOTS) -> dict:
    """The same bursty trace through the asyncio HTTP front end, over a
    real socket (``repro.serve.ServingServer`` + ``/v1/stream``).

    Two servers over one compiled program:

      * **throughput** — all 100 requests on one streaming connection
        with an unbounded queue; the entry records req/s, the
        first-result SLO percentiles, mean slot occupancy (the
        ``check_baseline.py`` gate requires >= 90% through the HTTP
        path), and the single-trace invariant surviving socket-driven
        concurrency;
      * **shed** — a burst of ``HTTP_SHED_REQUESTS`` one-shot admissions
        against a small bounded queue, so the front door must shed: the
        entry records the served/shed split and a conservation check
        (served + shed == submitted, every shed line a well-formed
        overload response, nothing admitted ever dropped).  The exact
        shed count races the worker's drain speed, so only its bounds
        are gated.
    """
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params, bits = _pruned(cfg, 0.75, num_patterns=8, seed=1)
    prog = compile_network(cfg, params, bits)
    n = sum(SERVICE_BURSTS)
    images = np.array(jax.random.normal(
        jax.random.PRNGKey(3), (n, cfg.conv_channels[0][0],
                                cfg.input_hw, cfg.input_hw)
    ), np.float32)
    payloads = [{"image": img.tolist()} for img in images]

    srv = ServingServer(
        classify_session(prog, batch_slots=batch_slots),
        admit_wait_s=0.02,
    )
    host, port = srv.start_in_thread()
    try:
        t0 = time.perf_counter()
        status, lines = _stream_http(host, port, payloads)
        dt = time.perf_counter() - t0
        m = srv.session.metrics
        entry = {
            "requests": n,
            "batch_slots": batch_slots,
            "all_ok": (
                status == 200
                and len(lines) == n
                and all(ln.get("ok") for ln in lines)
            ),
            "requests_per_s": n / max(dt, 1e-9),
            "first_result_p50_s": m["first_result_p50_s"],
            "first_result_p99_s": m["first_result_p99_s"],
            "occupancy_mean": m["occupancy_mean"],
            "batches_run": m["steps"],
            "trace_count": srv.session.trace_count(),
            "http_completed": srv.completed,
            "meter_rate_per_s": srv.meter.rate,
        }
    finally:
        srv.shutdown()

    shed_srv = ServingServer(
        classify_session(prog, batch_slots=HTTP_SHED_SLOTS,
                         max_queue=HTTP_SHED_QUEUE),
        admit_wait_s=0.0,
    )
    host, port = shed_srv.start_in_thread()
    try:
        status, lines = _stream_http(
            host, port, payloads[:HTTP_SHED_REQUESTS]
        )
        served = [ln for ln in lines if ln.get("ok")]
        shed = [ln for ln in lines if not ln.get("ok")]
        sm = shed_srv.session.metrics
        entry["shed"] = {
            "requests": HTTP_SHED_REQUESTS,
            "batch_slots": HTTP_SHED_SLOTS,
            "max_queue": HTTP_SHED_QUEUE,
            "served": len(served),
            "shed": len(shed),
            "trace_count": shed_srv.session.trace_count(),
            "conservation_ok": (
                status == 200
                and len(served) + len(shed) == HTTP_SHED_REQUESTS
                and len(served) == sm["completed"]
                and sm["rejected"] == len(shed)
                and all(
                    ln.get("error") == "overloaded"
                    and ln.get("retry_after_s", 0) > 0
                    for ln in shed
                )
            ),
        }
    finally:
        shed_srv.shutdown()
    return entry


# The backend must see the forced host-device count before it initializes,
# so the sharded comparison runs in a subprocess (same pattern as
# tests/test_distributed.py).
_SHARDED_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import json, time
import jax, numpy as np
from repro.core.pruning import (build_dictionaries, magnitude_prune,
                                project_params)
from repro.engine import compile_network, make_forward, partition_network
from repro.launch.mesh import make_mesh
from repro.models.cnn import conv_weight_names, init_cnn, mini_cnn_config

cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
params = init_cnn(cfg, jax.random.PRNGKey(1))
names = conv_weight_names(cfg)
params = magnitude_prune(params, names, {sparsity})
dicts = build_dictionaries(params, names, 8)
params, bits = project_params(params, dicts)
prog = compile_network(cfg, params, bits)
x = jax.random.normal(jax.random.PRNGKey(0), ({batch}, 1, 12, 12))


def timed(fn, repeats=5):
    out = jax.block_until_ready(fn())  # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn())
    return out, (time.perf_counter() - t0) / repeats * 1e6


single = make_forward(prog, backend="xla")
y1, single_us = timed(lambda: single(x))
mesh = make_mesh(({data}, {model}), ("data", "model"))
sharded = make_forward(partition_network(prog, data={data}, model={model}),
                       backend="xla", mesh=mesh)
yn, sharded_us = timed(lambda: sharded(x))
print(json.dumps({{
    "devices": {n}, "mesh": [{data}, {model}], "batch": {batch},
    "sparsity": {sparsity},
    "single_device_us": single_us, "sharded_us": sharded_us,
    "speedup": single_us / max(sharded_us, 1e-9),
    "max_abs_diff": float(np.abs(np.asarray(yn) - np.asarray(y1)).max()),
}}))
"""


def _sharded_throughput(n_devices: int = 4, batch: int = 8,
                        sparsity: float = 0.75) -> dict:
    """1-vs-N-device throughput of the same compiled program (subprocess
    with virtualized host devices; data x model mesh = 2 x N/2)."""
    data = 2 if n_devices >= 2 else 1
    code = textwrap.dedent(_SHARDED_BODY).format(
        n=n_devices, data=data, model=n_devices // data,
        batch=batch, sparsity=sparsity,
    )
    env = dict(os.environ)
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600,
    )
    if out.returncode != 0:
        return {"error": out.stderr[-2000:], "devices": n_devices}
    return json.loads(out.stdout.strip().splitlines()[-1])


def _synthetic_vgg():
    """(cfg, params, bits) for the synthetic cifar10 VGG — the largest
    network the bench compiles, shared by the consistency and verify
    entries."""
    stats, layers = synthesize_network("cifar10", seed=0)
    cfg = vgg16_config(num_classes=10, input_hw=stats.input_hw)
    params = {}
    bits = {}
    for i, layer in enumerate(layers, start=1):
        spec = layer.spec
        params[f"conv{i}"] = {
            "w": jnp.asarray(
                layer.weights.reshape(spec.c_out, spec.c_in, 3, 3)
            ),
            "b": jnp.zeros((spec.c_out,), jnp.float32),
        }
        bits[f"conv{i}"] = layer.pattern_bits
    c_last = cfg.conv_channels[-1][1]
    params["fc"] = {
        "w": jnp.zeros((c_last, cfg.num_classes), jnp.float32),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    return cfg, params, bits


def _mapping_model_entry(name: str, cfg, params, bits,
                         sparsity: float | None = None) -> dict:
    """Fixed-vs-searched mapping numbers for one model.

    Compiles the same pruned network twice — the fixed paper scheme and
    ``optimize='auto'`` — and reports the deterministic chosen-vs-fixed
    crossbar area/energy ratios, whether the search is drift-free against
    the simulator pricing (``mapping_cost`` == report rows, exact
    equality), whether a standalone re-search reproduces the compiled
    choice byte-for-byte, and the search wall-clock relative to a fixed
    compile (a ratio, so machine speed cancels).
    """
    from repro.core.simulator import mapping_cost
    from repro.engine.lowering import conv_mapping_search

    # fixed compile: best-of-2 removes timer noise from the ratio gate
    times = []
    for _ in range(2):
        t0 = time.perf_counter()
        prog_fixed = compile_network(cfg, params, bits)
        times.append(time.perf_counter() - t0)
    fixed_compile_s = min(times)

    tr = Tracer()
    prog_auto = compile_network(
        cfg, params, bits,
        options=CompileOptions(optimize="auto", tracer=tr),
    )
    search_spans = [s for s in tr.spans("compile")
                    if s.name.startswith("search:")]
    search_s = float(sum(s.dur for s in search_spans))
    evaluations = int(sum(s.args.get("evaluations", 0)
                          for s in search_spans))

    # determinism: the standalone search must reproduce the compiled
    # choice exactly (same seed -> same candidate)
    deterministic = True
    for i, c in enumerate(prog_auto.convs, start=1):
        res = conv_mapping_search(
            np.asarray(params[f"conv{i}"]["w"]), bits.get(f"conv{i}"),
            c.out_hw,
        )
        deterministic &= res.chosen == c.mapping

    rf = prog_fixed.hardware_report()
    ra = prog_auto.hardware_report()

    # zero-drift: the search's cost model re-prices every chosen layer to
    # the exact report numbers (== on floats, not a tolerance)
    cost_exact = True
    for c, row in zip(prog_auto.convs, ra["layers"]):
        mc = mapping_cost(c.pattern_bits, c.mapping, c.out_hw ** 2,
                          c.kernel ** 2)
        cost_exact &= (
            mc.crossbars == row["crossbars"]
            and mc.area_cells == row["area_cells"]
            and mc.energy_pj == row["energy_pj"]
            and mc.cycles == row["cycles"]
        )

    area_ratio = ra["area_cells"] / max(rf["area_cells"], 1)
    energy_ratio = ra["energy_pj"] / max(rf["energy_pj"], 1e-9)
    return {
        "model": name,
        "sparsity": sparsity,
        "fixed": {"area_cells": rf["area_cells"],
                  "energy_pj": rf["energy_pj"],
                  "cycles": rf["cycles"],
                  "crossbars": rf["crossbars"]},
        "searched": {"area_cells": ra["area_cells"],
                     "energy_pj": ra["energy_pj"],
                     "cycles": ra["cycles"],
                     "crossbars": ra["crossbars"]},
        "chosen": ra["mapping"]["per_layer"],
        "fc_reorder": ra["mapping"]["fc_reorder"],
        "area_ratio": area_ratio,
        "energy_ratio": energy_ratio,
        "searched_le_fixed": (
            ra["area_cells"] <= rf["area_cells"]
            and ra["energy_pj"] <= rf["energy_pj"]
        ),
        "strictly_improved": (
            ra["area_cells"] < rf["area_cells"]
            or ra["energy_pj"] < rf["energy_pj"]
        ),
        "cost_model_exact": cost_exact,
        "search_deterministic": deterministic,
        "evaluations": evaluations,
        "search_s": search_s,
        "fixed_compile_s": fixed_compile_s,
        "search_overhead": search_s / max(fixed_compile_s, 1e-9),
    }


def _mapping_entry(smoke: bool) -> dict:
    """The ``mapping`` bench entry: searched must match-or-beat fixed on
    area *and* energy for every model here (``check_baseline.py`` gates
    the aggregate booleans and the deterministic ratios)."""
    cfg = mini_cnn_config(num_classes=4, input_hw=12, widths=(8, 16, 16))
    params, bits = _pruned(cfg, 0.75, num_patterns=8, seed=1)
    models = [_mapping_model_entry("mini_cnn", cfg, params, bits, 0.75)]
    if not smoke:
        vcfg, vparams, vbits = _synthetic_vgg()
        models.append(
            _mapping_model_entry("vgg16_cifar_synth", vcfg, vparams, vbits)
        )
    return {
        "models": models,
        "all_searched_le_fixed": all(
            m["searched_le_fixed"] for m in models
        ),
        "any_strictly_improved": any(
            m["strictly_improved"] for m in models
        ),
        "cost_model_exact": all(m["cost_model_exact"] for m in models),
        "search_deterministic": all(
            m["search_deterministic"] for m in models
        ),
    }


def _consistency_check() -> dict:
    """Engine hardware_report vs simulate_dataset on identical bits."""
    cfg, params, bits = _synthetic_vgg()
    prog = compile_network(cfg, params, bits)
    rep = prog.hardware_report()
    sim = simulate_dataset("cifar10", seed=0)
    engine_per_layer = [l["crossbars"] for l in rep["layers"]]
    sim_per_layer = [l.ours_crossbars for l in sim.layers]
    return {
        "dataset": "cifar10",
        "engine_crossbars": int(sum(engine_per_layer)),
        "simulator_crossbars": int(sum(sim_per_layer)),
        "per_layer_match": engine_per_layer == sim_per_layer,
    }


def _verify_overhead() -> dict:
    """Static-verifier cost relative to compile on the synthetic VGG.

    Both stored precisions are compiled and verified; compile and verify
    wall-times are summed so the ratio reflects the real cost of leaving
    ``verify`` on at every trust boundary.  ``check_baseline.py`` gates
    ``overhead_frac`` at < 10% of compile time and requires
    ``errors == 0`` — every program this bench compiles must pass.
    """
    from repro.analysis.verify import verify_network

    cfg, params, bits = _synthetic_vgg()
    compile_s = verify_s = 0.0
    errors = warnings_ = 0
    for precision in ("fp32", "int8"):
        t0 = time.perf_counter()
        prog = compile_network(
            cfg, params, bits, options=CompileOptions(precision=precision)
        )
        compile_s += time.perf_counter() - t0
        # verification is deterministic; best-of-2 removes timer noise
        # from the ratio gate
        times = []
        for _ in range(2):
            t0 = time.perf_counter()
            report = verify_network(prog)
            times.append(time.perf_counter() - t0)
        verify_s += min(times)
        errors += len(report.errors)
        warnings_ += len(report.warnings)
    return {
        "compile_s": compile_s,
        "verify_s": verify_s,
        "overhead_frac": verify_s / max(compile_s, 1e-9),
        "errors": errors,
        "warnings": warnings_,
    }


def _ranges_overhead() -> dict:
    """Range-certification cost relative to compile on the synthetic VGG.

    Both stored precisions are compiled once and range-analyzed twice
    (best-of-2 removes timer noise).  ``check_baseline.py`` gates
    ``overhead_frac`` at < 1.5x compile time (the pass touches every
    stored weight, so its floor is compile-comparable — the gate stops
    regressions, not physics), ``errors == 0`` on both precisions, and
    ``deterministic`` — two independent analyses of the same program
    must produce byte-identical certificates.  Warnings are reported
    but not gated: the deep VGG legitimately exceeds the fp32 range
    through the channel-norm eps division (rule V504).
    """
    from repro.analysis.ranges import analyze_network

    cfg, params, bits = _synthetic_vgg()
    compile_s = ranges_s = 0.0
    errors = warnings_ = 0
    deterministic = True
    certified_cells: dict = {}
    for precision in ("fp32", "int8"):
        t0 = time.perf_counter()
        prog = compile_network(
            cfg, params, bits, options=CompileOptions(precision=precision)
        )
        compile_s += time.perf_counter() - t0
        times = []
        manifests = []
        for _ in range(2):
            t0 = time.perf_counter()
            report, cert = analyze_network(prog)
            times.append(time.perf_counter() - t0)
            manifests.append(cert.to_manifest())
        ranges_s += min(times)
        deterministic &= manifests[0] == manifests[1]
        errors += len(report.errors)
        warnings_ += len(report.warnings)
        if precision == "int8":
            certified_cells = cert.certified_cells()
    return {
        "compile_s": compile_s,
        "ranges_s": ranges_s,
        "overhead_frac": ranges_s / max(compile_s, 1e-9),
        "errors": errors,
        "warnings": warnings_,
        "deterministic": deterministic,
        "certified_cells": certified_cells,
    }


def collect(quick: bool = False, smoke: bool = False,
            tracer: Tracer | None = None) -> dict:
    sparsities = SPARSITIES[1:2] if (quick or smoke) else SPARSITIES
    networks = [
        _bench_network(
            "mini_cnn",
            mini_cnn_config(num_classes=4, input_hw=12,
                            widths=(8, 16, 16)),
            batch=8,
            sparsities=sparsities,
        ),
    ]
    if not smoke:
        networks.append(
            _bench_network(
                "vgg16_cifar",
                vgg16_config(num_classes=10, input_hw=32),
                batch=2,
                sparsities=sparsities,
            )
        )
    report = {
        "networks": networks,
        "service": _service_throughput(tracer=tracer),
        "http_service": _http_service_throughput(),
        "sharded": _sharded_throughput(
            n_devices=2 if smoke else (4 if quick else 8)
        ),
        "consistency": _consistency_check(),
        "verify": _verify_overhead(),
        "ranges": _ranges_overhead(),
        "mapping": _mapping_entry(smoke),
    }
    return report


def run():
    """CSV rows for benchmarks.run."""
    report = collect(quick=True)
    for net in report["networks"]:
        for lv in net["levels"]:
            hw = lv["hardware_report"]
            q = lv["quantized"]
            yield (
                f"engine_{net['network']}_s{lv['sparsity']:.2f},"
                f"{lv['engine_us']:.1f},"
                f"dense_us={lv['dense_us']:.1f}"
                f";crossbars={hw['crossbars']}"
                f";area_eff={hw['area_efficiency']:.2f}"
                f";e_measured_pj={lv['energy_pj_measured']:.0f}"
                f";e_assumed_pj={lv['energy_pj_assumed']:.0f}"
            )
            yield (
                f"engine_{net['network']}_s{lv['sparsity']:.2f}_int8,"
                f"{q['engine_us']:.1f},"
                f"top1_agree={q['top1_agreement_vs_fp32']:.3f}"
                f";max_diff={q['max_abs_diff_vs_fp32']:.1e}"
                f";crossbars={q['crossbars']}"
                f";area_win={q['area_win_vs_fp32']:.2f}"
                f";energy_win={q['energy_win_vs_fp32']:.2f}"
            )
    sv = report["service"]
    yield (
        f"engine_service_{sv['batch_slots']}slots,"
        f"{sv['requests_per_s']:.1f},"
        f"requests={sv['requests']}"
        f";batches={sv['batches_run']}"
        f";traces={sv['trace_count']}"
        f";occupancy={sv['occupancy_mean']:.2f}"
        f";stats_exact={sv['stats_exact']}"
    )
    hs = report["http_service"]
    yield (
        f"engine_http_{hs['batch_slots']}slots,"
        f"{hs['requests_per_s']:.1f},"
        f"occupancy={hs['occupancy_mean']:.2f}"
        f";p50_s={hs['first_result_p50_s']:.4f}"
        f";p99_s={hs['first_result_p99_s']:.4f}"
        f";traces={hs['trace_count']}"
        f";shed={hs['shed']['shed']}"
        f";all_ok={hs['all_ok']}"
    )
    sh = report["sharded"]
    if "error" not in sh:
        yield (
            f"engine_sharded_{sh['devices']}dev,"
            f"{sh['sharded_us']:.1f},"
            f"single_us={sh['single_device_us']:.1f}"
            f";speedup={sh['speedup']:.2f}"
            f";max_diff={sh['max_abs_diff']:.1e}"
        )
    c = report["consistency"]
    yield (
        f"engine_consistency,0.0,"
        f"engine={c['engine_crossbars']}"
        f";simulator={c['simulator_crossbars']}"
        f";match={c['per_layer_match']}"
    )
    mp = report["mapping"]
    for m in mp["models"]:
        yield (
            f"engine_mapping_{m['model']},"
            f"{m['search_s'] * 1e6:.1f},"
            f"area_ratio={m['area_ratio']:.4f}"
            f";energy_ratio={m['energy_ratio']:.4f}"
            f";le_fixed={m['searched_le_fixed']}"
            f";improved={m['strictly_improved']}"
            f";cost_exact={m['cost_model_exact']}"
            f";deterministic={m['search_deterministic']}"
            f";evals={m['evaluations']}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write JSON here (else stdout)")
    ap.add_argument("--quick", action="store_true",
                    help="single sparsity level")
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-regression config: mini-CNN only, one "
                         "sparsity, 2-device sharded entry")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome trace-event JSON (Perfetto / "
                         "chrome://tracing) of the service entry: compile "
                         "phases, per-layer forward, request lifecycles")
    args = ap.parse_args()
    tracer = Tracer() if args.trace_out else None
    report = collect(quick=args.quick, smoke=args.smoke, tracer=tracer)
    payload = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload + "\n")
        print(f"wrote {args.out}")
    else:
        print(payload)
    if tracer is not None:
        tracer.write(args.trace_out)
        print(f"wrote {args.trace_out}")
    if not report["consistency"]["per_layer_match"]:
        raise SystemExit("engine/simulator crossbar mismatch")


if __name__ == "__main__":
    main()
