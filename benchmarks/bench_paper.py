"""Paper tables/figures: Fig 7 (area), Fig 8 (energy), §V-C (speedup),
§V-D (index overhead), Table II (pruning statistics).

One simulation per dataset feeds all five artifacts; rows are emitted per
figure so benchmarks/run.py prints one CSV line per paper artifact.

Paper reference values (for the derived column comparisons):
  area efficiency   4.67x / 5.20x / 4.16x   (CIFAR-10 / CIFAR-100 / ImageNet)
  energy efficiency 2.13x / 2.15x / 1.98x
  speedup           1.35x / 1.15x / 1.17x
  index overhead    729.5KB / 1013.5KB / 990.6KB
"""

from __future__ import annotations

from benchmarks.common import row, timed
from repro.core.simulator import simulate_dataset
from repro.core.synthetic import (
    TABLE_II,
    network_sparsity,
    network_zero_pattern_ratio,
    synthesize_network,
)

PAPER = {
    "cifar10": dict(area=4.67, energy=2.13, speedup=1.35, index_kb=729.5),
    "cifar100": dict(area=5.20, energy=2.15, speedup=1.15, index_kb=1013.5),
    "imagenet": dict(area=4.16, energy=1.98, speedup=1.17, index_kb=990.6),
}


def run() -> list[str]:
    rows = []
    for ds in ("cifar10", "cifar100", "imagenet"):
        rep, us = timed(simulate_dataset, ds, seed=0)
        s = rep.summary()
        p = PAPER[ds]
        rows.append(row(
            f"fig7_area_{ds}", us,
            f"ours={s['area_efficiency']:.2f}x paper={p['area']}x "
            f"xbars={int(s['ours_crossbars'])}/{int(s['naive_crossbars'])}",
        ))
        rows.append(row(
            f"fig8_energy_{ds}", us,
            f"ours={s['energy_efficiency']:.2f}x paper={p['energy']}x",
        ))
        bd = rep.breakdown("ours")
        total = sum(bd.values())
        rows.append(row(
            f"fig8_breakdown_{ds}", us,
            f"adc={bd['adc_pj']/total:.0%} array={bd['array_pj']/total:.0%} "
            f"dac={bd['dac_pj']/total:.0%}",
        ))
        rows.append(row(
            f"sec5c_speedup_{ds}", us,
            f"ours={s['speedup']:.2f}x paper={p['speedup']}x",
        ))
        rows.append(row(
            f"sec5d_index_{ds}", us,
            f"ours={s['index_overhead_kb']:.0f}KB paper={p['index_kb']}KB",
        ))
    # Table II statistics of the synthetic checkpoints
    for ds in ("cifar10", "cifar100", "imagenet"):
        (stats, layers), us = timed(synthesize_network, ds, seed=0)
        rows.append(row(
            f"table2_{ds}", us,
            f"sparsity={network_sparsity(layers):.4f}"
            f"(target {stats.sparsity}) "
            f"zero_ratio={network_zero_pattern_ratio(layers):.3f}"
            f"(target {stats.zero_pattern_ratio})",
        ))
    return rows
